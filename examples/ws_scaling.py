"""Watts-Strogatz scaling probe (the paper's proposed 'accessible
benchmark for interaction-based graph simulations', §IV-A2): generate WS
populations of growing size, simulate, report per-day time and TEPS.

    PYTHONPATH=src python examples/ws_scaling.py
"""

import time

import numpy as np

from repro.core import disease, transmission
from repro.engine.core import EngineCore
from repro.data import watts_strogatz_population

print(f"{'people':>9s} {'locs':>8s} {'visits/wk':>10s} {'s/day':>8s} {'TEPS':>10s}")
for P, L in ((5_000, 1_250), (20_000, 5_000), (80_000, 20_000)):
    pop = watts_strogatz_population(P, L, seed=0, name=f"ws{P}")
    sim = EngineCore.single(
        pop, disease.covid_model(), transmission.TransmissionModel(tau=5e-6),
        seed=1,
    )
    days = 20
    _, hist = sim.run1(days)  # includes compile
    t0 = time.time()
    _, hist = sim.run1(days)
    dt = time.time() - t0
    edges = float(np.asarray(hist["contacts"], np.float64).sum())
    print(f"{P:9d} {L:8d} {pop.visits_per_week:10d} {dt/days:8.3f} "
          f"{edges/dt:10.3g}")
