"""End-to-end LM training example: train a ~small model from the zoo for a
few hundred steps on the deterministic synthetic pipeline, with a mid-run
checkpoint + injected failure to demonstrate exact recovery.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen2-1.5b] [--steps 200]

(Thin wrapper over repro.launch.train — the production driver.)
"""

import subprocess
import sys
import tempfile

arch = "smollm-360m"
steps = "200"
args = sys.argv[1:]
if "--arch" in args:
    arch = args[args.index("--arch") + 1]
if "--steps" in args:
    steps = args[args.index("--steps") + 1]

with tempfile.TemporaryDirectory() as ckpt:
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", arch, "--preset", "smoke", "--steps", steps,
        "--batch", "16", "--seq", "128", "--lr", "3e-3",
        "--ckpt-dir", ckpt, "--ckpt-every", "50",
        "--inject-failures", str(int(steps) // 2 + 3),
    ]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))
