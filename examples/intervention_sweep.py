"""Factorial intervention sweep through the unified API.

One :class:`repro.api.ExperimentSpec` describes the whole study —
3 intervention arms x 2 transmissibilities x 2 Monte Carlo seeds = 12
scenarios — and ``repro.api.run`` executes it as a SINGLE jitted
``lax.scan`` whose body is the vmap-over-scenarios day step, with the
cross-scenario mean/CI reductions computed on device inside that scan.
Per-scenario trajectories are bitwise identical to 12 sequential
single-scenario core runs (tests/test_api.py proves engine-dispatch parity);
only the wall-clock differs.

With >= 4 JAX devices visible (e.g. XLA_FLAGS=
--xla_force_host_platform_device_count=8) the same spec is re-dispatched
onto the hybrid 2-D (workers x scenarios) mesh — every scenario
people/location-sharded over 2 workers — and checked bitwise against the
ensemble run: changing the mesh never changes the science.

    PYTHONPATH=src python examples/intervention_sweep.py
"""

import numpy as np
import jax

from repro import api
from repro.analysis.report import summarize_result, sweep_table

spec = api.ExperimentSpec(
    name="intervention-sweep",
    dataset="twin-2k",
    disease="covid",
    days=100,
    interventions=("none", "school-closure", "lockdown"),
    tau=9e-6,
    tau_scales=(1.0, 1.4),      # low / high transmissibility
    replicates=2,               # MC replicates (innermost axis)
)
print(f"{spec.num_scenarios} scenarios "
      f"({len(spec.interventions)} interventions x "
      f"{len(spec.tau_scales)} tau x {spec.replicates} replicates)")

result = api.run(spec)  # ONE lax.scan over 100 vmapped days
sweep_table(summarize_result(result))
edges = sum(r["interactions"] for r in result.summaries)
wall = result.provenance["run_wall_s"]  # day loop only, excl. pop build
print(f"\nengine={result.provenance['engine']}: {spec.num_scenarios} "
      f"scenarios x {spec.days} days in {wall:.1f}s "
      f"(ensemble TEPS = {edges / wall:.3g})")

# Cross-scenario incidence band, reduced on device inside the scan:
band = result.observables["ensemble_mean_ci"]["new_infections"]
d = int(np.argmax(np.asarray(band["mean"])))
print(f"ensemble incidence peaks on day {d}: "
      f"mean {band['mean'][d]:.1f}, 95% CI "
      f"[{band['lo'][d]:.1f}, {band['hi'][d]:.1f}]")

# --- same spec, hybrid 2-D mesh: only the mesh shape changes -------------
if len(jax.devices()) >= 4:
    hybrid = api.run(spec.with_overrides(
        workers=2, scenarios=len(jax.devices()) // 2))
    assert hybrid.provenance["engine"] == "hybrid"
    np.testing.assert_array_equal(hybrid.history["cumulative"],
                                  result.history["cumulative"])
    print(f"hybrid 2x{len(jax.devices()) // 2} mesh: same batch in "
          f"{hybrid.provenance['run_wall_s']:.1f}s, trajectories bitwise "
          "identical")
else:
    print("(run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to "
          "also exercise the hybrid workers x scenarios mesh)")
