"""Factorial intervention sweep — the ensemble twin of intervention_study.py.

Where intervention_study.py loops Python-side over scenarios and
replicates (one jitted run each), this study runs the whole factorial —
2 intervention arms x 2 transmissibilities x 2 Monte Carlo seeds = 8
scenarios — as a SINGLE jitted ``lax.scan`` whose body is the
vmap-over-scenarios day step (repro.sweep). Per-scenario trajectories are
bitwise identical to what 8 sequential EpidemicSimulator runs would
produce (tests/test_sweep.py proves it); only the wall-clock differs.

    PYTHONPATH=src python examples/intervention_sweep.py
"""

import time

from repro.analysis.report import summarize_sweep, sweep_table
from repro.configs import ScenarioBatch
from repro.core import disease
from repro.core import interventions as iv
from repro.data import digital_twin_population
from repro.sweep import EnsembleSimulator

pop = digital_twin_population(4000, seed=1, name="sweep-study")

batch = ScenarioBatch.from_product(
    interventions={
        "baseline": (),
        "schools+masks": [
            iv.Intervention("schools", iv.CaseThreshold(on=50),
                            iv.LocTypeIs(2), iv.CloseLocations()),
            iv.Intervention("masks", iv.CaseThreshold(on=100, off=20),
                            iv.Everyone(), iv.ScaleInfectivity(0.4)),
        ],
    },
    tau=[9e-6, 1.3e-5],  # low / high transmissibility
    disease=disease.covid_model(),
    seeds=[100, 101],  # Monte Carlo replicates (innermost axis)
)
assert len(batch) >= 8, len(batch)

ens = EnsembleSimulator(pop, batch)
t0 = time.time()
final, hist = ens.run(100)  # ONE lax.scan over 100 vmapped days
wall = time.time() - t0

rows = summarize_sweep(hist, batch.names, pop.num_people)
sweep_table(rows)
edges = sum(r["interactions"] for r in rows)
print(f"\n{len(batch)} scenarios x 100 days in {wall:.1f}s "
      f"(one jitted scan; ensemble TEPS = {edges / wall:.3g})")
