"""Factorial intervention sweep — the ensemble twin of intervention_study.py.

Where intervention_study.py loops Python-side over scenarios and
replicates (one jitted run each), this study runs the whole factorial —
2 intervention arms x 2 transmissibilities x 2 Monte Carlo seeds = 8
scenarios — as a SINGLE jitted ``lax.scan`` whose body is the
vmap-over-scenarios day step (repro.sweep). Per-scenario trajectories are
bitwise identical to what 8 sequential EpidemicSimulator runs would
produce (tests/test_sweep.py proves it); only the wall-clock differs.

With multiple JAX devices visible (e.g. XLA_FLAGS=
--xla_force_host_platform_device_count=8) the same batch is also run on a
hybrid 2-D (workers x scenarios) mesh — every scenario people/location-
sharded over 2 workers — and checked bitwise against the vmap run.

    PYTHONPATH=src python examples/intervention_sweep.py
"""

import time

import numpy as np
import jax

from repro.analysis.report import summarize_sweep, sweep_table
from repro.configs import ScenarioBatch
from repro.core import disease
from repro.core import interventions as iv
from repro.data import digital_twin_population
from repro.launch.mesh import make_hybrid_mesh
from repro.sweep import EnsembleSimulator, HybridEnsemble

pop = digital_twin_population(4000, seed=1, name="sweep-study")

batch = ScenarioBatch.from_product(
    interventions={
        "baseline": (),
        "schools+masks": [
            iv.Intervention("schools", iv.CaseThreshold(on=50),
                            iv.LocTypeIs(2), iv.CloseLocations()),
            iv.Intervention("masks", iv.CaseThreshold(on=100, off=20),
                            iv.Everyone(), iv.ScaleInfectivity(0.4)),
        ],
    },
    tau=[9e-6, 1.3e-5],  # low / high transmissibility
    disease=disease.covid_model(),
    seeds=[100, 101],  # Monte Carlo replicates (innermost axis)
)
assert len(batch) >= 8, len(batch)

ens = EnsembleSimulator(pop, batch)
t0 = time.time()
final, hist = ens.run(100)  # ONE lax.scan over 100 vmapped days
wall = time.time() - t0

rows = summarize_sweep(hist, batch.names, pop.num_people)
sweep_table(rows)
edges = sum(r["interactions"] for r in rows)
print(f"\n{len(batch)} scenarios x 100 days in {wall:.1f}s "
      f"(one jitted scan; ensemble TEPS = {edges / wall:.3g})")

# --- hybrid 2-D mesh: the same batch, each scenario people-sharded -------
if len(jax.devices()) >= 4:
    mesh = make_hybrid_mesh(2)  # (2 workers) x (devices // 2 scenarios)
    hyb = HybridEnsemble(pop, batch, mesh=mesh)
    t0 = time.time()
    _, hhist = hyb.run(100)
    hwall = time.time() - t0
    assert (np.asarray(hhist["cumulative"]) == np.asarray(hist["cumulative"])).all(), \
        "hybrid run must be bitwise identical to the vmap run"
    print(f"hybrid 2x{int(mesh.shape['scenarios'])} mesh: same batch in "
          f"{hwall:.1f}s, trajectories bitwise identical")
else:
    print("(run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to "
          "also exercise the hybrid workers x scenarios mesh)")
