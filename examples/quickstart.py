"""Quickstart: simulate a COVID-like outbreak on a synthetic population.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import disease, simulator, transmission
from repro.data import watts_strogatz_population

# 1. A population: 5k people visiting 1.2k locations on a small-world
#    graph, weekly schedules generated per the paper's §IV-A2.
pop = watts_strogatz_population(5000, 1200, seed=0, name="quickstart")
print("population:", pop.stats())

# 2. A disease: the COVID-tuned SEIR+ FSA (S->E->Ipre->{Isym,Iasym}->R).
covid = disease.covid_model()

# 3. A simulator: min/max/alpha contacts, propensity transmission.
sim = simulator.EpidemicSimulator(
    pop, covid, transmission.TransmissionModel(tau=5e-6), seed=42
)

# 4. Run 150 days (one jitted lax.scan over days).
final, hist = sim.run(150)

peak = int(np.argmax(hist["infectious"]))
print(f"cumulative infections: {int(hist['cumulative'][-1])} "
      f"({100 * int(hist['cumulative'][-1]) / pop.num_people:.1f}% attack rate)")
print(f"peak: {int(hist['infectious'][peak])} infectious on day {peak}")
print(f"total person-person interactions: "
      f"{int(np.asarray(hist['contacts'], np.int64).sum()):,}")

# 5. ASCII epidemic curve.
inf = hist["infectious"]
for d in range(0, 150, 6):
    bar = "#" * int(50 * inf[d] / max(inf.max(), 1))
    print(f"day {d:3d} |{bar}")
