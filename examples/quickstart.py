"""Quickstart: one declarative spec -> one run() -> observables.

    PYTHONPATH=src python examples/quickstart.py

The spec below is everything: dataset, disease, run length, Monte Carlo
replicates, and which reductions to compute on device. ``repro.api.run``
derives the engine (3 replicates on a 1x1 mesh -> the vmapped ensemble:
all replicates advance in ONE jitted lax.scan, with the observables
reduced inside the scan body). The same spec could be a TOML file —
see examples/experiment.toml and `python -m repro.launch.simulate --spec`.
"""

import numpy as np

from repro import api

spec = api.ExperimentSpec(
    name="quickstart",
    dataset="twin-2k",          # a 2k-person digital-twin population
    disease="covid",            # SEIR+ FSA (S->E->Ipre->{Isym,Iasym}->R)
    tau=2e-5,                   # transmissibility (Eq. 2 prefactor)
    days=150,
    replicates=3,               # MC seeds 0,1,2 -> a 3-wide ensemble
    observables=("daily_new_infections", "attack_rate", "peak_day",
                 "ensemble_mean_ci"),
)
result = api.run(spec)

print(f"engine={result.provenance['engine']} "
      f"scenarios={result.num_scenarios} days={result.days}")

# Per-replicate reductions, computed on device inside the scan:
ar = result.observables["attack_rate"]["attack_rate"]
peak = result.observables["peak_day"]
for i, name in enumerate(result.scenario_names):
    print(f"{name}: attack rate {100 * ar[i]:.1f}%, "
          f"peak {peak['peak_infectious'][i]} infectious "
          f"on day {peak['peak_day'][i]}")

# The cross-replicate mean/CI band of the infectious curve (also reduced
# on device), as an ASCII epidemic curve:
band = result.observables["ensemble_mean_ci"]["infectious"]
mean, lo, hi = (np.asarray(band[k]) for k in ("mean", "lo", "hi"))
scale = 50 / max(float(hi.max()), 1.0)
for d in range(0, spec.days, 6):
    bar = "#" * int(scale * mean[d])
    print(f"day {d:3d} |{bar}  (95% CI [{lo[d]:.0f}, {hi[d]:.0f}])")

# The day-major history is always (days, B) — engine-independent.
total = int(np.asarray(result.history["contacts"], np.int64).sum())
print(f"total person-person interactions across the ensemble: {total:,}")
