"""Intervention what-if study (the paper's §VIII use case), now through
the declarative front door: one :class:`repro.api.ExperimentSpec` sweeping
the named intervention presets — including the PR 7 per-agent
test-trace-isolate family — against a no-intervention baseline, with the
comparison reduced on device by the ``averted_by_tti`` observable
(scenario 0 is the baseline arm by convention).

    PYTHONPATH=src python examples/intervention_study.py
"""

import numpy as np

from repro.api import ExperimentSpec, run

spec = ExperimentSpec(
    name="intervention-study",
    dataset="twin-2k",
    disease="covid",
    days=150,
    seed=100,
    # One sweep axis over the preset vocabulary: the classic
    # trigger/selector/effect family plus both per-agent TTI presets
    # (capacity-limited testing with and without contact tracing).
    interventions=(
        "none", "school-closure", "vax-seniors", "lockdown",
        "tti", "tti-no-trace",
    ),
    observables=(
        "attack_rate", "peak_day", "tests_used", "isolated_count",
        "averted_by_tti",
    ),
)

res = run(spec)
obs = res.observables
names = res.scenario_names
pop_n = int(round(float(obs["attack_rate"]["cumulative"][0])
                  / float(obs["attack_rate"]["attack_rate"][0])))

print(f"{'scenario':16s} {'attack%':>8s} {'peak day':>9s} {'averted':>8s} "
      f"{'tests':>7s} {'peak iso':>9s}")
for i, name in enumerate(names):
    print(f"{name:16s} "
          f"{100 * obs['attack_rate']['attack_rate'][i]:7.1f}% "
          f"{obs['peak_day']['peak_day'][i]:9d} "
          f"{obs['averted_by_tti']['averted'][i]:8d} "
          f"{obs['tests_used']['tests_total'][i]:7d} "
          f"{obs['isolated_count']['peak_isolated'][i]:9d}")

# The day-major tests series shows budget saturation: once the symptomatic
# queue outgrows tests_per_day the daily count pins at the capacity.
daily_tests = np.asarray(obs["tests_used"]["daily"])
tti_col = list(names).index("tti")
print(f"\npeak daily tests (tti arm): {daily_tests[:, tti_col].max()} "
      f"(budget: 100/day)")
