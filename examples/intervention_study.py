"""Intervention what-if study (the paper's §VIII use case): compare
school closures, senior vaccination, and a triggered lockdown against a
no-intervention baseline, multiple replicates each.

    PYTHONPATH=src python examples/intervention_study.py
"""

import numpy as np

from repro.core import disease, transmission
from repro.engine.core import EngineCore
from repro.core import interventions as iv
from repro.data import digital_twin_population

pop = digital_twin_population(8000, seed=1, name="study")
covid = disease.covid_model()
tm = transmission.TransmissionModel(tau=9e-6)

SCENARIOS = {
    "baseline": [],
    "school-closure@50cases": [iv.Intervention(
        "schools", iv.CaseThreshold(on=50), iv.LocTypeIs(2), iv.CloseLocations()
    )],
    "vaccinate-60%-day10": [iv.Intervention(
        "vax", iv.DayRange(10), iv.RandomFraction(0.6, salt=7), iv.Vaccinate(0.9)
    )],
    "mask-mandate@100cases": [iv.Intervention(
        "masks", iv.CaseThreshold(on=100, off=20), iv.Everyone(),
        iv.ScaleInfectivity(0.4)
    )],
    "triggered-lockdown": [iv.Intervention(
        "lockdown", iv.CaseThreshold(on=400, off=50),
        iv.RandomFraction(0.75, salt=3), iv.Isolate()
    )],
}

REPS = 5
print(f"{'scenario':28s} {'attack%':>8s} {'peak':>6s} {'peak day':>9s}")
for name, ivs in SCENARIOS.items():
    attack, peaks, pdays = [], [], []
    for rep in range(REPS):
        sim = EngineCore.single(
            pop, covid, tm, interventions=ivs, seed=100 + rep
        )
        _, hist = sim.run1(150)
        attack.append(100 * hist["cumulative"][-1] / pop.num_people)
        peaks.append(hist["infectious"].max())
        pdays.append(np.argmax(hist["infectious"]))
    print(f"{name:28s} {np.mean(attack):7.1f}% {np.mean(peaks):6.0f} "
          f"{np.mean(pdays):9.1f}")
