"""Kernel microbenchmarks: interaction pass backends, flash attention,
SSD scan — wall time on CPU vs their oracles (the TPU story lives in the
dry-run roofline)."""

# detlint: skip-file — microbench input generation: fixed-seed host/keyed
# draws shaping LM-kernel tensors; no epidemic randomness, timing only.

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import contact as contact_lib
from repro.core import population as pop_lib
from repro.kernels.interactions import ops as iops
from repro.models import ssd


def run():
    # --- interaction backends -------------------------------------------
    rs = np.random.default_rng(0)
    Vn, L, P, b = 4096, 600, 2000, 128
    person = rs.integers(0, P, Vn)
    loc = rs.integers(0, L, Vn)
    start = rs.uniform(0, 80000, Vn).astype(np.float32)
    end = (start + rs.uniform(600, 20000, Vn)).astype(np.float32)
    dv = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    occ = contact_lib.max_occupancy_fast(L, loc, start, end)
    p_loc = np.asarray(contact_lib.MinMaxAlpha().probability(occ), np.float32)
    sus = rs.uniform(0, 1, P).astype(np.float32)
    inf = np.where(rs.random(P) < 0.1, 1.0, 0.0).astype(np.float32)
    safe = np.maximum(dv.person, 0)
    sched = pop_lib.build_block_schedule(dv.loc, dv.num_real, b)
    sus_v = jnp.asarray(sus[safe] * dv.active)
    inf_v = jnp.asarray(inf[safe] * dv.active)
    args = (
        jnp.asarray(dv.person), jnp.asarray(dv.loc), jnp.asarray(dv.start),
        jnp.asarray(dv.end), jnp.asarray(p_loc[np.minimum(dv.loc, L - 1)]),
        sus_v, inf_v,
        jnp.asarray(sched.row_block), jnp.asarray(sched.col_block),
        jnp.asarray(sched.row_start.astype(np.int32)),
        jnp.asarray(sched.pair_active.astype(np.int32)),
        iops.col_has_infectious(inf_v, jnp.asarray(dv.person),
                                sched.num_blocks, b),
        iops.row_has_susceptible(sus_v, jnp.asarray(dv.person),
                                 sched.num_blocks, b),
        jnp.asarray([1, 0], jnp.uint32),
    )
    pairs = sched.num_pairs * b * b
    for backend in ("jnp", "scan", "compact"):
        t = time_fn(lambda be=backend: iops.interactions_auto(
            *args, block_size=b, backend=be)[0])
        emit(f"kernel_interactions/{backend}", t * 1e6,
             f"pairs={pairs};pairs_per_s={pairs/t:.3g};"
             f"sparsity={sched.sparsity:.3f}")

    # --- flash attention vs naive ----------------------------------------
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models import attention as A
    import dataclasses
    from repro.configs import ARCHS, reduced_config

    cfg = dataclasses.replace(reduced_config(ARCHS["granite-3-2b"]),
                              num_heads=8, num_kv_heads=4, head_dim=64,
                              compute_dtype="float32")
    B, S, M, G, Dh = 1, 1024, 4, 2, 64
    q = jax.random.normal(jax.random.key(0), (B, S, M, G, Dh))
    k = jax.random.normal(jax.random.key(1), (B, S, M, Dh))
    v = jax.random.normal(jax.random.key(2), (B, S, M, Dh))
    mask = A.causal_window_mask(S, 0, S, None)[None, None, None]
    t_naive = time_fn(lambda: A.attend(q, k, v, mask, cfg))
    t_chunk = time_fn(lambda: A.attend_chunked(q, k, v, cfg, chunk=256))
    flops = 4 * B * M * G * S * S * Dh
    emit("kernel_attention/naive", t_naive * 1e6, f"gflops_s={flops/t_naive/1e9:.1f}")
    emit("kernel_attention/chunked", t_chunk * 1e6, f"gflops_s={flops/t_chunk/1e9:.1f}")
    t_flash = time_fn(lambda: flash_attention(q, k, v, blk_q=128, blk_k=128))
    emit("kernel_attention/pallas_interpret", t_flash * 1e6,
         "interpret-mode (correctness path; perf target is TPU)")

    # --- SSD scan ----------------------------------------------------------
    bs, S2, H, P2, Gg, N = 2, 2048, 8, 64, 1, 64
    x = jax.random.normal(jax.random.key(3), (bs, S2, H, P2))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(4), (bs, S2, H)))
    Aa = -jnp.exp(jax.random.normal(jax.random.key(5), (H,)) * 0.5)
    Bm = jax.random.normal(jax.random.key(6), (bs, S2, Gg, N)) * 0.3
    Cm = jax.random.normal(jax.random.key(7), (bs, S2, Gg, N)) * 0.3
    for chunk in (64, 256):
        t = time_fn(lambda c=chunk: ssd.ssd_scan_ref(x, dt, Aa, Bm, Cm, c)[0])
        emit(f"kernel_ssd/chunk{chunk}", t * 1e6,
             f"tokens_per_s={bs*S2/t:.3g}")
