"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sizes are CPU-calibrated;
the scale-out story is carried by the dry-run roofline (bench_roofline
reads its artifacts).

    PYTHONPATH=src python -m benchmarks.run [--only fig6,fig9] [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench keys")
    ap.add_argument("--fast", action="store_true",
                    help="smaller datasets / fewer replicates")
    args = ap.parse_args()

    from benchmarks import (
        bench_kernels, bench_opts, bench_phases, bench_roofline,
        bench_sharding, bench_strong, bench_sweep, bench_teps,
        bench_validation, bench_weak,
    )

    fast = args.fast
    suites = {
        "fig1_config": lambda: bench_sharding.run(days=6 if fast else 10),
        "fig5_opts": lambda: bench_opts.run(
            dataset="twin-2k" if fast else "md-mini"),
        "fig6_strong": lambda: bench_strong.run(
            datasets=("twin-2k",) if fast else ("twin-2k", "md-mini", "ws-50k"),
            days=10 if fast else 30),
        "fig7_phases": lambda: bench_phases.run(days=20 if fast else 60),
        "fig8_weak": lambda: bench_weak.run(days=7 if fast else 14),
        "fig9_validation": lambda: bench_validation.run(
            replicates=6 if fast else 30, days=60 if fast else 120),
        "table1_teps": lambda: bench_teps.run(
            dataset="twin-2k" if fast else "md-mini", days=10 if fast else 20),
        "sweep": lambda: bench_sweep.run(
            dataset="twin-2k", batch_size=4 if fast else 8,
            days=10 if fast else 20),
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failures = 0
    for key, fn in suites.items():
        if only and not any(key.startswith(o) or o.startswith(key) for o in only):
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {key} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
