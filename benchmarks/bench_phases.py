"""Figs 4/7 analog: per-phase time breakdown over a simulated outbreak —
visits (intervention masks + gathers), interactions (DES replacement),
update (infection sampling + FSA). Shows the interaction phase tracking
the infection curve (Fig 4) and the phase shares (Fig 7)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_tau, emit, get_pop
from repro.core import disease, simulator, transmission
from repro.engine.core import EngineCore


def run(dataset="twin-2k", days=60):
    pop = get_pop(dataset)
    sim = EngineCore.single(
        pop, disease.covid_model(),
        transmission.TransmissionModel(tau=calibrated_tau(dataset)), seed=3,
        backend="scan",
    )
    _, hist, times = simulator.run_eager(sim, days)
    for phase in ("visits", "interact", "update"):
        t = times[phase][3:]  # skip jit warmup days
        emit(f"fig7_phase/{phase}", float(np.mean(t)) * 1e6,
             f"share={float(np.sum(t))/sum(float(np.sum(times[p][3:])) for p in times):.3f}")
    # Fig 4: correlation of interaction time with infectious count
    inf = hist["infectious"][3:].astype(float)
    it = times["interact"][3:]
    if inf.std() > 0 and np.std(it) > 0:
        rho = float(np.corrcoef(inf, it)[0, 1])
    else:
        rho = 0.0
    peak_day = int(np.argmax(hist["infectious"]))
    emit("fig4_interact_tracks_infections", 0.0,
         f"corr={rho:.3f};peak_day={peak_day};days={days}")
