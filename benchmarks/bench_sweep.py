"""Ensemble throughput: TEPS x batch for the vmap-over-scenarios engine.

The paper's Table I throughput metric (traversed edges per second) is
defined for a single trajectory; ensembles add a batch axis, so the
figure of merit here is **ensemble-TEPS** = sum over scenarios of
interactions, divided by wall time. Reported alongside per-scenario TEPS
and the vmap efficiency (ensemble-TEPS / single-run TEPS): values near B
mean the batch axis is nearly free, which is the point of running
ensembles inside one scan instead of looping.

CI smoke usage (writes the JSON perf breadcrumb uploaded as an artifact):

    python benchmarks/bench_sweep.py --tiny --out bench_sweep_tiny.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/bench_sweep.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np


def run(dataset="twin-2k", batch_size=8, days=20, backend="jnp", out=None):
    from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
    from repro.configs import ScenarioBatch
    from repro.core import disease
    from repro.sweep import EnsembleSimulator

    pop = get_pop(dataset)
    tau = calibrated_tau(dataset)
    batch = ScenarioBatch.from_product(
        disease=disease.covid_model(),
        tau=tau,
        seeds=list(range(1, batch_size + 1)),
    )
    ens = EnsembleSimulator(pop, batch, backend=backend)

    # Warm-up run also yields the interaction counts (identical re-run).
    _, hist = ens.run(days)
    edges = float(np.asarray(hist["contacts"], np.int64).sum())
    t_ens = time_fn(
        lambda: ens._run_scan(ens.params, ens.init_state(), days=days)[0].day,
        warmup=0, iters=1,
    )

    # Single-run reference: scenario 0 alone through the same engine.
    single = EnsembleSimulator(pop, ScenarioBatch.from_scenarios(batch[:1]),
                               backend=backend)
    single.run(days)
    t_one = time_fn(
        lambda: single._run_scan(single.params, single.init_state(),
                                 days=days)[0].day,
        warmup=0, iters=1,
    )

    ens_teps = edges / t_ens
    single_teps = (edges / batch_size) / t_one
    result = {
        "bench": "sweep",
        "dataset": dataset,
        "batch": batch_size,
        "days": days,
        "backend": backend,
        "wall_s": round(t_ens, 3),
        "single_wall_s": round(t_one, 3),
        "interactions_total": edges,
        "ensemble_teps": round(ens_teps, 1),
        "single_teps": round(single_teps, 1),
        "vmap_efficiency_x": round(ens_teps / max(single_teps, 1e-9), 2),
    }
    emit(f"sweep_teps/{dataset}_b{batch_size}", t_ens / days * 1e6,
         f"ensemble_teps={ens_teps:.3g};single_teps={single_teps:.3g};"
         f"vmap_eff_x={result['vmap_efficiency_x']}")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="twin-2k")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size: B=4, 10 days on the test twin")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.tiny:
        args.dataset, args.batch, args.days = "twin-2k", 4, 10
    r = run(args.dataset, args.batch, args.days, args.backend, args.out)
    print(json.dumps(r))


if __name__ == "__main__":
    main()
