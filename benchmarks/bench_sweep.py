"""Ensemble throughput: TEPS x batch for the ensemble engine layouts.

The paper's Table I throughput metric (traversed edges per second) is
defined for a single trajectory; ensembles add a batch axis, so the
figure of merit here is **ensemble-TEPS** = sum over scenarios of
interactions, divided by wall time. Reported alongside per-scenario TEPS
and the vmap efficiency (ensemble-TEPS / single-run TEPS): values near B
mean the batch axis is nearly free, which is the point of running
ensembles inside one scan instead of looping. The single-run reference
uses scenario 0's *own* traversed-edge count (scenarios traverse
different edge counts once interventions/transmissibility vary, so
dividing the ensemble total by B would skew the baseline).

``--workers W`` measures the hybrid 2-D (workers x scenarios) engine
instead: every scenario people/location-sharded over W devices, the
scenario axis over the rest (needs >= W devices, e.g. via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

CI smoke usage (writes the JSON perf breadcrumb uploaded as an artifact):

    python benchmarks/bench_sweep.py --tiny --out bench_sweep_tiny.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/bench_sweep.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np


def run(dataset="twin-2k", batch_size=8, days=20, backend="jnp", out=None,
        workers=1):
    from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
    from repro.configs import ScenarioBatch
    from repro.core import disease
    from repro.engine.core import EngineCore

    pop = get_pop(dataset)
    tau = calibrated_tau(dataset)
    batch = ScenarioBatch.from_product(
        disease=disease.covid_model(),
        tau=tau,
        seeds=list(range(1, batch_size + 1)),
    )
    if workers > 1:
        from repro.launch.mesh import make_hybrid_mesh

        mesh = make_hybrid_mesh(workers)
        ens = EngineCore(pop, batch, layout="hybrid", mesh=mesh,
                         backend=backend)
        mode = f"hybrid {workers}x{int(mesh.shape['scenarios'])}"
    else:
        ens = EngineCore(pop, batch, backend=backend)
        mode = "vmap"
    timed = ens.bench_fn(days)

    # Warm-up run also yields the interaction counts (identical re-run).
    # Batch padding slots are inert no-op scenarios in the engine core, so
    # the real-scenario edge total is the honest numerator.
    _, hist = ens.run(days)
    per_scenario = np.asarray(hist["contacts"], np.int64).sum(axis=0)  # (B,)
    edges = float(per_scenario.sum())
    t_ens = time_fn(timed, warmup=0, iters=1)

    # Single-run reference: scenario 0 alone through the same engine, scored
    # on its OWN traversed-edge count (not the batch mean).
    single = EngineCore(pop, ScenarioBatch.from_scenarios(batch[:1]),
                        backend=backend)
    _, hist_one = single.run(days)
    edges_one = float(np.asarray(hist_one["contacts"], np.int64).sum())
    t_one = time_fn(single.bench_fn(days), warmup=0, iters=1)

    ens_teps = edges / t_ens
    single_teps = edges_one / t_one
    result = {
        "bench": "sweep",
        "dataset": dataset,
        "mode": mode,
        "batch": batch_size,
        "workers": workers,
        "days": days,
        "backend": backend,
        "wall_s": round(t_ens, 3),
        "single_wall_s": round(t_one, 3),
        "interactions_total": edges,
        "ensemble_teps": round(ens_teps, 1),
        "single_teps": round(single_teps, 1),
        "vmap_efficiency_x": round(ens_teps / max(single_teps, 1e-9), 2),
    }
    tag = f"{dataset}_b{batch_size}" + (f"_w{workers}" if workers > 1 else "")
    emit(f"sweep_teps/{tag}", t_ens / days * 1e6,
         f"mode={mode};ensemble_teps={ens_teps:.3g};single_teps={single_teps:.3g};"
         f"vmap_eff_x={result['vmap_efficiency_x']}")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="twin-2k")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--workers", type=int, default=1,
                    help="hybrid mode: people-shard each scenario over this "
                         "many devices (2-D workers x scenarios mesh)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size: B=4, 10 days on the test twin")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.tiny:
        args.dataset, args.batch, args.days = "twin-2k", 4, 10
    r = run(args.dataset, args.batch, args.days, args.backend, args.out,
            workers=args.workers)
    print(json.dumps(r))


if __name__ == "__main__":
    main()
