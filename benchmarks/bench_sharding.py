"""Fig 1 analog: runtime-configuration study. The paper compares Charm++
SMP process/thread geometries; the JAX analog is the interaction-backend ×
block-size matrix (the knobs that trade dispatch overhead against
parallel-efficiency, like p/n × t/p did)."""

from __future__ import annotations

from benchmarks.common import calibrated_tau, day_step_fn, emit, get_pop, time_fn
from repro.core import disease, transmission
from repro.engine.core import EngineCore


def run(dataset="twin-2k", days=10):
    pop = get_pop(dataset)
    tau = calibrated_tau(dataset)
    for backend in ("jnp", "scan"):
        for block in (64, 128, 256):
            sim = EngineCore.single(
                pop, disease.covid_model(),
                transmission.TransmissionModel(tau=tau), seed=1,
                backend=backend, block_size=block,
            )
            st, _ = sim.run1(10)  # representative epidemic state
            step = day_step_fn(sim)
            t = time_fn(lambda: step(st)[0].day, iters=3)
            emit(f"fig1_config/{backend}/b{block}", t * 1e6,
                 f"pairs={int(sim.week_data.row_idx.shape[1])}")
