"""Serving-tier benchmark: warm time-to-first-day vs cold per-spec runs.

The serving tier's claim is quantitative: once a shape bucket is warm,
a what-if request costs milliseconds of simulation instead of seconds of
XLA compile. This bench measures both sides on the tiny CI workload:

- **cold** — per-spec ``api.run`` (a fresh EngineCore and jit cache per
  call, exactly what an unserved client pays), wall clock per spec with
  the population prebuilt so only compile+run is on the clock;
- **warm** — a ``SimulationServer`` with the bucket pre-warmed, fired
  with a concurrent mix of specs that vary seeds/replicates (traced
  values and batch widths inside one bucket): p50/p99 time-to-first-day,
  request latency, and specs/sec.

``--check`` enforces the acceptance gate: zero steady-state recompiles
(server metrics, sentinel-backed) and warm p50 TTFD at least ``--min-
speedup`` (default 10x) better than the cold p50 per-spec wall.

    python benchmarks/bench_serve.py --tiny --out BENCH_serve.json --check
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

if __package__ in (None, ""):  # `python benchmarks/bench_serve.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs, float), p)) if xs else 0.0


def request_mix(base, n):
    """A deterministic concurrent-load mix: every request shares the
    bucket (same dataset/disease/interventions/backend) but varies the
    traced values (seed) and the batch width (replicates 1 vs 2 =>
    different padding amounts inside the same bucket)."""
    return [
        base.with_overrides(seed=i + 1, replicates=1 + (i % 2))
        for i in range(n)
    ]


def run(dataset="twin-2k", days=10, requests=12, concurrency=4,
        chunk_days=5, cold_runs=2, out=None, check=False, min_speedup=10.0):
    from benchmarks.common import calibrated_tau, emit
    from repro import api
    from repro.api.spec import ExperimentSpec
    from repro.serve import ServeConfig, SimulationServer

    base = ExperimentSpec(
        dataset=dataset, days=days, tau=calibrated_tau(dataset),
        interventions=("none", "school-closure"),
    )
    mix = request_mix(base, requests)

    # --- cold: what each spec costs without the serving tier -------------
    # Plain api.run(spec): the unserved client builds the dataset AND pays
    # a fresh EngineCore compile per call — exactly the path the server
    # amortizes (its bucket holds both the population and the executable).
    cold_walls = []
    for spec in mix[:cold_runs]:
        t0 = time.perf_counter()
        api.run(spec)
        cold_walls.append(time.perf_counter() - t0)
    cold_p50 = _pct(cold_walls, 50)
    emit("serve/cold_per_spec", cold_p50 * 1e6,
         f"runs={cold_runs};p50_s={cold_p50:.3f}")

    # --- warm: the served path -------------------------------------------
    # Lattice floor 2: under closed-loop load most dispatches carry one
    # request, so padding every B=2 request up to width 4 would double the
    # device work per dispatch for empty slots. The width-2 and width-4
    # buckets both stay resident (max_executables=2).
    server = SimulationServer(ServeConfig(
        chunk_days=chunk_days, b_lattice=(2, 4, 8), max_executables=2))
    warm_info = server.warm_up(base)
    # Reach steady state before the clock starts: one pilot request per
    # batch width in the mix warms the bucket's runner AND the jitted
    # observable-replay cache — the timed phase below must be pure serving.
    for spec in mix[:2]:
        server.run(spec)
    server.start()

    # Closed-loop load generator: each of `concurrency` workers keeps one
    # request in flight (submit -> result -> next), the standard shape for
    # latency benchmarks — an open-loop burst of N would measure backlog
    # queueing, not the serving path.
    tickets = [None] * len(mix)

    def client(worker: int):
        for i in range(worker, len(mix), concurrency):
            ticket = server.submit(mix[i])
            tickets[i] = ticket
            ticket.result(timeout=600)

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=concurrency) as pool:
        for f in [pool.submit(client, w) for w in range(concurrency)]:
            f.result()
    wall = time.perf_counter() - t0
    server.stop()
    results = [t.result(timeout=1) for t in tickets]

    ttfds = [t.ttfd_s for t in tickets if t.ttfd_s is not None]
    lats = [t.latency_s for t in tickets if t.latency_s is not None]
    metrics = server.metrics_dict()
    warm_p50 = _pct(ttfds, 50)
    speedup = cold_p50 / max(warm_p50, 1e-9)
    emit("serve/warm_ttfd", warm_p50 * 1e6,
         f"p99_s={_pct(ttfds, 99):.4f};specs_per_s={requests / wall:.2f};"
         f"speedup_vs_cold={speedup:.1f}x")

    result = {
        "bench": "serve",
        "dataset": dataset,
        "days": days,
        "chunk_days": chunk_days,
        "requests": requests,
        "concurrency": concurrency,
        "bucket": warm_info["bucket"],
        "warmup_compile_s": round(warm_info["compile_s"] or 0.0, 3),
        "cold": {
            "runs": cold_runs,
            "walls_s": [round(w, 4) for w in cold_walls],
            "p50_s": round(cold_p50, 4),
        },
        "warm": {
            "completed": sum(r is not None for r in results),
            "ttfd_p50_s": round(warm_p50, 5),
            "ttfd_p99_s": round(_pct(ttfds, 99), 5),
            "latency_p50_s": round(_pct(lats, 50), 5),
            "latency_p99_s": round(_pct(lats, 99), 5),
            "wall_s": round(wall, 4),
            "specs_per_s": round(requests / wall, 3),
        },
        "speedup_ttfd_p50": round(speedup, 2),
        "metrics": metrics,
    }
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    if check:
        ex = metrics["executables"]
        assert ex["recompile_violations"] == 0, \
            f"steady-state recompiles: {ex['recompile_violations']}"
        assert result["warm"]["completed"] == requests, \
            f"only {result['warm']['completed']}/{requests} completed"
        assert speedup >= min_speedup, (
            f"warm p50 TTFD {warm_p50:.4f}s is only {speedup:.1f}x better "
            f"than cold p50 {cold_p50:.3f}s (need >= {min_speedup}x)")
        print(f"# serve check OK: speedup={speedup:.1f}x, "
              f"0 recompile violations", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="twin-2k")
    ap.add_argument("--days", type=int, default=10)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--chunk-days", type=int, default=5)
    ap.add_argument("--cold-runs", type=int, default=2)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size: 12 requests, 10 days on the twin")
    ap.add_argument("--out", default=None, help="write BENCH_serve.json here")
    ap.add_argument("--check", action="store_true",
                    help="assert zero recompiles and the TTFD speedup gate")
    ap.add_argument("--min-speedup", type=float, default=10.0)
    args = ap.parse_args()
    if args.tiny:
        # concurrency 2 keeps the single CPU device just below saturation
        # — the gated p50 TTFD then measures the serving path, not pure
        # backlog queueing (which any one-device box saturates into).
        args.dataset, args.days, args.requests = "twin-2k", 10, 12
        args.chunk_days, args.concurrency = 2, 2
    r = run(args.dataset, args.days, args.requests, args.concurrency,
            args.chunk_days, args.cold_runs, args.out, args.check,
            args.min_speedup)
    print(json.dumps({k: v for k, v in r.items() if k != "metrics"}))


if __name__ == "__main__":
    main()
