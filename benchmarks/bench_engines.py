"""Engine-parity benchmark: the one unified scan, timed on every topology.

All five legacy layouts now execute the identical topology-parameterized
day loop (repro/engine); this bench pins the refactor's perf against the
per-engine numbers PR 3 tracked: per-topology wall clock, TEPS (traversed
edges per second, the paper's Table I metric), and the parity of the
trajectories it timed (a wrong-result fast engine is not a fast engine).

Emits ``BENCH_engines.json`` (uploaded as a CI artifact by the smoke-bench
job):

    python benchmarks/bench_engines.py --tiny --out BENCH_engines.json

Topologies needing more devices than visible (dist/sharded/hybrid run on
1-device meshes in --tiny mode) are still exercised through their real
shard_map programs — axis size 1, same code path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/bench_engines.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np


LAYOUTS = (
    # (engine label, EngineCore layout, kwargs)
    ("ensemble", "local", {}),
    ("sharded", "scenarios", {"scen_shards": None}),  # None = all devices
    ("hybrid", "hybrid", {"workers": 1, "scen_shards": None}),
    ("dist", "workers", {"workers": None}),  # B=1, all devices as workers
    ("single", "local", {}),  # B=1 local
)


def run(dataset="twin-2k", batch_size=4, days=10, backend="jnp", out=None):
    import jax

    from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
    from repro.configs import ScenarioBatch
    from repro.core import disease
    from repro.engine import EngineCore

    pop = get_pop(dataset)
    tau = calibrated_tau(dataset)
    ndev = len(jax.devices())
    batch = ScenarioBatch.from_product(
        disease=disease.covid_model(), tau=tau,
        seeds=list(range(1, batch_size + 1)),
    )
    one = ScenarioBatch.from_scenarios(batch[:1])

    results, ref_hist = [], None
    for label, layout, kw in LAYOUTS:
        kw = dict(kw)
        b = one if label in ("single", "dist") else batch
        if "workers" in kw and kw["workers"] is None:
            kw["workers"] = ndev
        if "scen_shards" in kw and kw["scen_shards"] is None:
            kw["scen_shards"] = max(1, min(ndev, len(b)))
        if layout == "hybrid":
            kw["scen_shards"] = max(1, min(ndev // kw["workers"], len(b)))
        core = EngineCore(pop, b, layout=layout, backend=backend, **kw)

        # Parity first: the trajectories this timing run produces.
        _, _, hist, _ = core.run_days(days)
        if label == "ensemble":
            ref_hist = hist
        if ref_hist is not None:
            Bb = hist["cumulative"].shape[1]
            np.testing.assert_array_equal(
                hist["cumulative"], ref_hist["cumulative"][:, :Bb],
                err_msg=f"{label}: trajectory diverged from ensemble")

        # "edges" is the telemetry stat (the in-kernel SMEM counter on the
        # pallas-compact backend, cnt.sum() elsewhere); "contacts" is always
        # the host-side fold. Equality cross-checks the measurement.
        edges = float(np.asarray(hist["edges"], np.int64).sum())
        host_edges = float(np.asarray(hist["contacts"], np.int64).sum())
        assert edges == host_edges, \
            f"{label}: edge telemetry {edges} != host count {host_edges}"
        t = time_fn(core.bench_fn(days), warmup=1, iters=3)
        teps = edges / t
        row = {
            "engine": label,
            "layout": layout,
            "topology": type(core.topo).__name__,
            "batch": len(b),
            "workers": core.workers,
            "scen_shards": core.scen_shards,
            "wall_s": round(t, 4),
            "interactions_total": edges,
            "edge_counter": ("in-kernel" if backend == "pallas-compact"
                             else "host"),
            "teps": round(teps, 1),
        }
        results.append(row)
        emit(f"engines/{label}", t / days * 1e6,
             f"teps={teps:.3g};topology={row['topology']};"
             f"mesh={core.workers}x{core.scen_shards}")

    # --- per-agent TTI phase: tracing-on vs the plain ensemble ------------
    # Same batch, one TestTraceIsolate slot per scenario: the interaction
    # pass carries the second (traced-contact) accumulator and the day
    # step runs the capacity-limited budget. TEPS versus the plain
    # ensemble row is the whole-engine cost of contact tracing.
    from repro.core import interventions as iv_lib

    tti_batch = ScenarioBatch.from_product(
        interventions={"tti": [iv_lib.TestTraceIsolate(
            "tti", tests_per_day=max(4, pop.num_people // 100))]},
        disease=disease.covid_model(), tau=tau,
        seeds=list(range(1, batch_size + 1)),
    )
    core = EngineCore(pop, tti_batch, layout="local", backend=backend)
    _, _, hist, _ = core.run_days(days)
    edges = float(np.asarray(hist["edges"], np.int64).sum())
    host_edges = float(np.asarray(hist["contacts"], np.int64).sum())
    assert edges == host_edges, \
        f"tti: edge telemetry {edges} != host count {host_edges}"
    t = time_fn(core.bench_fn(days), warmup=1, iters=3)
    plain = next(r for r in results if r["engine"] == "ensemble")
    tti_row = {
        "engine": "ensemble+tti",
        "layout": "local",
        "topology": type(core.topo).__name__,
        "batch": len(tti_batch),
        "workers": 1,
        "scen_shards": 1,
        "wall_s": round(t, 4),
        "interactions_total": edges,
        "edge_counter": ("in-kernel" if backend == "pallas-compact"
                         else "host"),
        "teps": round(edges / t, 1),
        "tests_used": int(np.asarray(hist["tests_used"]).sum()),
        "teps_vs_plain": round((edges / t) / max(plain["teps"], 1e-9), 3),
    }
    results.append(tti_row)
    emit("engines/ensemble+tti", t / days * 1e6,
         f"teps={tti_row['teps']:.3g};"
         f"vs_plain={tti_row['teps_vs_plain']:.3f};"
         f"tests_used={tti_row['tests_used']}")

    result = {
        "bench": "engines",
        "dataset": dataset,
        "batch": batch_size,
        "days": days,
        "backend": backend,
        "num_devices": ndev,
        "parity": "bitwise (asserted in-run vs ensemble layout)",
        "engines": results,
    }
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="twin-2k")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--days", type=int, default=10)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size: B=4, 10 days on the test twin")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.tiny:
        args.dataset, args.batch, args.days = "twin-2k", 4, 10
    r = run(args.dataset, args.batch, args.days, args.backend, args.out)
    print(json.dumps(r))


if __name__ == "__main__":
    main()
