"""Shared benchmark helpers: timing, CSV emission, dataset cache."""

from __future__ import annotations

import functools
import time

import numpy as np
import jax

_ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows() -> list[str]:
    return list(_ROWS)


def time_fn(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@functools.lru_cache(maxsize=8)
def get_pop(name: str):
    from repro.configs import get_epidemic

    return get_epidemic(name).build()


def day_step_fn(core):
    """A jitted single-day step over a B=1 EngineCore's own scenario —
    ``state -> (state', stats)`` — for per-day microbenchmarks."""
    from repro.core import simulator

    static, week, contact_prob, params = simulator.legacy_parts(core)
    return jax.jit(
        lambda st: simulator.day_step(static, week, contact_prob, params, st)
    )


def calibrated_tau(pop_name: str) -> float:
    """Transmissibilities tuned (offline) so the infectious peak lands mid-
    run (paper §VI: 'tuned so that the number of infectious people peaked
    about halfway through the simulations')."""
    return {
        "twin-2k": 2.0e-5,
        "md-mini": 8.0e-6,
        "va-mini": 8.0e-6,
        "ws-50k": 5.0e-6,
        "ws-200k": 4.0e-6,
        "grid-tiny": 8.0e-6,
        "grid-1x": 6.0e-6,
    }.get(pop_name, 8.0e-6)
