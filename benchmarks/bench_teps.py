"""Table I headline: measured TEPS (traversed edges per second) per backend.

The paper reports 4.6B TEPS for 200 days of the California digital twin on
512 nodes (PAPER.md); this bench produces the comparable figure for every
interaction backend on whatever hardware runs it, from *measured* traversed
edges — the per-day edge counters threaded through ``day_step`` — over the
wall clock of the whole compiled scan. On the ``pallas-compact`` backend the
edge count comes from the kernel's own SMEM accumulator; every run asserts
it equals the host-side fold (``contacts``), so the headline number is a
cross-checked measurement, not an estimate.

Also emits the v5e-projected kernel-roofline TEPS (VPU ops per candidate
pair x pairs per day) for context against the paper's scale.

CI runs the tiny gate (writes + checks ``BENCH_teps.json``):

    python benchmarks/bench_teps.py --tiny --out BENCH_teps.json \
        --check --tolerance 0.15

``--check`` compares against the committed baseline
(``benchmarks/baselines/BENCH_teps_baseline.json``): traversed-edge totals
must match *exactly* (they are deterministic), measured TEPS may not regress
more than ``--tolerance`` below baseline. ``--update-baseline`` rewrites the
baseline file from the current run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/bench_teps.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baselines", "BENCH_teps_baseline.json")

# Per candidate pair in the kernel tile: overlap (4 VPU ops), masks (~6),
# hash (2x fmix32 chain ~ 22 u32 ops), propensity (~4) => ~36 VPU ops.
OPS_PER_PAIR = 36.0
V5E_VPU_OPS = 197e12 / 2 / 128 * 8  # ~ f32 VPU throughput proxy (ops/s)


def run(dataset="md-mini", days=20,
        backends=("jnp", "compact", "pallas-compact"), out=None):
    from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
    from repro.core import disease, transmission
    from repro.engine.core import EngineCore

    pop = get_pop(dataset)
    rows = {}
    edges_ref = None
    for backend in backends:
        sim = EngineCore.single(
            pop, disease.covid_model(),
            transmission.TransmissionModel(tau=calibrated_tau(dataset)),
            seed=1, backend=backend,
        )
        # Warm-up run doubles as the edge measurement (identical re-run).
        _, hist = sim.run1(days)
        edges = int(np.asarray(hist["edges"], np.int64).sum())
        host_edges = int(np.asarray(hist["contacts"], np.int64).sum())
        # On pallas-compact "edges" is the kernel's SMEM accumulator; it
        # must equal the host-side fold exactly — else the telemetry lies.
        assert edges == host_edges, (
            f"{backend}: in-kernel edge counter {edges} != "
            f"host-side count {host_edges}")
        if edges_ref is None:
            edges_ref = edges
        else:
            assert edges == edges_ref, \
                f"{backend} traversed {edges} edges, expected {edges_ref}"
        t = time_fn(sim.bench_fn(days), warmup=0, iters=1)
        teps = edges / t
        rows[backend] = {
            "wall_s": round(t, 4),
            "edges_total": edges,
            "edge_counter": ("in-kernel" if backend == "pallas-compact"
                             else "host"),
            "teps": round(teps, 1),
        }
        emit(f"table1_teps/{backend}", t / days * 1e6,
             f"teps={teps:.3g};edges_total={edges:.3g};"
             f"counter={rows[backend]['edge_counter']}")

    # PR 7 gate: a per-agent intervention slot that is *disabled* (the TTI
    # layer statically compiled out) or *enabled with zero budget* (the
    # traced program with an identically-zero source channel) must not
    # perturb a single traversed edge relative to the plain run.
    from repro.core import interventions as iv_lib

    pa_variants = {
        "disabled_pa_slot": dict(iv_enabled=[False]),
        "zero_budget_pa_slot": dict(iv_enabled=[True]),
    }
    for label, en in pa_variants.items():
        budget = 0 if label == "zero_budget_pa_slot" else 50
        sim_pa = EngineCore.single(
            pop, disease.covid_model(),
            transmission.TransmissionModel(tau=calibrated_tau(dataset)),
            seed=1, backend=backends[0],
            interventions=[iv_lib.TestTraceIsolate(
                "tti", tests_per_day=budget)],
            **en,
        )
        _, hist_pa = sim_pa.run1(days)
        edges_pa = int(np.asarray(hist_pa["edges"], np.int64).sum())
        assert edges_pa == edges_ref, (
            f"{label}: traversed {edges_pa} edges, expected {edges_ref} — "
            "an inert per-agent intervention perturbed the trajectory")
        assert int(np.asarray(hist_pa["tests_used"]).sum()) == 0, label
        rows.setdefault("_pa_noop", {})[label] = edges_pa
        emit(f"table1_teps/{label}", 0.0, f"edges_total={edges_pa:.3g};ok")
    pa_noop = rows.pop("_pa_noop")

    # kernel-level v5e projection: candidate pairs per day from the block
    # schedule (post-packing); edges/candidates from the measured run.
    pairs_per_day = float(sim.week_data.row_idx.shape[1]) * sim.block_size**2
    proj_days_per_s = V5E_VPU_OPS / (pairs_per_day * OPS_PER_PAIR)
    proj_teps_chip = (edges_ref / days) * proj_days_per_s
    emit("table1_teps/v5e_projection_per_chip", 0.0,
         f"teps={proj_teps_chip:.3g};"
         f"x256_chips={proj_teps_chip*256:.3g};paper_512nodes=4.6e9")

    result = {
        "bench": "teps",
        "dataset": dataset,
        "days": days,
        "edges_total": edges_ref,
        "edges_total_pa_noop": pa_noop,
        "backends": rows,
        "v5e_projection_per_chip_teps": proj_teps_chip,
    }
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"# wrote {out}")
    return result


def check(result, baseline_path=BASELINE, tolerance=0.15) -> list[str]:
    """Regression gate vs the committed baseline. Returns failure strings
    (empty = pass). Edge totals are deterministic => exact; TEPS is wall-
    clock => bounded relative regression."""
    with open(baseline_path) as f:
        base = json.load(f)
    fails = []
    if (result["dataset"], result["days"]) != (base["dataset"], base["days"]):
        return [f"baseline is {base['dataset']}/{base['days']}d, "
                f"run is {result['dataset']}/{result['days']}d — not comparable"]
    if result["edges_total"] != base["edges_total"]:
        fails.append(f"edges_total {result['edges_total']} != baseline "
                     f"{base['edges_total']} (determinism broken)")
    for label, e in result.get("edges_total_pa_noop", {}).items():
        if e != result["edges_total"]:
            fails.append(
                f"{label}: edges_total {e} != plain run "
                f"{result['edges_total']} (an inert per-agent intervention "
                "slot must not perturb the traversed-edge count)")
    for be, b_row in base["backends"].items():
        row = result["backends"].get(be)
        if row is None:
            fails.append(f"backend '{be}' missing from run")
            continue
        floor = b_row["teps"] * (1.0 - tolerance)
        if row["teps"] < floor:
            fails.append(
                f"{be}: teps {row['teps']:.3g} < {floor:.3g} "
                f"(baseline {b_row['teps']:.3g} - {tolerance:.0%})")
    return fails


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="md-mini")
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--backends", default="jnp,compact,pallas-compact")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size: twin-2k, 10 days")
    ap.add_argument("--out", default=None, help="write BENCH_teps.json here")
    ap.add_argument("--check", action="store_true",
                    help="fail on TEPS regression vs the committed baseline")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.15)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the committed baseline from this run")
    args = ap.parse_args()
    if args.tiny:
        args.dataset, args.days = "twin-2k", 10
    print("name,us_per_call,derived")
    result = run(dataset=args.dataset, days=args.days,
                 backends=tuple(args.backends.split(",")), out=args.out)
    if args.update_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(result, f, indent=1)
        print(f"# baseline updated: {args.baseline}")
    if args.check:
        fails = check(result, args.baseline, args.tolerance)
        for msg in fails:
            print(f"FAIL {msg}")
        if fails:
            sys.exit(1)
        print(f"# TEPS gate passed (tolerance {args.tolerance:.0%})")


if __name__ == "__main__":
    main()
