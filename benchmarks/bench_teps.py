"""Table I context: interactions-per-second (TEPS) of this implementation
on CPU, plus the v5e-projected figure from the interaction kernel's
roofline (VPU ops per pair x pairs per tile), for comparison against the
paper's 1.4B TEPS on 576 Xeon cores."""

from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
from repro.core import disease, simulator, transmission


# Per candidate pair in the kernel tile: overlap (4 VPU ops), masks (~6),
# hash (2x fmix32 chain ~ 22 u32 ops), propensity (~4) => ~36 VPU ops.
OPS_PER_PAIR = 36.0
V5E_VPU_OPS = 197e12 / 2 / 128 * 8  # ~ f32 VPU throughput proxy (ops/s)


def run(dataset="md-mini", days=20, backends=("jnp", "compact")):
    pop = get_pop(dataset)
    edges = None
    for backend in backends:
        sim = simulator.EpidemicSimulator(
            pop, disease.covid_model(),
            transmission.TransmissionModel(tau=calibrated_tau(dataset)),
            seed=1, backend=backend,
        )
        state, hist = sim.run(days)
        t = time_fn(sim._core.bench_fn(days),
                    warmup=0, iters=1)
        e = float(np.asarray(hist["contacts"], np.float64).sum())
        if edges is None:
            edges = e
        else:
            assert e == edges, "backends must traverse identical edge sets"
        emit(f"table1_teps/cpu_{backend}", t / days * 1e6,
             f"teps={e/t:.3g};interactions_total={e:.3g}")
    # kernel-level v5e projection: candidate pairs per day from the block
    # schedule (post-packing); contacts/candidates from the measured run
    pairs_per_day = float(sim.week.row_idx.shape[1]) * sim.block_size**2
    proj_days_per_s = V5E_VPU_OPS / (pairs_per_day * OPS_PER_PAIR)
    proj_teps_chip = (edges / days) * proj_days_per_s
    emit("table1_teps/v5e_projection_per_chip", 0.0,
         f"teps={proj_teps_chip:.3g};"
         f"x256_chips={proj_teps_chip*256:.3g};paper_576cores=1.4e9")
