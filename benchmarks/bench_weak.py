"""Fig 8 analog: weak scaling — fixed per-worker load (Table III ratios
1x/2x/4x, scaled 1/10 for the single CPU core). Flat time-per-day per unit
load = good weak scaling."""

from __future__ import annotations

from benchmarks.common import emit, time_fn
from repro.core import disease, transmission
from repro.engine.core import EngineCore
from repro.data import grid_population


def run(days=14):
    base = None
    for mult, (w, h) in (("1x", (60, 60)), ("2x", (85, 85)), ("4x", (120, 120))):
        pop = grid_population(w, h, density=4.0, seed=0, name=f"grid-{mult}")
        sim = EngineCore.single(
            pop, disease.covid_model(),
            transmission.TransmissionModel(tau=8e-6), seed=1,
        )
        t = time_fn(sim.bench_fn(days),
                    warmup=0, iters=1)
        per_day = t / days
        per_load = per_day / (pop.visits_per_week / 7)
        if base is None:
            base = per_load
        emit(
            f"fig8_weak/{mult}", per_day * 1e6,
            f"people={pop.num_people};per_visit_us={per_load*1e6:.3f};"
            f"efficiency={base/per_load:.3f}",
        )
