"""Fig 9 reproduction: 30-replicate validation — Loimos's dynamic contact
network vs the EpiHiper-style static network, same SIR disease, same
visit schedule. Reports: mean cumulative infections of persistent
outbreaks, die-out counts, and trajectory spread (the paper finds dynamic
networks cluster more tightly — the die-average smoothing argument)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_pop
from repro.core import disease, transmission
from repro.engine.core import EngineCore


def run(dataset="twin-2k", replicates=30, days=120, tau=1.2e-5,
        dieout_threshold=100):
    pop = get_pop(dataset)
    results = {}
    for mode, static in (("loimos_dynamic", False), ("epihiper_static", True)):
        finals, persistent, dieouts, peak_days = [], [], 0, []
        for rep in range(replicates):
            sim = EngineCore.single(
                pop, disease.sir_model(), transmission.TransmissionModel(tau=tau),
                seed=1000 + rep, static_network=static,
                seed_per_day=2, seed_days=5,
            )
            _, hist = sim.run1(days)
            total = int(hist["cumulative"][-1])
            finals.append(total)
            if total < dieout_threshold:
                dieouts += 1
            else:
                persistent.append(total)
                peak_days.append(int(np.argmax(hist["infectious"])))
        mean_persist = float(np.mean(persistent)) if persistent else 0.0
        spread = float(np.std(peak_days)) if peak_days else 0.0
        emit(
            f"fig9_validation/{mode}", 0.0,
            f"replicates={replicates};mean_cumulative={mean_persist:.0f};"
            f"dieouts={dieouts};peak_day_std={spread:.2f}",
        )
        results[mode] = (mean_persist, dieouts, spread)
    dyn, sta = results["loimos_dynamic"], results["epihiper_static"]
    rel = abs(dyn[0] - sta[0]) / max(sta[0], 1)
    emit("fig9_validation/agreement", 0.0,
         f"relative_mean_diff={rel:.3f};"
         f"dynamic_tighter_peaks={dyn[2] <= sta[2]}")
