"""Roofline table emission: reads artifacts/dryrun/*.json (produced by
launch/dryrun.py) and prints the per-cell three-term roofline rows —
the §Roofline source of truth for EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(pattern="*_16x16.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(os.path.abspath(ART), pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    cells = load_cells()
    if not cells:
        emit("roofline/none", 0.0, "run launch/dryrun.py first")
        return
    for r in cells:
        tag = f"{r.get('arch')}/{r.get('shape')}"
        if "skipped" in r:
            emit(f"roofline/{tag}", 0.0, f"skipped:{r['skipped']}")
            continue
        if "error" in r:
            emit(f"roofline/{tag}", 0.0, "ERROR")
            continue
        rf = r.get("roofline", {})
        if not rf:
            emit(f"roofline/{tag}", 0.0, "quick-mode (no correction pass)")
            continue
        emit(
            f"roofline/{tag}",
            max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"]) * 1e6,
            f"bottleneck={rf['bottleneck']};"
            f"tc={rf['t_compute_s']:.4f};tm={rf['t_memory_s']:.4f};"
            f"tcoll={rf['t_collective_s']:.4f};"
            f"useful={rf['useful_flops_fraction']:.3f};"
            f"frac={rf['roofline_fraction']:.4f}",
        )
