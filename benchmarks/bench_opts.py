"""Figs 2/3/5 analog: the paper's three optimizations, measured.

1. **Static load balancing (Fig 2/5)** — partition-imbalance (max/mean
   load) of naive vs geo-sorted balanced partitions, and the simulated
   slowest-worker time they imply (the quantity that sets SPMD step time).
2. **Message aggregation (Fig 3/5)** — bucketed-exchange payload vs
   per-visit messaging: bytes moved and message counts for the visit
   exchange (the aggregation win the Charm++ TRAM utility provides).
3. **Short-circuit evaluation (Figs 4/5)** — wall-clock of the interaction
   pass across backends at low/high infectious fractions: no-skip (jnp),
   cond-per-tile (scan), and the active-set engine (compact) whose work is
   proportional to the *live* tile count. Also reports the live-tile
   fraction per phase, the schedule-NP effect of occupancy-aware visit
   packing, and compact-backend TEPS — emitted as ``BENCH_interactions.json``
   when ``--out`` is given (CI uploads the ``--tiny`` run as an artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/bench_opts.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import calibrated_tau, day_step_fn, emit, get_pop, time_fn
from repro.core import disease, population as pop_lib, simulator_dist, transmission
from repro.engine.core import EngineCore


def live_tile_fraction(sim, state) -> float:
    """Fraction of scheduled tiles live today (pair_active ∧ col-has-inf ∧
    row-has-sus), recomputed on host from the simulator's week data.
    Ignores interventions (none in this bench)."""
    wk = sim.week_data
    params = sim.scenario_params(0)
    dow = int(np.asarray(state.day)) % pop_lib.DAYS_PER_WEEK
    pid = np.asarray(wk.pid)[dow]
    health = np.asarray(state.health)
    p_sus = np.asarray(params.sus_table)[health] * np.asarray(params.beta_sus)
    p_inf = np.asarray(params.inf_table)[health] * np.asarray(params.beta_inf)
    safe = np.maximum(pid, 0)
    act = pid >= 0
    nb, b = wk.num_blocks, wk.block_size
    col = ((p_inf[safe] * act) > 0).reshape(nb, b).any(axis=1)
    row = ((p_sus[safe] * act) > 0).reshape(nb, b).any(axis=1)
    ri = np.asarray(wk.row_idx)[dow]
    ci = np.asarray(wk.col_idx)[dow]
    pa = np.asarray(wk.pair_active)[dow]
    live = (pa == 1) & col[ci] & row[ri]
    return float(live.sum() / max(len(pa), 1))


def run(dataset="md-mini", workers=16, days_warm=10, out=None):
    pop = get_pop(dataset)
    result = {"dataset": dataset, "phases": {}, "trajectory_match": True}

    # --- 1. static load balancing ---------------------------------------
    visits = np.zeros(pop.num_locations, np.int64)
    for d in pop.week:
        np.add.at(visits, d.loc[: d.num_real], 1)
    naive = pop_lib.naive_location_partition(pop.num_locations, workers)
    bal = pop_lib.balanced_location_partition(pop.geo_key, visits, workers)
    imb_n = pop_lib.partition_imbalance(naive, visits, workers)
    imb_b = pop_lib.partition_imbalance(bal, visits, workers)
    emit("fig5_static_lb/naive", 0.0, f"imbalance={imb_n:.3f}")
    emit("fig5_static_lb/balanced", 0.0,
         f"imbalance={imb_b:.3f};speedup_bound={imb_n/imb_b:.2f}x")

    # --- 2. message aggregation ------------------------------------------
    plan = simulator_dist.build_dist_plan(pop, workers)
    per_visit_msgs = int(sum(d.num_real for d in pop.week) / 7)
    bucketed_msgs = workers * workers  # one aggregated buffer per pair
    payload = plan.send_idx[0].size * 4 * 3  # 3 channels
    emit("fig5_aggregation/per_visit", 0.0,
         f"messages_per_day={per_visit_msgs}")
    emit("fig5_aggregation/bucketed", 0.0,
         f"messages_per_day={bucketed_msgs};"
         f"reduction={per_visit_msgs/max(bucketed_msgs,1):.0f}x;"
         f"bytes_per_worker={payload}")

    # --- occupancy-aware visit packing (schedule NP before/after) --------
    packing = pop_lib.week_packing_stats(pop, block_size=128)
    result["packing"] = packing
    emit("fig5_visit_packing/np", 0.0,
         f"np_before={packing['np_before']};np_after={packing['np_after']};"
         f"reduction={packing['np_reduction']:.2f}x")

    # --- 3. short-circuit evaluation --------------------------------------
    tau = calibrated_tau(dataset)
    backends = ("jnp", "scan", "compact")
    # (label, seed_per_day, seed_days, days to advance before timing):
    # low_prevalence is the paper's §V-D motivating regime — a handful of
    # infectious people, so nearly every tile is dead; peak_prevalence is
    # the stress case where the short-circuit cannot help much.
    phases = (
        ("low_prevalence", 2, 10, 3),
        ("peak_prevalence", 200, 7, days_warm),
    )
    for label, seed_per_day, seed_days, warm in phases:
        sims, states, hists = {}, {}, {}
        for backend in backends:
            sim = EngineCore.single(
                pop, disease.covid_model(), transmission.TransmissionModel(tau=tau),
                seed=2, backend=backend, seed_days=seed_days,
                seed_per_day=seed_per_day,
            )
            # advance to a comparable epidemic phase
            st, hist = sim.run1(warm)
            sims[backend], states[backend], hists[backend] = sim, st, hist
        # Acceptance: identical infection trajectories across backends.
        for backend in backends[1:]:
            if not np.array_equal(hists[backend]["cumulative"],
                                  hists["jnp"]["cumulative"]):
                result["trajectory_match"] = False
        steps = {backend: day_step_fn(sims[backend]) for backend in backends}
        times = {
            backend: time_fn(
                lambda be=backend: steps[be](states[be])[0].day,
                iters=3,
            )
            for backend in backends
        }
        frac = live_tile_fraction(sims["jnp"], states["jnp"])
        emit(f"fig5_short_circuit/{label}/no_skip", times["jnp"] * 1e6, "")
        emit(f"fig5_short_circuit/{label}/skip", times["scan"] * 1e6,
             f"speedup={times['jnp']/max(times['scan'],1e-9):.2f}x")
        emit(f"fig5_short_circuit/{label}/compact", times["compact"] * 1e6,
             f"speedup={times['jnp']/max(times['compact'],1e-9):.2f}x;"
             f"live_tile_fraction={frac:.4f}")
        contacts_per_day = float(
            np.asarray(hists["compact"]["contacts"], np.float64)[-3:].mean()
        )
        result["phases"][label] = {
            "jnp_us": times["jnp"] * 1e6,
            "scan_us": times["scan"] * 1e6,
            "compact_us": times["compact"] * 1e6,
            "speedup_compact_vs_jnp": times["jnp"] / max(times["compact"], 1e-9),
            "live_tile_fraction": frac,
            "compact_teps": contacts_per_day / max(times["compact"], 1e-9),
        }

    # --- 4. per-agent TTI: the second kernel accumulator's cost -----------
    # Tracing-on compiles one extra accumulator into the interaction pass
    # (same tiles, same order); this phase measures what that costs in TEPS
    # against the identical run with the TTI layer compiled out.
    from repro.core import interventions as iv_lib

    tti_days = 10
    budget = max(4, pop.num_people // 100)
    tti = {}
    for label, ivs in (
        ("tracing_off", []),
        ("tracing_on", [iv_lib.TestTraceIsolate(
            "tti", tests_per_day=budget)]),
    ):
        sim = EngineCore.single(
            pop, disease.covid_model(),
            transmission.TransmissionModel(tau=tau),
            seed=2, backend="compact", seed_per_day=200,
            interventions=ivs,
        )
        t = time_fn(sim.bench_fn(tti_days), iters=3)
        _, hist = sim.run1(tti_days)
        edges = float(np.asarray(hist["edges"], np.float64).sum())
        tti[label] = {
            "wall_s": t,
            "edges_total": edges,
            "teps": edges / max(t, 1e-9),
            "tests_used": int(np.asarray(hist["tests_used"]).sum()),
        }
        emit(f"fig5_tti/{label}", t / tti_days * 1e6,
             f"teps={tti[label]['teps']:.3g};"
             f"tests_used={tti[label]['tests_used']}")
    tti["teps_ratio_on_vs_off"] = (
        tti["tracing_on"]["teps"] / max(tti["tracing_off"]["teps"], 1e-9)
    )
    emit("fig5_tti/teps_ratio", 0.0,
         f"tracing_on/off={tti['teps_ratio_on_vs_off']:.3f}")
    result["tti"] = tti

    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"# wrote {out}")
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="md-mini")
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size: twin-2k, 4 workers")
    ap.add_argument("--out", default=None,
                    help="write BENCH_interactions.json here")
    args = ap.parse_args()
    if args.tiny:
        args.dataset, args.workers = "twin-2k", 4
    print("name,us_per_call,derived")
    run(dataset=args.dataset, workers=args.workers, out=args.out)


if __name__ == "__main__":
    main()
