"""Figs 2/3/5 analog: the paper's three optimizations, measured.

1. **Static load balancing (Fig 2/5)** — partition-imbalance (max/mean
   load) of naive vs geo-sorted balanced partitions, and the simulated
   slowest-worker time they imply (the quantity that sets SPMD step time).
2. **Message aggregation (Fig 3/5)** — bucketed-exchange payload vs
   per-visit messaging: bytes moved and message counts for the visit
   exchange (the aggregation win the Charm++ TRAM utility provides).
3. **Short-circuit evaluation (Figs 4/5)** — wall-clock of the interaction
   pass with runtime block-skip (scan+cond backend) vs no-skip (vmap
   backend) at low/high infectious fractions.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
from repro.core import disease, population as pop_lib, simulator, simulator_dist, transmission


def run(dataset="md-mini", workers=16):
    pop = get_pop(dataset)

    # --- 1. static load balancing ---------------------------------------
    visits = np.zeros(pop.num_locations, np.int64)
    for d in pop.week:
        np.add.at(visits, d.loc[: d.num_real], 1)
    naive = pop_lib.naive_location_partition(pop.num_locations, workers)
    bal = pop_lib.balanced_location_partition(pop.geo_key, visits, workers)
    imb_n = pop_lib.partition_imbalance(naive, visits, workers)
    imb_b = pop_lib.partition_imbalance(bal, visits, workers)
    emit("fig5_static_lb/naive", 0.0, f"imbalance={imb_n:.3f}")
    emit("fig5_static_lb/balanced", 0.0,
         f"imbalance={imb_b:.3f};speedup_bound={imb_n/imb_b:.2f}x")

    # --- 2. message aggregation ------------------------------------------
    plan = simulator_dist.build_dist_plan(pop, workers)
    per_visit_msgs = int(sum(d.num_real for d in pop.week) / 7)
    bucketed_msgs = workers * workers  # one aggregated buffer per pair
    payload = plan.send_idx[0].size * 4 * 3  # 3 channels
    emit("fig5_aggregation/per_visit", 0.0,
         f"messages_per_day={per_visit_msgs}")
    emit("fig5_aggregation/bucketed", 0.0,
         f"messages_per_day={bucketed_msgs};"
         f"reduction={per_visit_msgs/max(bucketed_msgs,1):.0f}x;"
         f"bytes_per_worker={payload}")

    # --- 3. short-circuit evaluation --------------------------------------
    tau = calibrated_tau(dataset)
    for label, seed_days in (("early_low_infectious", 1), ("high_infectious", 7)):
        sim_skip = simulator.EpidemicSimulator(
            pop, disease.covid_model(), transmission.TransmissionModel(tau=tau),
            seed=2, backend="scan", seed_days=seed_days, seed_per_day=200,
        )
        sim_noskip = simulator.EpidemicSimulator(
            pop, disease.covid_model(), transmission.TransmissionModel(tau=tau),
            seed=2, backend="jnp", seed_days=seed_days, seed_per_day=200,
        )
        # advance both to a comparable epidemic phase
        st_a, _ = sim_skip.run(10)
        st_b, _ = sim_noskip.run(10)
        t_skip = time_fn(lambda: sim_skip._day_step(st_a)[0].day, iters=3)
        t_nos = time_fn(lambda: sim_noskip._day_step(st_b)[0].day, iters=3)
        emit(f"fig5_short_circuit/{label}/skip", t_skip * 1e6, "")
        emit(f"fig5_short_circuit/{label}/no_skip", t_nos * 1e6,
             f"speedup={t_nos/max(t_skip,1e-9):.2f}x")
