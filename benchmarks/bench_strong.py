"""Fig 6 analog: per-day execution time and TEPS across datasets.

The paper's strong-scaling axis (node count) is replaced on this 1-core
host by the dataset axis at fixed resources + the dry-run roofline for the
scale-out story; per-day time and traversed-edges-per-second (TEPS) are
the same metrics as Fig 6 (TEPS counts person-person interaction edges,
as in the paper)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import calibrated_tau, emit, get_pop, time_fn
from repro.core import disease, transmission
from repro.engine.core import EngineCore


def run(datasets=("twin-2k", "md-mini", "ws-50k"), days=30):
    for name in datasets:
        pop = get_pop(name)
        sim = EngineCore.single(
            pop, disease.covid_model(),
            transmission.TransmissionModel(tau=calibrated_tau(name)), seed=1,
        )
        # warm the epidemic so interaction load is representative
        state, hist = sim.run1(days)
        t = time_fn(sim.bench_fn(days),
                    warmup=0, iters=1)
        per_day = t / days
        edges = float(np.asarray(hist["contacts"], np.float64).sum())
        teps = edges / t if t > 0 else 0.0
        emit(
            f"fig6_strong/{name}", per_day * 1e6,
            f"people={pop.num_people};visits_wk={pop.visits_per_week};"
            f"interactions={edges:.3g};teps={teps:.3g}",
        )
