"""detlint engine: file walking, import resolution, pragmas, baselines.

detlint is the repo's determinism static-analysis pass. The bitwise
contract (one global seed => identical results on every mesh shape,
PAPER.md §VI strengthened to bitwise identity by the counter RNG) rests
on a handful of coding invariants that used to live only in reviewers'
heads; each rule in :mod:`repro.analysis.lint.rules` encodes one of them
as a named, suppressible check. This module owns everything around the
rules:

  * **ModuleContext** — one parsed file: AST, source lines, an
    import-alias map that resolves ``jnp.zeros`` -> ``jax.numpy.zeros``,
    and the RNG stream registry scraped from ``core/rng.py``.
  * **pragmas** — ``# detlint: ignore[DET001]`` on the flagged line (or
    on a comment-only line directly above it) suppresses a finding;
    ``# detlint: skip-file`` skips the module.
  * **baseline** — a committed JSON multiset of finding keys; findings
    present in the baseline are reported as suppressed, anything new
    fails the run. Keys are line-number-free (rule + path + message), so
    unrelated edits do not churn the baseline.
  * **run_lint / render** — the driver the CLI and the tests share.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from collections import Counter
from typing import Iterable, Optional, Sequence

PRAGMA_RE = re.compile(r"detlint:\s*ignore\[([A-Za-z0-9*,\s]+)\]")
SKIP_FILE_RE = re.compile(r"detlint:\s*skip-file")

#: Directories never linted (golden-bad corpora live in lint_corpus).
DEFAULT_EXCLUDES = ("__pycache__", "lint_corpus", ".git")

#: Module suffix treated as the RNG stream registry (DET001's sanctioned
#: home for raw randomness, DET002's source of declared stream ids).
RNG_MODULE_SUFFIX = "core/rng.py"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str  # "DET003"
    path: str  # posix path as given to the linter
    line: int  # 1-based
    col: int  # 0-based
    message: str

    def key(self) -> str:
        """Baseline key: stable under line renumbering."""
        return f"{self.rule}::{self.path}::{self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LintConfig:
    """Knobs shared by the CLI and the test harness."""

    select: Optional[Sequence[str]] = None  # rule codes to run; None = all
    excludes: Sequence[str] = DEFAULT_EXCLUDES
    rng_module_suffix: str = RNG_MODULE_SUFFIX
    #: Explicit stream registry {NAME: value}; None = scrape it from any
    #: scanned file matching ``rng_module_suffix``.
    streams: Optional[dict] = None
    #: Per-directory rule relaxation: ``(path_prefix, rule_codes)`` pairs.
    #: A finding whose (posix) path starts with a prefix and whose rule is
    #: in that prefix's codes is dropped entirely — unlike pragmas it
    #: never appears as suppressed. This is how tests/ gets linted with a
    #: different posture than src/ (e.g. DET001 off: tests draw raw
    #: numpy randomness to *build fixtures*, which is not simulation
    #: state).
    relax: Sequence[tuple] = ()

    def relaxed(self, path: str, rule: str) -> bool:
        p = path.replace(os.sep, "/")
        for prefix, codes in self.relax:
            if p.startswith(prefix.replace(os.sep, "/").rstrip("/")):
                if "*" in codes or rule in codes:
                    return True
        return False


class ImportMap:
    """Alias -> canonical dotted module map for one module.

    ``import jax.numpy as jnp`` binds jnp -> jax.numpy;
    ``from repro.core import rng`` binds rng -> repro.core.rng;
    ``from jax.experimental.pallas import tpu as pltpu`` binds
    pltpu -> jax.experimental.pallas.tpu. Plain ``import jax.numpy``
    binds the top name (jax -> jax), which dotted resolution completes.
    """

    def __init__(self, tree: ast.AST):
        self.aliases: dict = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.aliases[a.asname] = a.name
                    else:
                        top = a.name.split(".")[0]
                        self.aliases[top] = top
            elif isinstance(node, ast.ImportFrom):
                if node.level or not node.module:
                    continue  # relative imports stay unresolved
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name for a Name/Attribute chain, or None if
        the chain roots in a local variable rather than an import."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id)
        if base is None:
            return None
        return ".".join([base] + parts[::-1])


def dotted(node: ast.AST) -> Optional[str]:
    """Syntactic dotted form ("topo.psum") regardless of imports."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    return ".".join([node.id] + parts[::-1])


def parse_stream_registry(tree: ast.AST) -> dict:
    """Module-level ``NAME = np.uint32(<int>)`` assignments -> {NAME: int}.

    This is the declared-streams registry in ``core/rng.py``; DET002
    cross-checks every draw call site against it and flags duplicate
    values (a reused stream id silently correlates two decisions).
    """
    streams: dict = {}
    for node in tree.body if isinstance(tree, ast.Module) else []:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Name) and tgt.id.isupper()):
            continue
        v = node.value
        if (
            isinstance(v, ast.Call)
            and isinstance(v.func, ast.Attribute)
            and v.func.attr in ("uint32", "int32", "uint64")
            and v.args
            and isinstance(v.args[0], ast.Constant)
            and isinstance(v.args[0].value, int)
        ):
            # Private mixing constants (underscore names) are not streams.
            if not tgt.id.startswith("_"):
                streams[tgt.id] = v.args[0].value
    return streams


class ModuleContext:
    """Everything a rule needs about one parsed source file."""

    def __init__(self, path: str, source: str, config: LintConfig,
                 streams: Optional[dict] = None):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.imports = ImportMap(self.tree)
        self.config = config
        self.streams = streams if streams is not None else {}
        self.skip_file = False
        self._pragmas: dict = {}  # line -> set of rule codes (or {"*"})
        self._scan_pragmas()

    def _scan_pragmas(self):
        comment_only: dict = {}  # line -> codes, for "applies to next line"
        try:
            toks = list(tokenize.generate_tokens(
                io.StringIO(self.source).readline))
        except tokenize.TokenError:  # pragma: no cover - malformed tail
            toks = []
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            if SKIP_FILE_RE.search(tok.string):
                self.skip_file = True
            m = PRAGMA_RE.search(tok.string)
            if not m:
                continue
            codes = {c.strip().upper() for c in m.group(1).split(",")
                     if c.strip()}
            line = tok.start[0]
            stripped = self.lines[line - 1].strip() if line <= len(self.lines) else ""
            if stripped.startswith("#"):
                comment_only[line] = codes
            else:
                self._pragmas.setdefault(line, set()).update(codes)
        # A pragma on its own comment line covers the next source line,
        # skipping blank lines and continuation comment lines (so a
        # multi-line justification between the pragma and the code works).
        for line, codes in comment_only.items():
            nxt = line + 1
            while nxt <= len(self.lines):
                stripped = self.lines[nxt - 1].strip()
                if not stripped or stripped.startswith("#"):
                    nxt += 1
                else:
                    break
            self._pragmas.setdefault(nxt, set()).update(codes)

    def suppressed(self, rule: str, line: int) -> bool:
        codes = self._pragmas.get(line, ())
        return "*" in codes or rule in codes

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, path=self.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


# ---------------------------------------------------------------------------
# shared semantic helpers (used by DET003/DET004)
# ---------------------------------------------------------------------------

_BOOL_DTYPE_NAMES = {"bool", "bool_"}


def _is_bool_dtype_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Name) and node.id in _BOOL_DTYPE_NAMES:
        return True
    if isinstance(node, ast.Attribute) and node.attr in _BOOL_DTYPE_NAMES:
        return True
    return False


def local_assignments(fn: ast.AST) -> dict:
    """name -> [assigned value exprs] for single-Name targets anywhere in
    ``fn``'s subtree (closures included). Cross-scope name collisions are
    harmless for :func:`is_boolish`: a name is only classified boolean if
    *every* visible assignment is."""
    env: dict = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            env.setdefault(node.targets[0].id, []).append(node.value)
    return env


def is_boolish(node: ast.AST, env: dict, _stack: frozenset = frozenset()) -> bool:
    """Conservative "this expression is a boolean mask" classifier.

    True for comparisons, ``&``/``|``/``^`` chains with a boolish side,
    ``~``/``not``, ``.astype(bool)``, bool-dtype ``jnp.zeros/ones``, and
    names whose every visible assignment is boolish. A bool mask's
    ``.sum()`` is bounded by the shard width, so an int32 psum of it
    cannot overflow — DET004 exempts exactly these.
    """
    if isinstance(node, ast.Compare):
        return True
    if isinstance(node, ast.BoolOp):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.Invert, ast.Not)):
        return is_boolish(node.operand, env, _stack)
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitAnd, ast.BitOr, ast.BitXor)):
        return (is_boolish(node.left, env, _stack)
                or is_boolish(node.right, env, _stack))
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "astype" and node.args:
            return _is_bool_dtype_expr(node.args[0])
        if isinstance(f, ast.Attribute) and f.attr in ("zeros", "ones", "full"):
            dt = None
            for kw in node.keywords:
                if kw.arg == "dtype":
                    dt = kw.value
            pos = 2 if f.attr == "full" else 1
            if dt is None and len(node.args) > pos:
                dt = node.args[pos]
            return dt is not None and _is_bool_dtype_expr(dt)
        if isinstance(f, ast.Attribute) and f.attr in ("logical_and",
                                                       "logical_or",
                                                       "logical_not",
                                                       "isnan", "isinf",
                                                       "isfinite"):
            return True
    if isinstance(node, ast.Name):
        if node.id in _stack:
            return False  # self-reference inside an |/& chain: let the
            # other operand decide
        vals = env.get(node.id)
        if vals:
            sub = _stack | {node.id}
            return all(is_boolish(v, env, sub) for v in vals)
    return False


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def load_baseline(path: Optional[str]) -> Counter:
    """Baseline JSON -> multiset of finding keys. Missing/None = empty."""
    if not path or not os.path.exists(path):
        return Counter()
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "suppress" not in data:
        raise ValueError(f"{path}: not a detlint baseline "
                         "(expected {'version': 1, 'suppress': {...}})")
    return Counter({k: int(v) for k, v in data["suppress"].items()})


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    counts = Counter(f.key() for f in findings)
    with open(path, "w") as f:
        json.dump({"version": 1, "suppress": dict(sorted(counts.items()))},
                  f, indent=2, sort_keys=True)
        f.write("\n")


def apply_baseline(findings: Sequence[Finding], baseline: Counter):
    """Split findings into (new, suppressed) against the baseline multiset."""
    budget = Counter(baseline)
    new, suppressed = [], []
    for f in findings:
        if budget[f.key()] > 0:
            budget[f.key()] -= 1
            suppressed.append(f)
        else:
            new.append(f)
    return new, suppressed


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def iter_python_files(paths: Sequence[str], excludes: Sequence[str]):
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs
                if d not in excludes and not d.startswith(".")
            )
            for name in sorted(files):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def run_lint(paths: Sequence[str], config: Optional[LintConfig] = None):
    """Lint ``paths`` (files or directories). Returns (findings, errors):
    findings sorted by (path, line, rule), errors a list of
    ``path: reason`` strings for unparseable files."""
    from repro.analysis.lint.rules import all_rules

    config = config or LintConfig()
    rules = [r for r in all_rules()
             if config.select is None or r.code in config.select]

    files = list(iter_python_files(paths, tuple(config.excludes)))
    sources: dict = {}
    errors: list = []
    for path in files:
        try:
            with open(path, encoding="utf-8") as f:
                sources[path] = f.read()
        except OSError as e:
            errors.append(f"{path}: {e}")

    # Pass 1: locate the stream registry among the scanned files (unless
    # the config supplies one) so DET002 can cross-check call sites.
    streams = config.streams
    registry_paths = [
        p for p in sources
        if p.replace(os.sep, "/").endswith(config.rng_module_suffix)
    ]
    if streams is None:
        streams = {}
        for p in registry_paths:
            try:
                streams.update(parse_stream_registry(ast.parse(sources[p])))
            except SyntaxError:
                pass

    findings: list = []
    for path in files:
        if path not in sources:
            continue
        try:
            ctx = ModuleContext(path.replace(os.sep, "/"), sources[path],
                                config, streams=streams)
        except SyntaxError as e:
            errors.append(f"{path}: syntax error: {e}")
            continue
        ctx.is_rng_module = path in registry_paths
        if ctx.skip_file:
            continue
        for rule in rules:
            for f in rule.check(ctx):
                if ctx.suppressed(f.rule, f.line):
                    continue
                if config.relaxed(f.path, f.rule):
                    continue
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, errors


def render_console(new: Sequence[Finding], suppressed: Sequence[Finding],
                   errors: Sequence[str]) -> str:
    out = []
    for f in new:
        out.append(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}")
    for e in errors:
        out.append(f"error: {e}")
    by_rule = Counter(f.rule for f in new)
    summary = ", ".join(f"{r}×{n}" for r, n in sorted(by_rule.items()))
    tail = f"detlint: {len(new)} finding(s)"
    if summary:
        tail += f" ({summary})"
    if suppressed:
        tail += f", {len(suppressed)} baseline-suppressed"
    out.append(tail)
    return "\n".join(out)


def render_json(new: Sequence[Finding], suppressed: Sequence[Finding],
                errors: Sequence[str]) -> dict:
    """The machine-readable report (schema pinned by tests)."""
    return {
        "version": 1,
        "tool": "detlint",
        "findings": [f.to_dict() for f in new],
        "suppressed": [f.to_dict() for f in suppressed],
        "errors": list(errors),
        "counts": dict(Counter(f.rule for f in new)),
        "exit_code": 1 if (new or errors) else 0,
    }
