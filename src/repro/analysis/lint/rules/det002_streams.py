"""DET002: undeclared or reused counter-RNG stream ids.

Every random decision owns a declared stream constant in ``core/rng.py``
(CONTACT, INFECT, ...). Two decisions sharing one id silently correlate
their draws; an ad-hoc literal id is invisible to the registry and can
collide with a future stream. The rule (a) flags duplicate values inside
the registry itself and (b) checks that every draw call site passes a
declared constant in the stream slot.
"""

from __future__ import annotations

import ast
from collections import defaultdict

from repro.analysis.lint.engine import parse_stream_registry

#: draw function -> index of the stream argument (first of ``*words``).
_DRAW_STREAM_ARG = {
    "uniform": 1,
    "np_uniform": 1,
    "hash_u32": 1,
    "exponential": 2,  # (mean, seed, stream, ...)
    "categorical": 2,  # (cum_probs, seed, stream, ...)
}

_RNG_MODULE = "repro.core.rng"


def _unwrap_int(node: ast.AST) -> ast.AST:
    """``int(rng.X)`` (the numpy-mirror idiom) -> ``rng.X``."""
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "int" and len(node.args) == 1):
        return node.args[0]
    return node


class StreamRegistryRule:
    code = "DET002"
    description = ("undeclared or reused RNG stream ids (call sites must "
                   "pass a constant declared in core/rng.py)")

    def check(self, ctx):
        # (a) the registry itself: one id per stream.
        if getattr(ctx, "is_rng_module", False) or ctx.path.endswith(
                ctx.config.rng_module_suffix):
            streams = parse_stream_registry(ctx.tree)
            by_value = defaultdict(list)
            for name, value in streams.items():
                by_value[value].append(name)
            for value, names in sorted(by_value.items()):
                if len(names) > 1:
                    yield ctx.finding(
                        self.code, ctx.tree,
                        f"stream id {value:#x} reused by "
                        f"{', '.join(sorted(names))}: every random decision "
                        "needs its own stream",
                    )
            return

        # (b) call sites: the stream slot must be a declared constant.
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.imports.resolve(node.func)
            if not name or not name.startswith(_RNG_MODULE + "."):
                continue
            fn = name[len(_RNG_MODULE) + 1:]
            if fn not in _DRAW_STREAM_ARG:
                continue
            idx = _DRAW_STREAM_ARG[fn]
            if len(node.args) <= idx:
                yield ctx.finding(
                    self.code, node,
                    f"rng.{fn}() call with no stream argument "
                    f"(expected a declared stream at position {idx})",
                )
                continue
            stream = _unwrap_int(node.args[idx])
            const = None
            if isinstance(stream, ast.Attribute):
                const = stream.attr
            elif isinstance(stream, ast.Name):
                const = stream.id
            if const is None:
                yield ctx.finding(
                    self.code, node,
                    f"rng.{fn}() stream argument is not a declared "
                    "constant (literal or computed ids are invisible to "
                    "the core/rng.py registry)",
                )
            elif ctx.streams and const not in ctx.streams:
                yield ctx.finding(
                    self.code, node,
                    f"rng.{fn}() uses undeclared stream '{const}' "
                    f"(registry: {', '.join(sorted(ctx.streams))})",
                )
