"""detlint rule registry — one module per encoded bug class.

Each rule names the historical bug it encodes (docs/static_analysis.md
has the full catalog with the PRs that fixed each class by hand before
the rule existed):

  DET001  raw RNG use outside core/rng.py
  DET002  undeclared / reused counter-RNG stream ids
  DET003  dtype-unpinned jnp constructors & default-dtype scalar calls
  DET004  unwidened integer accumulators crossing psum/all_gather
  DET005  Pallas output refs with no unconditional or zeroing write
  DET006  host nondeterminism inside traced code
"""

from __future__ import annotations

from repro.analysis.lint.rules import (
    det001_raw_rng,
    det002_streams,
    det003_dtype,
    det004_widening,
    det005_kernel_outputs,
    det006_host_nondet,
)

_RULES = (
    det001_raw_rng.RawRngRule(),
    det002_streams.StreamRegistryRule(),
    det003_dtype.DtypePinRule(),
    det004_widening.WideningRule(),
    det005_kernel_outputs.KernelOutputRule(),
    det006_host_nondet.HostNondetRule(),
)


def all_rules():
    return _RULES


def rule_catalog() -> dict:
    """code -> one-line description (for ``detlint --list-rules``)."""
    return {r.code: r.description for r in _RULES}
