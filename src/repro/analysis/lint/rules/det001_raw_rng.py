"""DET001: raw RNG use outside the counter-RNG module.

The reproducibility contract makes every stochastic draw a pure function
of ``(seed, day, entity ids, stream)`` via ``core/rng.py`` — that is what
makes results bitwise identical across mesh shapes and elastic restarts.
A stray ``jax.random.split``, ``np.random.*`` draw, or stdlib ``random``
call reintroduces order- or partition-dependent streams. Host-side
builders that deliberately use a *seeded* numpy Generator (synthetic
populations, the chaos harness) carry an inline pragma with their
justification.
"""

from __future__ import annotations

import ast

_BANNED_PREFIXES = ("jax.random.", "numpy.random.", "random.")
_BANNED_MODULES = ("jax.random", "numpy.random", "random")


class RawRngRule:
    code = "DET001"
    description = ("raw jax.random / np.random / random use outside "
                   "core/rng.py (draws must go through counter-RNG streams)")

    def check(self, ctx):
        if ctx.path.endswith(ctx.config.rng_module_suffix):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name in _BANNED_MODULES:
                        yield ctx.finding(
                            self.code, node,
                            f"import of '{a.name}': all stochastic draws "
                            "must go through repro.core.rng streams",
                        )
            elif isinstance(node, ast.ImportFrom):
                if node.level == 0 and node.module in _BANNED_MODULES:
                    yield ctx.finding(
                        self.code, node,
                        f"import from '{node.module}': all stochastic draws "
                        "must go through repro.core.rng streams",
                    )
            elif isinstance(node, ast.Call):
                name = ctx.imports.resolve(node.func)
                if name and (name in _BANNED_MODULES
                             or name.startswith(_BANNED_PREFIXES)):
                    yield ctx.finding(
                        self.code, node,
                        f"call to '{name}': use repro.core.rng "
                        "(counter-based, partition-invariant) instead",
                    )
