"""DET004: integer accumulators crossing collectives without widening.

PR 2's contacts bug: per-visit contact counts were summed to int32 and
``psum``-ed across workers — at paper scale the global sum wraps within
one day, and it wraps *differently per mesh shape*, breaking the bitwise
contract in the worst possible way (silently). The day step now widens
to int64 before the contacts psum; this rule keeps it that way.

Heuristic: for every ``psum(...)`` / ``all_gather(...)`` operand, find
the ``.sum()`` / ``jnp.sum(...)`` feeding it and classify the summed
source:

  * a **bool mask** (comparison / mask algebra / bool-dtype zeros) —
    its sum is bounded by the shard width, int32 is provably safe;
  * anything else — the sum is unbounded; it must pass through
    ``.astype(<non-32-bit dtype expr>)`` before the collective. A cast
    to a *named* dtype (``cdtype``, ``contacts_dtype()``) counts as a
    deliberate widening decision; a literal ``jnp.int32`` does not.
"""

from __future__ import annotations

import ast

from repro.analysis.lint.engine import is_boolish, local_assignments

_COLLECTIVE_ATTRS = {"psum", "all_gather", "all_to_all", "psum_scatter"}
_NARROW_INT_DTYPES = {"int32", "uint32", "int16", "uint16", "int8", "uint8"}


def _narrow_int_dtype(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute):
        return node.attr in _NARROW_INT_DTYPES
    if isinstance(node, ast.Name):
        return node.id in _NARROW_INT_DTYPES
    return False


def _is_sum_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    return isinstance(f, ast.Attribute) and f.attr == "sum"


def _sum_source(node: ast.Call) -> ast.AST:
    """The expression being summed: ``x.sum()`` -> x, ``jnp.sum(x)`` -> x."""
    f = node.func
    if node.args:  # jnp.sum(x, ...) form
        obj = f.value if isinstance(f, ast.Attribute) else None
        # Method form x.sum(axis=..) has the source as the receiver even
        # with args; module form jnp.sum(x) has it as args[0]. Receivers
        # named like modules (jnp/np) mean module form.
        if isinstance(obj, ast.Name) and obj.id in ("jnp", "np", "numpy",
                                                    "lax"):
            return node.args[0]
        return obj if obj is not None else node.args[0]
    return f.value if isinstance(f, ast.Attribute) else node


class WideningRule:
    code = "DET004"
    description = ("unwidened integer .sum() flowing into psum/all_gather "
                   "(int32 accumulators wrap cross-worker at scale)")

    def _check_operand(self, ctx, call, operand, env):
        """Yield findings for unwidened unbounded sums inside ``operand``."""
        for node in ast.walk(operand):
            if not _is_sum_call(node):
                continue
            src = _sum_source(node)
            if is_boolish(src, env):
                continue  # bounded by shard width — int32 safe
            # Chase one level of local assignment for the source.
            if isinstance(src, ast.Name):
                vals = env.get(src.id, [])
                if vals and all(is_boolish(v, env) for v in vals):
                    continue
            # Is the sum wrapped in a widening astype before the collective?
            wrapped = self._astype_target(operand, node)
            if wrapped is None:
                yield ctx.finding(
                    self.code, call,
                    "unbounded .sum() crosses a collective with no "
                    "explicit dtype: widen with .astype(...) before "
                    "psum/all_gather (int32 wraps at scale)",
                )
            elif _narrow_int_dtype(wrapped):
                yield ctx.finding(
                    self.code, call,
                    "unbounded .sum() is pinned to a 32-bit-or-narrower "
                    "int before a collective: widen (int64 under x64, or "
                    "a named dtype seam like cdtype) before psum",
                )

    @staticmethod
    def _astype_target(operand, sum_call):
        """If ``sum_call`` is the receiver of an ``.astype(X)`` somewhere in
        ``operand``, return X; else None."""
        for node in ast.walk(operand):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype"
                    and node.func.value is sum_call
                    and node.args):
                return node.args[0]
        return None

    def check(self, ctx):
        # Outermost functions claim their collectives first (ast.walk is
        # breadth-first), with an env spanning their whole subtree — so a
        # psum inside a closure still sees the enclosing scope's masks.
        covered = set()
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            env = local_assignments(fn)
            for node in ast.walk(fn):
                if id(node) in covered or not self._is_collective(ctx, node):
                    continue
                covered.add(id(node))
                yield from self._check_operand(ctx, node, node.args[0], env)
        # module level (rare, but keep the rule total)
        for node in ast.walk(ctx.tree):
            if id(node) not in covered and self._is_collective(ctx, node):
                yield from self._check_operand(ctx, node, node.args[0], {})

    @staticmethod
    def _is_collective(ctx, node) -> bool:
        if not (isinstance(node, ast.Call) and node.args):
            return False
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in _COLLECTIVE_ATTRS:
            return True
        resolved = ctx.imports.resolve(f)
        return bool(resolved) and resolved.split(".")[-1] in _COLLECTIVE_ATTRS
