"""DET006: host nondeterminism inside traced code.

``day_step``, scan bodies and kernel bodies execute at *trace time*:
any host-side effect there either bakes a trace-time value into the
compiled program (wall-clock, set-iteration order under hash
randomization) or mutates state behind jit's back (attribute writes),
and both produce programs that differ run to run while looking pure.
Flagged inside traced contexts:

  * wall-clock / entropy calls (``time.*``, ``datetime.now``,
    ``os.urandom``, ``uuid.*``);
  * iteration over a ``set`` (PYTHONHASHSEED-dependent order decides
    accumulation order — the one iteration order Python does not pin);
  * attribute mutation (``self.x = ...`` inside a pure step).

A *traced context* is any function named like the repo's step/body/
kernel conventions (``*day_step``, ``body``/``*_body``, ``*_kernel``)
or passed as the body argument of ``lax.scan`` / ``fori_loop`` /
``while_loop`` / ``cond`` / ``pl.pallas_call``.
"""

from __future__ import annotations

import ast
import re

_NAME_PATTERNS = re.compile(
    r"(day_step$|^body$|_body$|^scan_body|^loop_body|_kernel$|^kernel$)"
)

_CLOCK_PREFIXES = ("time.", "datetime.", "uuid.")
_CLOCK_EXACT = {"os.urandom", "secrets.token_bytes", "secrets.randbits"}

#: (resolved callable, index of the traced-body argument)
_BODY_ARG = {
    "jax.lax.scan": 0,
    "jax.lax.fori_loop": 2,
    "jax.lax.while_loop": 1,
    "jax.lax.cond": 1,  # and 2 — both branches, handled below
    "jax.experimental.pallas.pallas_call": 0,
}


def _traced_function_names(ctx) -> set:
    names = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        resolved = ctx.imports.resolve(node.func)
        if resolved in _BODY_ARG:
            idxs = (1, 2) if resolved.endswith(".cond") else (
                _BODY_ARG[resolved],)
            for i in idxs:
                if i < len(node.args) and isinstance(node.args[i], ast.Name):
                    names.add(node.args[i].id)
    return names


class HostNondetRule:
    code = "DET006"
    description = ("host nondeterminism (wall-clock, set iteration, "
                   "attribute mutation) inside day_step/scan/kernel bodies")

    def check(self, ctx):
        body_names = _traced_function_names(ctx)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not (_NAME_PATTERNS.search(fn.name) or fn.name in body_names):
                continue
            yield from self._check_traced(ctx, fn)

    def _check_traced(self, ctx, fn):
        # ``self.x = ...`` inside an ``__init__`` is object construction
        # (trace-time adapter/view classes), not mutation of live state.
        init_spans = [
            (n.lineno, n.end_lineno) for n in ast.walk(fn)
            if isinstance(n, ast.FunctionDef) and n.name == "__init__"
        ]
        in_init = lambda node: any(a <= node.lineno <= b
                                   for a, b in init_spans)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = ctx.imports.resolve(node.func)
                if name and (name in _CLOCK_EXACT
                             or name.startswith(_CLOCK_PREFIXES)):
                    yield ctx.finding(
                        self.code, node,
                        f"'{name}' inside traced '{fn.name}': the value is "
                        "baked in at trace time and differs per run",
                    )
            elif isinstance(node, ast.For):
                if self._is_set_expr(node.iter):
                    yield ctx.finding(
                        self.code, node,
                        f"iteration over a set inside traced '{fn.name}': "
                        "set order is hash-seed dependent — sort first",
                    )
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute) and not in_init(node):
                        yield ctx.finding(
                            self.code, node,
                            f"attribute mutation '{ast.unparse(t)}' inside "
                            f"traced '{fn.name}': traced code must be pure "
                            "in (params, state)",
                        )

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "set":
            return True
        # x & y on sets is invisible statically; keep to the direct forms.
        return False
