"""DET005: Pallas output refs must be fully written.

The undefined-VMEM bug class (PR 3's all-padding-block test, hardened
again in PR 6): a kernel whose output ref is written only under a
``pl.when`` guard flushes *undefined VMEM* for grid steps where the guard
is false — values that differ run to run and device to device, the exact
opposite of the bitwise contract. The repo's rule: every output ref gets
either an unconditional write, or an explicit zeroing write on a guard
branch (the ``row_start`` zeroing idiom), with the wrapper masking any
rows the grid never visits.

Detection: inside any function that uses ``pl.program_id`` / ``pl.when``
(i.e. a Pallas kernel body), every name stored through subscript
(``ref[...] = / +=``) is an output ref. A ref whose writes all sit under
``pl.when``-guarded nested functions, none of them zeroing
(``jnp.zeros_like`` / constant 0), is flagged.
"""

from __future__ import annotations

import ast

_PL_MARKERS = {"when", "program_id", "num_programs"}


def _uses_pallas(fn: ast.AST, ctx) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = ctx.imports.resolve(node.func)
            d = name or ""
            if d.split(".")[-1] in _PL_MARKERS and (
                    "pallas" in d or (dotted_prefix(node.func) == "pl")):
                return True
    return False


def dotted_prefix(func: ast.AST) -> str:
    node = func
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _is_when_guarded(fn_def: ast.FunctionDef) -> bool:
    """True for ``@pl.when(...)``-decorated nested kernel branches."""
    for dec in fn_def.decorator_list:
        if isinstance(dec, ast.Call) and isinstance(dec.func, ast.Attribute) \
                and dec.func.attr == "when":
            return True
    return False


def _is_zeroing(value: ast.AST) -> bool:
    if isinstance(value, ast.Constant) and value.value in (0, 0.0, False):
        return True
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Attribute):
        return value.func.attr in ("zeros_like", "zeros", "full_like")
    return False


class KernelOutputRule:
    code = "DET005"
    description = ("Pallas output ref written only under pl.when with no "
                   "zeroing branch (flushes undefined VMEM)")

    def check(self, ctx):
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if not _uses_pallas(fn, ctx):
                continue
            # Skip nested guard branches; they are analyzed as part of
            # their enclosing kernel.
            if _is_when_guarded(fn):
                continue
            yield from self._check_kernel(ctx, fn)

    def _check_kernel(self, ctx, fn):
        # writes[name] -> list of (conditional?, zeroing?, node)
        writes: dict = {}

        def record(target, value, conditional, node):
            base = target
            while isinstance(base, ast.Subscript):
                base = base.value
            if not isinstance(base, ast.Name):
                return
            writes.setdefault(base.id, []).append(
                (conditional, value is not None and _is_zeroing(value), node)
            )

        def visit(node, conditional):
            for child in ast.iter_child_nodes(node):
                cond = conditional
                if isinstance(child, ast.FunctionDef) and child is not fn:
                    cond = conditional or _is_when_guarded(child)
                if isinstance(child, ast.Assign):
                    for t in child.targets:
                        if isinstance(t, ast.Subscript):
                            record(t, child.value, cond, child)
                elif isinstance(child, ast.AugAssign) and isinstance(
                        child.target, ast.Subscript):
                    record(child.target, None, cond, child)
                visit(child, cond)

        visit(fn, False)

        for name, ws in sorted(writes.items()):
            if any(not conditional for conditional, _, _ in ws):
                continue  # unconditional write covers every grid step
            if any(zeroing for _, zeroing, _ in ws):
                continue  # explicit row-zeroing branch (row_start idiom)
            node = ws[0][2]
            yield ctx.finding(
                self.code, node,
                f"output ref '{name}' is written only under pl.when with "
                "no zeroing branch: unvisited grid steps flush undefined "
                "VMEM — add an unconditional or row_start-zeroing write",
            )
