"""DET003: dtype-unpinned jnp constructors and default-dtype scalar calls.

Under ``JAX_ENABLE_X64=1`` the default dtypes widen: ``jnp.zeros(n)`` is
f64, ``jnp.arange(n)`` is int64, ``jnp.log(10000.0)`` computes in f64.
Any such value meeting a pinned f32/int32 carry changes either the
carry dtype (scan error) or the rounding of downstream math — the twice-
recurred promotion bug class (PR 5's int32->int64 scan-carry break, the
LM stack's f64 promotion fixed in PR 6). Two checks:

  * constructors (``jnp.zeros/ones/full/arange/...``) must pin ``dtype=``
    (positionally or by keyword);
  * jnp calls whose every data argument is a bare python scalar
    materialize a default-dtype array (``jnp.array(0.5)``,
    ``jnp.log(10000.0)``) and must pin the dtype instead.

``bool`` counts as a pin (it has no x64 variant), and dtype-constructor
calls like ``jnp.float32(0.5)`` are themselves pins.
"""

from __future__ import annotations

import ast

_JNP = "jax.numpy."

#: constructor -> positional index where dtype may appear.
_CONSTRUCTORS = {
    "zeros": 1,
    "ones": 1,
    "empty": 1,
    "full": 2,
    "identity": 1,
    "eye": 3,
    "arange": 3,
    "linspace": 5,
    "tri": 3,
}

#: dtype-constructor names: calling these IS the pin.
_DTYPE_NAMES = {
    "float0", "float16", "float32", "float64", "bfloat16",
    "int4", "int8", "int16", "int32", "int64",
    "uint4", "uint8", "uint16", "uint32", "uint64",
    "bool_", "complex64", "complex128",
}

#: jnp namespace members that never materialize data arrays (no dtype
#: concern even with all-scalar arguments).
_NON_ARRAY_FNS = {
    "shape", "ndim", "size", "dtype", "result_type", "promote_types",
    "issubdtype", "iinfo", "finfo", "errstate",
}


def _has_dtype(node: ast.Call, pos: int) -> bool:
    if any(kw.arg == "dtype" for kw in node.keywords):
        return True
    return len(node.args) > pos


def _only_scalar_constants(args) -> bool:
    """True if every argument is a (possibly negated / arithmetic
    combination of) numeric python literal — i.e. no array operand sets
    the result dtype, so the default dtype wins."""
    if not args:
        return False
    saw_number = False
    for a in args:
        for sub in ast.walk(a):
            if isinstance(sub, ast.Constant):
                if isinstance(sub.value, (int, float)) and not isinstance(
                        sub.value, bool):
                    saw_number = True
                elif sub.value is not None:
                    return False
            elif not isinstance(sub, (ast.UnaryOp, ast.BinOp, ast.operator,
                                      ast.unaryop, ast.Tuple, ast.List,
                                      ast.expr_context, ast.Load)):
                return False
    return saw_number


class DtypePinRule:
    code = "DET003"
    description = ("dtype-unpinned jnp constructor or all-scalar jnp call "
                   "(default dtype widens under JAX_ENABLE_X64=1)")

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.imports.resolve(node.func)
            if not name or not name.startswith(_JNP):
                continue
            fn = name[len(_JNP):]
            if "." in fn or fn in _DTYPE_NAMES or fn in _NON_ARRAY_FNS:
                continue
            if fn in _CONSTRUCTORS:
                if not _has_dtype(node, _CONSTRUCTORS[fn]):
                    yield ctx.finding(
                        self.code, node,
                        f"jnp.{fn}() without dtype=: defaults promote "
                        "under JAX_ENABLE_X64=1 — pin the dtype",
                    )
                continue
            if fn in ("array", "asarray"):
                if not _has_dtype(node, 1) and node.args \
                        and _only_scalar_constants(node.args[:1]):
                    yield ctx.finding(
                        self.code, node,
                        f"jnp.{fn}(<literal>) without dtype=: materializes "
                        "a default-dtype array (f64/int64 under x64)",
                    )
                continue
            if _only_scalar_constants(node.args) and not node.keywords:
                yield ctx.finding(
                    self.code, node,
                    f"jnp.{fn}() on bare scalar literal(s): computes in "
                    "the default dtype (f64 under x64) — wrap an operand "
                    "in jnp.float32(...) or pass an array",
                )
