"""detlint — the repo's determinism static-analysis pass.

Level 1 (this package): an AST rule engine encoding the bitwise
contract's coding invariants as named DET rules, with inline
``# detlint: ignore[RULE]`` pragmas, a committed baseline, and console +
JSON output. ``python -m repro.analysis.lint src/`` is the CI entry
point. Level 2 lives in :mod:`repro.analysis.hlo`: jaxpr/HLO assertion
helpers (``assert_no_f64``, ``collective_count``, ``recompile_sentinel``)
for use from tests.

See docs/static_analysis.md for the rule catalog and the historical bug
each rule encodes.
"""

from repro.analysis.lint.engine import (  # noqa: F401
    Finding,
    LintConfig,
    apply_baseline,
    load_baseline,
    render_console,
    render_json,
    run_lint,
    write_baseline,
)
from repro.analysis.lint.rules import all_rules, rule_catalog  # noqa: F401
