"""``python -m repro.analysis.lint`` / ``detlint`` — CLI driver.

Exit codes: 0 clean (after pragmas + baseline), 1 new findings or file
errors, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint.engine import (
    LintConfig,
    apply_baseline,
    load_baseline,
    render_console,
    render_json,
    run_lint,
    write_baseline,
)
from repro.analysis.lint.rules import rule_catalog


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="detlint",
        description="Determinism static analysis for the repro codebase "
                    "(rule catalog: docs/static_analysis.md)",
    )
    p.add_argument("paths", nargs="*", help="files or directories to lint")
    p.add_argument("--rules", help="comma-separated rule codes to run "
                                   "(default: all)")
    p.add_argument("--baseline", help="baseline JSON; findings in it are "
                                      "suppressed, new ones fail")
    p.add_argument("--relax", action="append", default=[],
                   metavar="PREFIX:RULES",
                   help="drop RULES (comma list, or *) for files under "
                        "PREFIX, e.g. 'tests/:DET001' — a per-directory "
                        "posture, repeatable")
    p.add_argument("--write-baseline", metavar="PATH",
                   help="write current findings as the new baseline and "
                        "exit 0")
    p.add_argument("--json", dest="json_out", metavar="PATH",
                   help="also write the machine-readable report ( '-' for "
                        "stdout)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for code, desc in sorted(rule_catalog().items()):
            print(f"{code}  {desc}")
        return 0
    if not args.paths:
        print("detlint: no paths given (try: detlint src/)",
              file=sys.stderr)
        return 2

    select = None
    if args.rules:
        select = tuple(c.strip().upper() for c in args.rules.split(",")
                       if c.strip())
        unknown = set(select) - set(rule_catalog())
        if unknown:
            print(f"detlint: unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2

    relax = []
    for spec in args.relax:
        prefix, sep, codes_s = spec.partition(":")
        codes = tuple(c.strip().upper() for c in codes_s.split(",")
                      if c.strip())
        if not sep or not prefix or not codes:
            print(f"detlint: --relax wants PREFIX:RULES, got '{spec}'",
                  file=sys.stderr)
            return 2
        unknown = set(codes) - set(rule_catalog()) - {"*"}
        if unknown:
            print(f"detlint: unknown rule(s) in --relax: "
                  f"{', '.join(sorted(unknown))}", file=sys.stderr)
            return 2
        relax.append((prefix, codes))

    findings, errors = run_lint(
        args.paths, LintConfig(select=select, relax=tuple(relax)))

    if args.write_baseline:
        write_baseline(args.write_baseline, findings)
        print(f"detlint: wrote baseline with {len(findings)} finding(s) "
              f"to {args.write_baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    new, suppressed = apply_baseline(findings, baseline)

    if args.json_out:
        report = json.dumps(render_json(new, suppressed, errors), indent=2)
        if args.json_out == "-":
            print(report)
        else:
            with open(args.json_out, "w") as f:
                f.write(report + "\n")
    print(render_console(new, suppressed, errors))
    return 1 if (new or errors) else 0


if __name__ == "__main__":
    sys.exit(main())
