from repro.analysis.hlo import collective_bytes  # noqa: F401
from repro.analysis.roofline import RooflineTerms, roofline_from_measurements  # noqa: F401
