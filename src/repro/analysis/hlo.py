"""Post-SPMD HLO text analysis: collective operand bytes.

``compiled.as_text()`` is the per-device optimized module; collectives only
exist after SPMD partitioning, so this is the right artifact. We sum the
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, per the roofline spec. Shapes in the text
are per-partition, so the sums are per-device bytes — which is what the
collective roofline term divides by per-chip link bandwidth.

Caveat recorded in DESIGN.md §7: ops inside a while loop appear once in the
text; analysis/roofline.py corrects by per-layer extrapolation.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,4096,320]{2,1,0}" — capture dtype and dims
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# result shapes appear between '=' and the opcode: "%x = bf16[...]{...} all-gather("
_LINE_RE = re.compile(
    r"=\s+(?P<shapes>(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<suffix>-start|-done)?\("
)
# iota replica groups: replica_groups=[G,S]<=[N] => groups of size S
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# explicit: replica_groups={{0,1,2,3},{...}} => count ids in first group
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device *operand* bytes by collective type + op counts.

    HLO text lists operands as %refs without shapes, so operand bytes are
    derived from the result shape and the op semantics:
      all-reduce / all-to-all / collective-permute: operand == result
      all-gather:      operand = result / group_size
      reduce-scatter:  operand = result * group_size
    `*-done` ops are skipped (payload counted at `*-start`).
    """
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # async pair: counted at -start
        op = m.group("op")
        result = 0
        for dm in _SHAPE_RE.finditer(m.group("shapes")):
            result += _shape_bytes(dm.group(1), dm.group(2))
        g = _group_size(line)
        if op == "all-gather":
            operand = result // g
        elif op == "reduce-scatter":
            operand = result * g
        else:
            operand = result
        bytes_by[op] += operand
        count_by[op] += 1
    return {
        "bytes": dict(bytes_by),
        "count": dict(count_by),
        "total_bytes": int(sum(bytes_by.values())),
    }


# ---------------------------------------------------------------------------
# detlint Level 2: jaxpr-level determinism assertions.
#
# Level 1 (repro.analysis.lint) is purely syntactic; these helpers close the
# gap for properties only visible after tracing — weak-type promotion under
# JAX_ENABLE_X64, collective op counts, and silent recompilation. They are
# used by the x64 guard test (tests/test_detlint.py) and available to any
# test that wants to pin a compiled artifact's shape.
# ---------------------------------------------------------------------------

_WIDE_DTYPES = ("float64", "complex128")


def _iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (scan/cond/while bodies, pjit calls, custom_jvp, pallas grids, ...)."""
    import jax.extend.core as jex_core

    seen = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                for cand in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(cand, jex_core.ClosedJaxpr):
                        stack.append(cand.jaxpr)
                    elif isinstance(cand, jex_core.Jaxpr):
                        stack.append(cand)


def _jaxpr_of(fn, *args, **kwargs):
    import jax

    return jax.make_jaxpr(fn)(*args, **kwargs).jaxpr


def find_f64(fn, *args, **kwargs) -> list:
    """Trace ``fn`` and return every (eqn primitive, var, dtype) whose
    output is f64/c128 — the signature of a weak-type promotion leak.
    Empty list == the computation is f64-clean under the *current* x64
    setting (run it under JAX_ENABLE_X64=1 for the guard to bite)."""
    leaks = []
    for j in _iter_jaxprs(_jaxpr_of(fn, *args, **kwargs)):
        for eqn in j.eqns:
            for out in eqn.outvars:
                dt = getattr(getattr(out, "aval", None), "dtype", None)
                if dt is not None and str(dt) in _WIDE_DTYPES:
                    leaks.append((eqn.primitive.name, str(out), str(dt)))
    return leaks


def assert_no_f64(fn, *args, **kwargs) -> None:
    """Assert no f64/c128 intermediate anywhere in ``fn``'s jaxpr
    (including scan/cond/pjit sub-jaxprs). The historical bug class: a
    bare Python float or np.float64 scalar weakly promoting f32 state
    under JAX_ENABLE_X64=1, silently forking trajectories from the
    x64-off run (PR 5/6 model-stack incident)."""
    leaks = find_f64(fn, *args, **kwargs)
    if leaks:
        head = ", ".join(f"{p}->{v}:{d}" for p, v, d in leaks[:8])
        more = f" (+{len(leaks) - 8} more)" if len(leaks) > 8 else ""
        raise AssertionError(
            f"f64 leak: {len(leaks)} wide-dtype intermediate(s): {head}{more}"
        )


_COLLECTIVE_PRIMS = (
    "psum", "psum2", "all_gather", "all_to_all", "ppermute", "pmax",
    "pmin", "reduce_scatter",
)


def collective_count(fn, *args, **kwargs) -> dict:
    """Count collective primitives in ``fn``'s jaxpr, by primitive name.

    The determinism use: a fixed scenario must emit a *fixed* collective
    schedule — a data-dependent collective count means the reduction
    topology (and hence float summation order) varies run to run. Pin the
    expected dict in a test next to the mesh shape it was derived on.
    """
    counts: dict = {}
    for j in _iter_jaxprs(_jaxpr_of(fn, *args, **kwargs)):
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name in _COLLECTIVE_PRIMS:
                counts[name] = counts.get(name, 0) + 1
    return counts


class recompile_sentinel:
    """Context manager asserting a jitted fn does not recompile inside the
    ``with`` block::

        step = jax.jit(day_step_fn)
        step(state)                       # warm up
        with recompile_sentinel(step):
            for _ in range(n):            # steady-state loop
                state = step(state)

    A growing cache means some argument is changing shape/dtype/static
    value per call — each recompile is a fresh XLA autotune roll and a
    silent fork of the bitwise contract (and a TEPS cliff)."""

    def __init__(self, jitted_fn, allow: int = 0):
        self._fn = jitted_fn
        self._allow = int(allow)
        self._before = 0

    def _size(self) -> int:
        try:
            return int(self._fn._cache_size())
        except AttributeError:  # pragma: no cover - older jax
            return 0

    def __enter__(self):
        self._before = self._size()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            return False
        grew = self._size() - self._before
        if grew > self._allow:
            raise AssertionError(
                f"recompile sentinel: jit cache grew by {grew} "
                f"(allowed {self._allow}) — an argument is changing "
                f"shape/dtype/static value between calls"
            )
        return False


def measure_compiled(lowered, compiled) -> dict:
    """One-stop per-device measurement from a compiled cell."""
    ca = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }
    except Exception:  # pragma: no cover - backend-dependent
        mem_d = {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "memory": mem_d,
        "collectives": coll,
    }
