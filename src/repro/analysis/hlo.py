"""Post-SPMD HLO text analysis: collective operand bytes.

``compiled.as_text()`` is the per-device optimized module; collectives only
exist after SPMD partitioning, so this is the right artifact. We sum the
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, per the roofline spec. Shapes in the text
are per-partition, so the sums are per-device bytes — which is what the
collective roofline term divides by per-chip link bandwidth.

Caveat recorded in DESIGN.md §7: ops inside a while loop appear once in the
text; analysis/roofline.py corrects by per-layer extrapolation.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,4096,320]{2,1,0}" — capture dtype and dims
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# result shapes appear between '=' and the opcode: "%x = bf16[...]{...} all-gather("
_LINE_RE = re.compile(
    r"=\s+(?P<shapes>(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<suffix>-start|-done)?\("
)
# iota replica groups: replica_groups=[G,S]<=[N] => groups of size S
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# explicit: replica_groups={{0,1,2,3},{...}} => count ids in first group
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device *operand* bytes by collective type + op counts.

    HLO text lists operands as %refs without shapes, so operand bytes are
    derived from the result shape and the op semantics:
      all-reduce / all-to-all / collective-permute: operand == result
      all-gather:      operand = result / group_size
      reduce-scatter:  operand = result * group_size
    `*-done` ops are skipped (payload counted at `*-start`).
    """
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # async pair: counted at -start
        op = m.group("op")
        result = 0
        for dm in _SHAPE_RE.finditer(m.group("shapes")):
            result += _shape_bytes(dm.group(1), dm.group(2))
        g = _group_size(line)
        if op == "all-gather":
            operand = result // g
        elif op == "reduce-scatter":
            operand = result * g
        else:
            operand = result
        bytes_by[op] += operand
        count_by[op] += 1
    return {
        "bytes": dict(bytes_by),
        "count": dict(count_by),
        "total_bytes": int(sum(bytes_by.values())),
    }


def measure_compiled(lowered, compiled) -> dict:
    """One-stop per-device measurement from a compiled cell."""
    ca = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }
    except Exception:  # pragma: no cover - backend-dependent
        mem_d = {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "memory": mem_d,
        "collectives": coll,
    }
