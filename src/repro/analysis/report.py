"""Generate EXPERIMENTS.md tables from artifacts/dryrun/*.json, plus the
scenario-sweep summary tables used by launch/sweep.py and
examples/intervention_sweep.py.

    PYTHONPATH=src python -m repro.analysis.report [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_, pattern):
    out = []
    for p in sorted(glob.glob(os.path.join(dir_, pattern))):
        with open(p) as f:
            out.append((os.path.basename(p)[:-5], json.load(f)))
    return out


def fmt_bytes(b):
    return f"{b/2**30:.1f}G" if b > 2**28 else f"{b/2**20:.0f}M"


def dryrun_table(dir_):
    print("\n### Dry-run status (compile proof per cell)\n")
    print("| arch | shape | 16x16 | 2x16x16 | compile s (1-pod) |")
    print("|---|---|---|---|---|")
    single = {k.replace("_16x16", ""): v for k, v in load(dir_, "*_16x16.json")}
    multi = {k.replace("_2x16x16", ""): v for k, v in load(dir_, "*_2x16x16.json")}
    for key in sorted(single):
        if key.endswith(("_chunked", "_opt", "_capdata", "_capdata2", "_flash",
                         "_smdisp", "_opt1", "_opt2", "_final")):
            continue
        s, m = single[key], multi.get(key)
        stat = lambda r: ("skip" if r and "skipped" in r
                          else "FAIL" if r is None or "error" in r else "ok")
        cs = s.get("compile_s", "-")
        print(f"| {s.get('arch')} | {s.get('shape')} | {stat(s)} | {stat(m)} | {cs} |")


def roofline_table(dir_, suffix="_16x16"):
    print("\n### Roofline baseline (single pod, 256 chips; seconds per step)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | bottleneck | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for key, r in load(dir_, f"*{suffix}.json"):
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.4f} | "
            f"{rf['t_memory_s']:.4f} | {rf['t_collective_s']:.4f} | "
            f"{rf['bottleneck']} | {rf['useful_flops_fraction']:.3f} | "
            f"{rf['roofline_fraction']:.4f} |"
        )


def compare(dir_, base, opts):
    print(f"\n#### {base}")
    print("| variant | t_compute | t_memory | t_collective | temp mem | roofline frac |")
    print("|---|---|---|---|---|---|")
    for name, path in [("baseline", base)] + opts:
        try:
            with open(os.path.join(dir_, path + ".json")) as f:
                r = json.load(f)
        except FileNotFoundError:
            continue
        if "roofline" not in r:
            print(f"| {name} | - | - | - | - | ERROR |")
            continue
        rf = r["roofline"]
        tb = r["scanned"]["memory"].get("temp_bytes", 0)
        print(
            f"| {name} | {rf['t_compute_s']:.3f} | {rf['t_memory_s']:.3f} | "
            f"{rf['t_collective_s']:.3f} | {fmt_bytes(tb)} | "
            f"{rf['roofline_fraction']:.4f} |"
        )


def summarize_sweep(hist, names, num_people):
    """Per-scenario epidemic summaries from ensemble history.

    ``hist`` is the dict of (days, B) arrays returned by
    ``EngineCore.run``; returns one row per
    scenario with the headline intervention-study metrics.
    """
    import numpy as np

    cum = np.asarray(hist["cumulative"])  # (days, B)
    infectious = np.asarray(hist["infectious"])
    rows = []
    for i, name in enumerate(names):
        rows.append({
            "scenario": name,
            "cumulative": int(cum[-1, i]),
            "attack_rate_pct": round(100.0 * cum[-1, i] / num_people, 2),
            "peak_infectious": int(infectious[:, i].max()),
            "peak_day": int(np.argmax(infectious[:, i])),
            "interactions": int(
                np.asarray(hist["contacts"], np.int64)[:, i].sum()
            ),
        })
    return rows


def summarize_result(result):
    """Per-scenario rows straight from a RunResult's *observables* — the
    on-device reductions, no second pass over the history. Accepts a live
    ``repro.api.RunResult`` or one loaded back from JSON. Falls back to the
    legacy history-based :func:`summarize_sweep` when the result was run
    without the attack-rate/peak-day observables."""
    import numpy as np

    obs = result.observables
    if "attack_rate" in obs and "peak_day" in obs:
        cum = np.asarray(obs["attack_rate"]["cumulative"])
        peak = np.asarray(obs["peak_day"]["peak_infectious"])
        peak_day = np.asarray(obs["peak_day"]["peak_day"])
        contacts = np.asarray(result.history["contacts"], np.int64)
        num_people = result.provenance["num_people"]
        return [{
            "scenario": name,
            "cumulative": int(cum[i]),
            # float64 from the exact counts, matching summarize_sweep's
            # rounding (the f32 on-device attack_rate can round differently
            # at the 2nd decimal)
            "attack_rate_pct": round(100.0 * cum[i] / num_people, 2),
            "peak_infectious": int(peak[i]),
            "peak_day": int(peak_day[i]),
            "interactions": int(contacts[:, i].sum()),
        } for i, name in enumerate(result.scenario_names)]
    return summarize_sweep(result.history, result.scenario_names,
                           result.provenance["num_people"])


def mean_ci_table(result, key="new_infections", every=1, file=None):
    """Render the on-device cross-scenario mean/CI band series of a
    RunResult (requires the ``ensemble_mean_ci`` observable)."""
    import numpy as np

    band = result.observables.get("ensemble_mean_ci", {}).get(key)
    if band is None:
        print(f"(no ensemble_mean_ci[{key}] observable in this result)",
              file=file)
        return
    mean = np.asarray(band["mean"])
    lo, hi = np.asarray(band["lo"]), np.asarray(band["hi"])
    print(f"| day | mean {key} | 95% CI |", file=file)
    print("|---|---|---|", file=file)
    for d in range(0, len(mean), every):
        print(f"| {d} | {mean[d]:.1f} | [{lo[d]:.1f}, {hi[d]:.1f}] |",
              file=file)


def sweep_table(rows, file=None):
    """Render summarize_sweep rows as a markdown table."""
    print("| scenario | attack % | peak infectious | peak day | interactions |",
          file=file)
    print("|---|---|---|---|---|", file=file)
    for r in rows:
        print(
            f"| {r['scenario']} | {r['attack_rate_pct']:.1f} | "
            f"{r['peak_infectious']} | {r['peak_day']} | "
            f"{r['interactions']} |",
            file=file,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--section", default="all")
    ap.add_argument("--result", default=None,
                    help="render the sweep + mean/CI tables of a RunResult "
                         "JSON (repro.api.run output)")
    args = ap.parse_args()
    if args.result:
        from repro.api import RunResult

        result = RunResult.load(args.result)
        print(f"\n### {result.spec.name} "
              f"(engine={result.provenance['engine']})\n")
        sweep_table(summarize_result(result))
        print()
        mean_ci_table(result, every=max(1, result.days // 20))
        return
    if args.section in ("all", "dryrun"):
        dryrun_table(args.dir)
    if args.section in ("all", "roofline"):
        roofline_table(args.dir)


if __name__ == "__main__":
    main()
