"""Three-term roofline model (TPU v5e target) from dry-run measurements.

    compute    = flops_per_chip / PEAK_FLOPS
    memory     = bytes_per_chip / HBM_BW
    collective = collective_bytes_per_chip / LINK_BW

(jax's ``cost_analysis`` returns per-device values for SPMD modules —
verified empirically — so no division by chip count here; the spec's
``HLO_FLOPs / (chips × peak)`` with global FLOPs is the same quantity.)

Scan correction: XLA cost analysis counts while-loop bodies once. The
dry-run therefore compiles each cell 3×: the production scanned program
(for memory analysis + compile proof) and unrolled 1-/2-layer variants
whose difference isolates the per-layer cost; the corrected totals are
``m1 + (L-1)·(m2-m1)``. Recorded per cell in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses

# --- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link (conservative single-link figure)
HBM_BYTES = 16 * 2**30  # 16 GiB


@dataclasses.dataclass
class RooflineTerms:
    flops: float  # per-chip
    bytes_accessed: float  # per-chip HBM traffic proxy
    collective_bytes: float  # per-chip
    model_flops_global: float  # 6*N*D (train) or 2*N*D (inference)
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline-ideal step time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs(global): remat/padding/redundancy waste."""
        hlo_global = self.flops * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak that the ideal schedule achieves on
        *useful* model FLOPs: (MODEL_FLOPS / chips / peak) / t_bound."""
        if self.t_bound == 0:
            return 0.0
        t_model = self.model_flops_global / self.chips / PEAK_FLOPS_BF16
        return t_model / self.t_bound

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def extrapolate_layers(m1: dict, m2: dict, num_layers: int,
                       layers_per_unit: float = 1.0) -> dict:
    """m1/m2: measurements with 1 and 2 unrolled units; returns corrected
    totals for ``num_layers`` layers (num_layers/layers_per_unit units)."""
    units = num_layers / layers_per_unit

    def fix(a, b):
        delta = b - a
        return a + max(units - 1.0, 0.0) * delta

    out = {
        "flops": fix(m1["flops"], m2["flops"]),
        "bytes_accessed": fix(m1["bytes_accessed"], m2["bytes_accessed"]),
        "collective_total_bytes": fix(
            m1["collectives"]["total_bytes"], m2["collectives"]["total_bytes"]
        ),
    }
    ops = set(m1["collectives"]["bytes"]) | set(m2["collectives"]["bytes"])
    out["collective_bytes_by_op"] = {
        op: fix(
            m1["collectives"]["bytes"].get(op, 0),
            m2["collectives"]["bytes"].get(op, 0),
        )
        for op in ops
    }
    return out


def model_flops(cfg, shape, param_count: int, active_param_count: int) -> float:
    """MODEL_FLOPS for one step of this cell (global, all chips)."""
    n_active = active_param_count
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analytic_attention_flops(cfg, shape) -> float:
    """Global forward attention FLOPs per step (QK^T + PV), for cells using
    the Pallas flash kernel: its body runs in VMEM and is invisible to
    XLA's cost analysis, so the roofline adds the exact analytic count.
    Causal masking halves the effective key length; sliding windows cap it.
    """
    B = shape.global_batch
    H = max(cfg.num_heads, 1)
    Dh = cfg.resolved_head_dim if cfg.num_heads else 0

    def attn(bq, sq, sk, causal=True, window=None):
        sk_eff = min(sk, window) if window else sk
        factor = 0.5 if (causal and window is None and sq == sk) else 1.0
        return 4.0 * bq * H * sq * sk_eff * Dh * factor

    if shape.kind == "decode":
        sq = 1
    else:
        sq = shape.seq_len

    if cfg.family == "audio":
        enc = cfg.enc_layers * attn(B, cfg.enc_frames, cfg.enc_frames, causal=False)
        sk = shape.seq_len
        dec_self = cfg.num_layers * attn(B, sq, sk)
        cross = cfg.num_layers * attn(B, sq, cfg.enc_frames, causal=False)
        if shape.kind == "decode":
            enc = 0.0  # encoder not run at decode
        return enc + dec_self + cross
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        from repro.models.transformer import hybrid_layer_types

        n_attn = hybrid_layer_types(cfg).count("attn")
        return n_attn * attn(B, sq, shape.seq_len, window=cfg.local_window)
    return cfg.num_layers * attn(B, sq, shape.seq_len, window=cfg.attn_window)


def roofline_from_measurements(
    corrected: dict, model_flops_global: float, chips: int
) -> RooflineTerms:
    return RooflineTerms(
        flops=corrected["flops"],
        bytes_accessed=corrected["bytes_accessed"],
        collective_bytes=corrected["collective_total_bytes"],
        model_flops_global=model_flops_global,
        chips=chips,
    )
