"""LM *token*-serving driver — not the epidemic simulation server.

Prefills a batch of prompts, then decodes autoregressively with the
ring-buffer KV cache:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --preset smoke --batch 8 --prompt-len 64 --gen 32

For serving *epidemic scenario requests* (warm executable cache +
scenario-axis batching over ``ExperimentSpec``s), see
:mod:`repro.launch.serve_sim` and :mod:`repro.serve`.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.tokens import TokenPipeline
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = dataclasses.replace(reduced_config(cfg), compute_dtype="float32")
    if cfg.family == "audio":
        raise SystemExit("use whisper decode via tests; serve driver targets LMs")

    total = args.prompt_len + args.gen
    params = M.init_params(cfg, jax.random.key(args.seed),  # detlint: ignore[DET001] — keyed LM init
                           max_target_positions=total + 8)
    pipe = TokenPipeline(cfg.vocab_size, args.prompt_len, args.batch, args.seed)
    prompts = jnp.asarray(pipe.batch(0))

    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.num_patches, cfg.d_model), jnp.float32
        )

    t0 = time.time()
    prefill = jax.jit(lambda p, b: M.forward_prefill(cfg, p, None, b))
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.time() - t0

    # prefill emitted a full-length cache? init_cache for total length and
    # re-prefill decode-style for simplicity of slot layout:
    cache = M.init_cache(cfg, args.batch, total)
    decode = jax.jit(
        lambda p, c, t, pos: M.decode_step(cfg, p, None, c, t, pos)
    )
    # replay the prompt through decode steps (fills the ring cache exactly)
    tok = prompts[:, :1]
    t0 = time.time()
    out_tokens = []
    for pos in range(total - 1):
        if pos < args.prompt_len - 1:
            tok = prompts[:, pos : pos + 1]
        lg, cache = decode(params, cache, tok, jnp.asarray(pos, jnp.int32))
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if pos >= args.prompt_len - 1:
            tok = nxt
            out_tokens.append(np.asarray(nxt)[:, 0])
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack(out_tokens, 1) if out_tokens else np.zeros((args.batch, 0))

    print(json.dumps({
        "arch": cfg.name,
        "batch": args.batch,
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "tokens_generated": int(gen.size),
        "tokens_per_s": round(gen.size / max(t_decode, 1e-9), 1),
        "sample_generation": gen[0][:16].tolist(),
    }))


if __name__ == "__main__":
    main()
