"""Scenario-ensemble driver — a thin wrapper over ``repro.api.run``.

    PYTHONPATH=src python -m repro.launch.sweep --dataset twin-2k --days 60 \
        --interventions none,school-closure,lockdown --replicates 3 \
        --tau-scales 1.0,0.75 --out artifacts/sweep.json

The flags build (or, with ``--spec``, override) a declarative
:class:`~repro.api.ExperimentSpec` whose sweep axes (interventions x tau x
replicate seeds) expand to a ScenarioBatch; the facade picks the ensemble
engine from the mesh shape (``--workers W`` selects the hybrid 2-D
workers x scenarios mesh; multiple visible devices shard the scenario
axis automatically) and reports per-scenario attack-rate summaries plus
ensemble throughput (TEPS x batch).
"""

from __future__ import annotations

import argparse
import json

import jax

from repro import api
from repro.analysis.report import sweep_table
from repro.launch import cli


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    cli.add_common_args(ap)
    ap.add_argument("--interventions", default=None,
                    help="comma list of preset names "
                         "(see repro/configs/presets.py)")
    ap.add_argument("--tau-scales", default=None,
                    help="comma list of multipliers on the base tau")
    ap.add_argument("--sharded", action="store_true",
                    help="force sharding the scenario axis over all devices")
    args = ap.parse_args()

    extra = {}
    if args.interventions is not None:
        extra["interventions"] = cli.parse_intervention_axis(args.interventions)
    if args.tau_scales is not None:
        extra["tau_scales"] = cli.parse_float_axis(args.tau_scales,
                                                   "--tau-scales")
    if args.sharded and args.engine is None:
        extra["engine"] = "sharded"  # force the shard_map path, any devices

    spec = cli.build_spec(args, dict(
        name="sweep", days=60,
        interventions=("none", "school-closure"), replicates=2,
    ), **extra)

    # Auto-fill the scenario mesh axis, clamped to the batch size (a
    # 1-scenario study must not request a multi-device scenario axis):
    # --sharded shards over visible devices; flag-built hybrid runs
    # (--workers W) give the scenario axis the devices the workers leave;
    # other flag-built multi-device runs shard over everything. A --spec
    # file's declared mesh always wins unless --scenarios overrides it.
    ndev = len(jax.devices())
    if args.scenarios is None:
        B = spec.num_scenarios
        if args.sharded:
            spec = spec.with_overrides(scenarios=min(ndev, B))
        elif args.spec is None and spec.mesh.workers > 1:
            spec = spec.with_overrides(
                scenarios=max(1, min(ndev // spec.mesh.workers, B)))
        elif args.spec is None and ndev > 1 and B > 1:
            spec = spec.with_overrides(scenarios=min(ndev, B))

    result = api.run(spec)
    prov = result.provenance
    print(f"dataset={result.spec.dataset} engine={prov['engine']} "
          f"scenarios={result.num_scenarios} days={result.days} "
          f"devices={prov['num_devices']}")
    sweep_table(result.summaries)
    edges = float(sum(r["interactions"] for r in result.summaries))
    # Throughput from the day-loop wall clock (excl. pop build), keeping
    # the TEPS breadcrumbs comparable with the pre-facade artifacts.
    wall = prov["run_wall_s"]
    print(json.dumps({
        "dataset": result.spec.dataset,
        "engine": prov["engine"],
        "scenarios": result.num_scenarios,
        "days": result.days,
        "wall_s": wall,
        "s_per_scenario_day": round(
            wall / (result.days * result.num_scenarios), 5),
        "ensemble_teps": round(edges / wall, 1) if wall else None,
    }))

    if args.out:
        result.save(args.out)  # creates parent dirs


if __name__ == "__main__":
    main()
