"""Scenario-ensemble driver: a factorial intervention study in one scan.

    PYTHONPATH=src python -m repro.launch.sweep --dataset twin-2k --days 60 \
        --interventions none,school-closure,lockdown --replicates 3 \
        --tau-scales 1.0,0.75 --out artifacts/sweep.json

Builds the (interventions x tau x replicate-seeds) ScenarioBatch, runs it
as one jitted vmapped ``lax.scan`` (sharding the scenario axis over all
visible JAX devices when there are several), and reports per-scenario
attack-rate summaries plus ensemble throughput (TEPS x batch).

``--workers W`` switches to the hybrid 2-D (workers x scenarios) mesh:
each scenario is itself people/location-sharded over W devices while the
scenario axis is sharded over the remaining num_devices // W.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.analysis.report import summarize_sweep, sweep_table
from repro.configs import ScenarioBatch, get_epidemic
from repro.launch.mesh import make_hybrid_mesh
from repro.launch.simulate import DISEASES, INTERVENTION_PRESETS
from repro.sweep import EnsembleSimulator, HybridEnsemble, ShardedEnsemble


def build_batch(args, base_tau: float) -> ScenarioBatch:
    iv_axis = {}
    for name in args.interventions.split(","):
        if name not in INTERVENTION_PRESETS:
            raise SystemExit(
                f"error: unknown intervention preset '{name}'; "
                f"have {sorted(INTERVENTION_PRESETS)}"
            )
        iv_axis[name] = INTERVENTION_PRESETS[name]
    try:
        taus = [base_tau * float(s) for s in args.tau_scales.split(",")]
    except ValueError:
        raise SystemExit(f"error: --tau-scales must be comma-separated floats, "
                         f"got '{args.tau_scales}'")
    if args.replicates < 1:
        raise SystemExit("error: --replicates must be >= 1")
    seeds = [args.seed + r for r in range(args.replicates)]
    return ScenarioBatch.from_product(
        interventions=iv_axis,
        tau=taus,
        disease=DISEASES[args.disease](),
        seeds=seeds,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="twin-2k")
    ap.add_argument("--days", type=int, default=60)
    ap.add_argument("--disease", default="covid", choices=sorted(DISEASES))
    ap.add_argument("--interventions", default="none,school-closure",
                    help="comma list of preset names (see launch/simulate.py)")
    ap.add_argument("--tau", type=float, default=None)
    ap.add_argument("--tau-scales", default="1.0",
                    help="comma list of multipliers on the base tau")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicates", type=int, default=2)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "scan", "compact", "pallas"])
    ap.add_argument("--sharded", action="store_true",
                    help="force the shard_map path (auto when >1 device)")
    ap.add_argument("--workers", type=int, default=1,
                    help="people/location-shard each scenario over this many "
                         "devices (hybrid 2-D workers x scenarios mesh)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    epi = get_epidemic(args.dataset)
    pop = epi.build()
    base_tau = args.tau if args.tau is not None else epi.tau
    batch = build_batch(args, base_tau)
    print(f"dataset={args.dataset} scenarios={len(batch)} days={args.days} "
          f"devices={len(jax.devices())}")

    if args.workers > 1:
        mesh = make_hybrid_mesh(args.workers)
        ens = HybridEnsemble(pop, batch, mesh=mesh, backend=args.backend)
        mode = f"hybrid {args.workers}x{int(mesh.shape['scenarios'])}"
    elif args.sharded or len(jax.devices()) > 1:
        ens = ShardedEnsemble(pop, batch, backend=args.backend)
        mode = f"sharded x{len(jax.devices())}"
    else:
        ens = EnsembleSimulator(pop, batch, backend=args.backend)
        mode = "vmap"

    t0 = time.time()
    _, hist = ens.run(args.days)
    wall = time.time() - t0

    rows = summarize_sweep(hist, batch.names, pop.num_people)
    sweep_table(rows)
    edges = float(sum(r["interactions"] for r in rows))
    result = {
        "dataset": args.dataset,
        "mode": mode,
        "scenarios": len(batch),
        "days": args.days,
        "wall_s": round(wall, 2),
        "s_per_scenario_day": round(wall / (args.days * len(batch)), 5),
        "ensemble_teps": round(edges / wall, 1),
        "per_scenario": rows,
    }
    print(json.dumps({k: v for k, v in result.items() if k != "per_scenario"}))

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
