"""Epidemic simulation server driver — the serving tier's front door.

Two modes over one in-process :class:`repro.serve.SimulationServer`:

**Load-generator mode** (default): warm the base spec's bucket, fire a
deterministic concurrent request mix (seeds and replicate widths vary,
the bucket does not), and print/emit the server metrics — the same
closed-loop shape ``benchmarks/bench_serve.py`` measures, usable as a
smoke test: ``--check`` exits non-zero on any steady-state recompile or
failed request.

    PYTHONPATH=src python -m repro.launch.serve_sim \
        --dataset twin-2k --days 10 --requests 8 --concurrency 2 \
        --chunk-days 2 --out serve_metrics.json --check

**HTTP mode** (``--http PORT``): a minimal stdlib server exposing the
tier over a socket — ``POST /run`` with an ExperimentSpec JSON body
returns the RunResult JSON; ``GET /metrics`` returns server metrics.
No extra dependencies; single-process, for demos and local what-if UIs,
not production TLS/auth.

Not to be confused with :mod:`repro.launch.serve`, the LM token-serving
driver.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from concurrent.futures import ThreadPoolExecutor

from repro.launch.cli import (
    add_common_args,
    build_spec,
    parse_intervention_axis,
)
from repro.serve import ServeConfig, SimulationServer

DEFAULTS = dict(
    name="serve-sim", dataset="twin-2k", days=10,
    interventions=("none", "school-closure"),
)


def _int_csv(csv: str, flag: str) -> tuple:
    try:
        return tuple(int(s) for s in csv.split(","))
    except ValueError:
        raise SystemExit(f"error: {flag} must be comma-separated ints, "
                         f"got '{csv}'")


def make_config(args) -> ServeConfig:
    return ServeConfig(
        chunk_days=args.chunk_days,
        b_lattice=_int_csv(args.b_lattice, "--b-lattice"),
        seed_lattice=_int_csv(args.seed_lattice, "--seed-lattice"),
        max_executables=args.max_executables,
        max_wait_s=args.max_wait_ms / 1e3,
        strict=not args.no_strict,
    )


def load_generate(server: SimulationServer, base, requests: int,
                  concurrency: int) -> dict:
    """Closed-loop deterministic load: request i varies the Monte Carlo
    seed and alternates 1/2 replicates (two batch widths, one bucket
    family); `concurrency` clients each keep one request in flight."""
    mix = [base.with_overrides(seed=i + 1, replicates=1 + (i % 2))
           for i in range(requests)]
    tickets = [None] * len(mix)

    def client(worker: int):
        for i in range(worker, len(mix), concurrency):
            ticket = server.submit(mix[i])
            tickets[i] = ticket
            ticket.result(timeout=600)

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=concurrency) as pool:
        for f in [pool.submit(client, w) for w in range(concurrency)]:
            f.result()
    wall = time.perf_counter() - t0
    ttfds = sorted(t.ttfd_s for t in tickets if t.ttfd_s is not None)
    return {
        "requests": requests,
        "concurrency": concurrency,
        "wall_s": round(wall, 3),
        "specs_per_s": round(requests / wall, 3),
        "ttfd_p50_s": round(ttfds[len(ttfds) // 2], 5) if ttfds else None,
    }


def serve_http(server: SimulationServer, port: int):  # pragma: no cover - loop
    """Blocking stdlib HTTP front: POST /run (spec JSON -> result JSON),
    GET /metrics. Ctrl-C to stop."""
    httpd = make_http_server(server, port)
    host, bound = httpd.server_address[:2]
    print(f"serving on http://{host}:{bound}  "
          f"(POST /run, GET /metrics; Ctrl-C stops)", flush=True)
    server.start()
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.server_close()
        server.stop()


def make_http_server(server: SimulationServer, port: int):
    """Build (not run) the stdlib HTTP server — split out so tests can
    bind port 0 and drive it from a thread."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    from repro.api.spec import ExperimentSpec

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):  # quiet
            pass

        def _send(self, code: int, payload: dict):
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path.rstrip("/") == "/metrics":
                self._send(200, server.metrics_dict())
            else:
                self._send(404, {"error": f"unknown path {self.path}"})

        def do_POST(self):
            if self.path.rstrip("/") != "/run":
                self._send(404, {"error": f"unknown path {self.path}"})
                return
            try:
                n = int(self.headers.get("Content-Length", 0))
                spec = ExperimentSpec.from_json(self.rfile.read(n).decode())
                result = server.run(spec, timeout=600)
            except ValueError as e:
                self._send(400, {"error": str(e)})
                return
            except Exception as e:  # noqa: BLE001 - surface as 500
                self._send(500, {"error": str(e)})
                return
            self._send(200, result.to_dict())

    return ThreadingHTTPServer(("127.0.0.1", port), Handler)


def main():
    ap = argparse.ArgumentParser(
        description="epidemic scenario server: warm-cache load generator "
                    "or stdlib HTTP front (see repro.serve)")
    add_common_args(ap)
    ap.add_argument("--interventions", default=None,
                    help="comma list of intervention presets (the bucket's "
                         "slot structure)")
    # serving knobs
    ap.add_argument("--chunk-days", type=int, default=2,
                    help="days per streamed chunk = the one compiled "
                         "day-count per bucket")
    ap.add_argument("--b-lattice", default="2,4,8",
                    help="scenario-width bucket lattice (comma ints)")
    ap.add_argument("--seed-lattice", default="16,64,256",
                    help="seed_per_day cap lattice (comma ints)")
    ap.add_argument("--max-executables", type=int, default=4,
                    help="warm bucket budget (LRU beyond it)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="batching window before a partial dispatch")
    ap.add_argument("--no-strict", action="store_true",
                    help="count steady-state recompiles instead of failing "
                         "the batch")
    # load generator / http
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--concurrency", type=int, default=2)
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve over HTTP instead of running the load "
                         "generator")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on recompile violations or failed "
                         "requests")
    args = ap.parse_args()

    extra = {}
    if args.interventions:
        extra["interventions"] = parse_intervention_axis(args.interventions)
    base = build_spec(args, DEFAULTS, **extra)
    server = SimulationServer(make_config(args))

    if args.http is not None:
        serve_http(server, args.http)
        return

    warm = server.warm_up(base)
    print(f"# warmed {warm['bucket']} in {warm['compile_s']:.2f}s",
          flush=True)
    with server:  # background dispatch thread for the duration of the load
        load = load_generate(server, base, args.requests, args.concurrency)
    metrics = server.metrics_dict()
    report = {"driver": "serve_sim", "spec": base.to_dict(),
              "load": load, "metrics": metrics}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    print(json.dumps({"load": load,
                      "executables": metrics["executables"],
                      "requests": metrics["requests"]}, indent=1))
    if args.check:
        ex = metrics["executables"]
        bad = []
        if ex["recompile_violations"]:
            bad.append(f"{ex['recompile_violations']} recompile violations")
        if metrics["requests"]["failed"]:
            bad.append(f"{metrics['requests']['failed']} failed requests")
        if metrics["requests"]["completed"] < args.requests:
            bad.append("incomplete")
        if bad:
            print(f"# serve_sim check FAILED: {', '.join(bad)}",
                  file=sys.stderr)
            raise SystemExit(1)
        print("# serve_sim check OK: zero steady-state recompiles",
              flush=True)


if __name__ == "__main__":
    main()
