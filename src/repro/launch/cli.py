"""Shared CLI plumbing for the launch drivers.

``launch/simulate.py`` and ``launch/sweep.py`` used to carry near-identical
argparse blocks; the common flags (dataset / disease / backend / seed /
workers / checkpointing / ``--spec``) are defined once here, and both
drivers reduce to: parse flags, build or load an
:class:`~repro.api.ExperimentSpec`, call :func:`repro.api.run`.

Flag semantics with ``--spec``: the spec file is the base, and any flag the
user actually passed overrides the corresponding spec field (all common
flags default to ``None`` = "not given", so spec values survive untouched).
Without ``--spec``, the driver's own defaults fill the gaps.
"""

from __future__ import annotations

import argparse

from repro.api.spec import BACKENDS, ENGINES, ExperimentSpec
from repro.configs.presets import DISEASES, INTERVENTION_PRESETS


def add_common_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The flag set shared by every epidemic launch driver. All defaults
    are ``None`` so :func:`build_spec` can tell "not given" from a value."""
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="experiment spec (.toml or .json); other flags "
                         "override its fields")
    ap.add_argument("--dataset", default=None,
                    help="epidemic dataset name (configs/epidemics.py)")
    ap.add_argument("--disease", default=None, choices=sorted(DISEASES))
    ap.add_argument("--days", type=int, default=None)
    ap.add_argument("--tau", type=float, default=None,
                    help="base transmissibility (default: dataset's)")
    ap.add_argument("--seed", type=int, default=None,
                    help="base Monte Carlo seed (replicate r uses seed+r)")
    ap.add_argument("--replicates", type=int, default=None,
                    help="Monte Carlo replicates (innermost sweep axis)")
    ap.add_argument("--backend", default=None, choices=list(BACKENDS),
                    help="interaction kernel backend")
    ap.add_argument("--engine", default=None, choices=list(ENGINES),
                    help="pin an engine (default: derived from batch x mesh)")
    ap.add_argument("--workers", type=int, default=None,
                    help="people/location-shard each scenario over this "
                         "many devices")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="shard the scenario axis over this many devices")
    ap.add_argument("--static-network", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="EpiHiper-style fixed weekly contact network "
                         "(--no-static-network overrides a spec's true)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (enables day-chunked "
                         "checkpointing + resume)")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="days per checkpoint chunk")
    ap.add_argument("--resilient", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run the chunk loop under the recovery policy "
                         "(failure -> restore newest valid snapshot -> "
                         "bitwise replay; needs --ckpt-dir)")
    ap.add_argument("--max-restarts", type=int, default=None,
                    help="restart cap for the resilient chunk loop")
    ap.add_argument("--out", default=None,
                    help="write the RunResult JSON here")
    return ap


# args attribute -> ExperimentSpec.with_overrides keyword (1:1 names).
COMMON_SPEC_KEYS = (
    "dataset", "disease", "days", "tau", "seed", "replicates", "backend",
    "engine", "workers", "scenarios", "static_network", "ckpt_dir",
    "ckpt_every", "resilient", "max_restarts",
)


def build_spec(args: argparse.Namespace, defaults: dict,
               **extra) -> ExperimentSpec:
    """``--spec`` file (flags override) or a spec built from ``defaults``.

    ``extra`` carries driver-specific overrides (e.g. the parsed
    intervention axis); ``None`` values are ignored like unset flags."""
    try:
        base = (ExperimentSpec.from_file(args.spec) if args.spec
                else ExperimentSpec(**defaults))
        overrides = {k: getattr(args, k) for k in COMMON_SPEC_KEYS}
        overrides.update(extra)
        return base.with_overrides(**overrides)
    except (ValueError, TypeError, KeyError, FileNotFoundError) as e:
        raise SystemExit(f"error: {e}")


def parse_intervention_axis(csv: str) -> tuple:
    """Comma list of preset names -> validated tuple."""
    names = tuple(n.strip() for n in csv.split(",") if n.strip())
    for n in names:
        if n not in INTERVENTION_PRESETS:
            raise SystemExit(
                f"error: unknown intervention preset '{n}'; "
                f"have {sorted(INTERVENTION_PRESETS)}")
    return names


def parse_float_axis(csv: str, flag: str) -> tuple:
    try:
        return tuple(float(s) for s in csv.split(","))
    except ValueError:
        raise SystemExit(
            f"error: {flag} must be comma-separated floats, got '{csv}'")
