"""Jittable step functions + sharding assembly shared by train/serve/dryrun."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, rules, opt_cfg: AdamWConfig, unroll=False):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = M.forward_train(cfg, p, rules, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, rules):
    def prefill_step(params, batch):
        logits, cache = M.forward_prefill(cfg, params, rules, batch)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, rules):
    def decode_step(params, cache, token, pos):
        logits, new_cache = M.decode_step(cfg, params, rules, cache, token, pos)
        # greedy next token (serving semantics)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def train_shardings(cfg, shape, rules, mesh, max_target_positions=0):
    pspecs = M.param_partition_specs(cfg, rules, max_target_positions)
    opt_specs = {"mu": pspecs, "nu": pspecs, "step": P()}
    bspecs = M.batch_partition_specs(cfg, shape, rules)
    in_s = (named(mesh, pspecs), named(mesh, opt_specs), named(mesh, bspecs))
    out_s = (in_s[0], in_s[1], None)
    return in_s, out_s


def decode_shardings(cfg, shape, rules, mesh, cache, max_target_positions=0):
    pspecs = M.param_partition_specs(cfg, rules, max_target_positions)
    cspecs = M.cache_partition_specs(cfg, cache, rules)
    tok_spec = rules.spec((shape.global_batch, 1), ("batch", "seq"))
    in_s = (
        named(mesh, pspecs), named(mesh, cspecs),
        NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()),
    )
    out_s = (NamedSharding(mesh, tok_spec), in_s[1])
    return in_s, out_s


def prefill_shardings(cfg, shape, rules, mesh, cache_abs, max_target_positions=0):
    pspecs = M.param_partition_specs(cfg, rules, max_target_positions)
    bspecs = M.batch_partition_specs(cfg, shape, rules)
    in_s = (named(mesh, pspecs), named(mesh, bspecs))
    return in_s, None


def abstract_opt_state(params_abs):
    return jax.eval_shape(adamw_init, params_abs)
