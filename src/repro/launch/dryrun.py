import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16×16 single-pod / 2×16×16 multi-pod) and extracts the
roofline measurements:

  1. compile the production scanned program  -> memory analysis, proof
  2. compile unrolled 1- and 2-layer variants -> per-layer flops/bytes/
     collective bytes (XLA cost analysis counts loop bodies once; see
     analysis/roofline.py)
  3. write artifacts/dryrun/<arch>_<shape>_<mesh>.json

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--quick]
  python -m repro.launch.dryrun --epidemic md-mini [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
import traceback

import numpy as np
import jax

from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as rf
from repro.configs import ARCHS, LM_SHAPES, get_config, get_shape, supports_shape
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, mesh_num_devices
from repro.models import model as M
from repro.models.sharding import MeshRules
from repro.optim import AdamWConfig

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def _cell_programs(cfg, shape, rules, mesh, *, unroll=False):
    """Build (fn, abstract args, in_shardings, out_shardings, donate)."""
    mtp = shape.seq_len + 8
    params_abs = M.abstract_params(cfg, mtp)
    if shape.kind == "train":
        opt_abs = steps_lib.abstract_opt_state(params_abs)
        batch_abs = M.input_specs(cfg, shape)
        fn = _train_fn(cfg, rules, unroll)
        in_s, out_s = steps_lib.train_shardings(cfg, shape, rules, mesh, mtp)
        return fn, (params_abs, opt_abs, batch_abs), in_s, out_s, (0, 1)
    if shape.kind == "prefill":
        batch_abs = M.input_specs(cfg, shape)
        fn = _prefill_fn(cfg, rules, unroll)
        in_s, out_s = steps_lib.prefill_shardings(cfg, shape, rules, mesh, None, mtp)
        return fn, (params_abs, batch_abs), in_s, out_s, ()
    # decode
    spec = M.input_specs(cfg, shape)
    cache_abs = spec["cache"]
    fn = _decode_fn(cfg, rules, unroll)
    in_s, out_s = steps_lib.decode_shardings(cfg, shape, rules, mesh, cache_abs, mtp)
    args = (params_abs, cache_abs, spec["token"], spec["pos"])
    return fn, args, in_s, out_s, (1,)


def _train_fn(cfg, rules, unroll):
    from repro.optim import adamw_update

    opt_cfg = AdamWConfig()

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return M.forward_train(cfg, p, rules, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {**metrics, **om}

    return train_step


def _prefill_fn(cfg, rules, unroll):
    def prefill(params, batch):
        return M.forward_prefill(cfg, params, rules, batch)

    return prefill


def _decode_fn(cfg, rules, unroll):
    import jax.numpy as jnp

    def decode(params, cache, token, pos):
        logits, c2 = M.decode_step(cfg, params, rules, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], c2

    return decode


def _reduced_layers_cfg(cfg, units: int):
    """Config with `units` unrolled layer-units (family-aware)."""
    if cfg.family == "hybrid":
        pat = len(cfg.block_pattern)
        return dataclasses.replace(cfg, num_layers=units * pat), pat
    if cfg.family == "audio":
        return dataclasses.replace(cfg, num_layers=units, enc_layers=units), 1
    return dataclasses.replace(cfg, num_layers=units), 1


def compile_cell(arch: str, shape_name: str, multi_pod: bool, *,
                 quick: bool = False, overrides=None, cfg_overrides=None,
                 tag_suffix: str = ""):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    ok, why = supports_shape(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if not ok:
        record["skipped"] = why
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_num_devices(mesh)
    rules = MeshRules.for_mesh(mesh, overrides)
    record["chips"] = chips
    record["param_count"] = M.param_count(cfg)
    record["active_param_count"] = M.param_count(cfg, active_only=True)

    # --- 1. production (scanned) compile: THE dry-run proof ---------------
    fn, args, in_s, out_s, donate = _cell_programs(cfg, shape, rules, mesh)
    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_s, out_shardings=out_s,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    record["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 2)
    meas = hlo_lib.measure_compiled(lowered, compiled)
    record["scanned"] = meas
    record["dropped_shardings"] = [
        f"{ax}:{dim}%{size} {why}" for (axes, ax, dim, size, why) in rules.dropped
    ]

    if not quick:
        # --- 2. unrolled 1-/2-unit compiles for per-layer extrapolation ---
        ms = []
        from repro.models.unroll import unroll_mode

        for units in (1, 2):
            cfg_n, pat = _reduced_layers_cfg(cfg, units)
            rules_n = MeshRules.for_mesh(mesh, overrides)
            fn_n, args_n, in_n, out_n, don = _cell_programs(
                cfg_n, shape, rules_n, mesh, unroll=True
            )
            with unroll_mode():
                low = jax.jit(
                    fn_n, in_shardings=in_n, out_shardings=out_n,
                    donate_argnums=don,
                ).lower(*args_n)
            comp = low.compile()
            ms.append(hlo_lib.measure_compiled(None, comp))
        record["m1"], record["m2"] = ms
        corrected = rf.extrapolate_layers(
            ms[0], ms[1], cfg.num_layers,
            layers_per_unit=pat,
        )
        if cfg.attn_impl == "flash":
            # kernel bodies are VMEM-resident and invisible to cost
            # analysis: add exact analytic attention flops (fwd-only —
            # flash is restricted to prefill/decode cells)
            add = rf.analytic_attention_flops(cfg, shape) / chips
            corrected["flops"] += add
            record["flash_analytic_flops_per_chip"] = add
        record["corrected"] = corrected
        mf = rf.model_flops(
            cfg, shape, record["param_count"], record["active_param_count"]
        )
        record["model_flops_global"] = mf
        terms = rf.roofline_from_measurements(corrected, mf, chips)
        record["roofline"] = terms.row()
    return record


def run_epidemic_dryrun(dataset: str, multi_pod: bool):
    """Lower + compile the distributed epidemic day step on the production
    mesh (flattened to 1-D workers)."""
    from repro.configs import get_epidemic
    from repro.core import disease as disease_lib
    from repro.core import transmission as tx
    from repro.engine.core import EngineCore
    from jax.sharding import Mesh

    n = 512 if multi_pod else 256
    mesh = Mesh(np.array(jax.devices()[:n]), ("workers",))
    epi = get_epidemic(dataset)
    pop = epi.build()
    core = EngineCore.single(
        pop, disease_lib.covid_model(), tx.TransmissionModel(tau=epi.tau),
        seed=epi.seed, layout="workers", mesh=mesh,
    )
    state = core.init_state()
    t0 = time.time()
    # Lower the whole one-day scan program — the distributed day step.
    lowered = core._runner(1, ()).lower(
        core.params, state, (), core.week, core.route
    )
    compiled = lowered.compile()
    meas = hlo_lib.measure_compiled(lowered, compiled)
    rec = {
        "epidemic": dataset, "workers": n,
        "pop": pop.stats(),
        "compile_s": round(time.time() - t0, 2),
        "measured": meas,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="skip the unrolled correction compiles")
    ap.add_argument("--epidemic", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides, e.g. --set attn_impl=chunked")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule overrides, e.g. --rule expert_cap=data"
                         " (value 'none' clears; comma for tuples)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    cfg_overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        cfg_overrides[k] = v

    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        if v == "none":
            rule_overrides[k] = None
        elif "," in v:
            rule_overrides[k] = tuple(v.split(","))
        else:
            rule_overrides[k] = v

    out_dir = args.out or os.path.abspath(ART_DIR)
    os.makedirs(out_dir, exist_ok=True)

    if args.epidemic:
        rec = run_epidemic_dryrun(args.epidemic, args.multi_pod)
        path = os.path.join(
            out_dir, f"epidemic_{args.epidemic}_{rec['workers']}w.json"
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)
        print(json.dumps(rec, indent=1, default=float))
        return

    cells = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        tag = f"{a}_{s}_{'2x16x16' if mp else '16x16'}" + (
            f"_{args.tag}" if args.tag else "")
        path = os.path.join(out_dir, tag + ".json")
        try:
            rec = compile_cell(a, s, mp, quick=args.quick,
                               cfg_overrides=cfg_overrides or None,
                               overrides=rule_overrides or None)
            rec["cfg_overrides"] = cfg_overrides
            rec["rule_overrides"] = {k: str(v) for k, v in rule_overrides.items()}
            if "skipped" in rec:
                n_skip += 1
                print(f"[skip] {tag}: {rec['skipped']}", flush=True)
            else:
                n_ok += 1
                r = rec.get("roofline", {})
                print(
                    f"[ok]   {tag}: compile={rec['compile_s']}s "
                    f"flops/chip={rec['scanned']['flops']:.3g} "
                    f"bottleneck={r.get('bottleneck', '?')} "
                    f"roofline_frac={r.get('roofline_fraction', 0):.3f}",
                    flush=True,
                )
        except Exception as e:
            n_fail += 1
            rec = {"arch": a, "shape": s, "mesh": tag, "error": repr(e),
                   "traceback": traceback.format_exc()}
            print(f"[FAIL] {tag}: {e!r}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
