"""Mesh construction for the production topology.

Functions, not module-level constants — importing this module never touches
jax device state (device count is locked on first jax init, and smoke tests
must see 1 device while the dry-run sees 512).
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 chips per pod (TPU v5e); 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_worker_mesh(num_workers: int | None = None) -> Mesh:
    """Flattened 1-D mesh for the epidemic engine (people/location
    partitions don't distinguish pod/data/model — workers are workers,
    as in the paper's flat rank space)."""
    devs = np.array(jax.devices() if num_workers is None else jax.devices()[:num_workers])
    return Mesh(devs, ("workers",))


def mesh_num_devices(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
