"""Mesh construction for the production topology.

Functions, not module-level constants — importing this module never touches
jax device state (device count is locked on first jax init, and smoke tests
must see 1 device while the dry-run sees 512).
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 chips per pod (TPU v5e); 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_worker_mesh(num_workers: int | None = None) -> Mesh:
    """Flattened 1-D mesh for the epidemic engine (people/location
    partitions don't distinguish pod/data/model — workers are workers,
    as in the paper's flat rank space)."""
    devs = np.array(jax.devices() if num_workers is None else jax.devices()[:num_workers])
    return Mesh(devs, ("workers",))


def make_scenario_mesh(num_devices: int | None = None) -> Mesh:
    """1-D mesh for scenario-sharded ensembles (axis 'scenarios')."""
    devs = jax.devices() if num_devices is None else jax.devices()[:num_devices]
    return Mesh(np.array(devs), ("scenarios",))


def make_hybrid_mesh(
    num_workers: int, num_scenarios: int | None = None
) -> Mesh:
    """2-D (workers x scenarios) mesh for hybrid ensembles: each scenario's
    population is people/location-sharded over ``num_workers`` devices while
    the scenario axis is sharded over the remaining factor. With
    ``num_scenarios`` omitted, all visible devices are used
    (num_scenarios = num_devices // num_workers)."""
    devs = jax.devices()
    if num_scenarios is None:
        num_scenarios = max(1, len(devs) // num_workers)
    n = num_workers * num_scenarios
    if n > len(devs):
        raise ValueError(
            f"hybrid mesh {num_workers}x{num_scenarios} needs {n} devices, "
            f"have {len(devs)}"
        )
    return Mesh(
        np.array(devs[:n]).reshape(num_workers, num_scenarios),
        ("workers", "scenarios"),
    )


def mesh_num_devices(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
