"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --preset smoke --steps 100 --ckpt-dir /tmp/ckpt

Features: any registered arch (reduced presets for CPU), AdamW + cosine
schedule, deterministic synthetic data pipeline, checkpoint/restart
(restart-exact), fault-tolerant step loop with injected-failure testing
(--inject-failures), straggler tracking, optional int8 cross-pod gradient
compression (--grad-compression int8; engaged when the mesh has a 'pod'
axis).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data.tokens import TokenPipeline
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime import FaultConfig, FaultTolerantLoop


def build_cfg(args):
    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = reduced_config(cfg)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
    elif args.preset == "small100m":
        # ~100M-class config in the same family (example driver target)
        cfg = dataclasses.replace(
            cfg, num_layers=min(cfg.num_layers, 8), d_model=512,
            num_heads=8, num_kv_heads=max(1, min(cfg.num_kv_heads, 4)),
            head_dim=64, d_ff=2048, vocab_size=min(cfg.vocab_size, 32768),
            num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
            compute_dtype="float32",
        )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "small100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps at which to simulate a crash")
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = build_cfg(args)
    print(f"arch={cfg.name} params={M.param_count(cfg):,}")
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, schedule=cosine_schedule(20, args.steps))

    params = M.init_params(cfg, jax.random.key(args.seed),  # detlint: ignore[DET001] — keyed LM init
                           max_target_positions=args.seq + 8)
    opt_state = adamw_init(params)

    def make_batch(step):
        toks = jnp.asarray(pipe.batch(step))
        if cfg.family == "audio":
            return {"tokens": toks,
                    "frames": jnp.zeros((args.batch, cfg.enc_frames, cfg.d_model),
                                        jnp.float32)}
        if cfg.family == "vlm":
            return {"tokens": toks[:, : args.seq - cfg.num_patches],
                    "patch_embeds": jnp.zeros(
                        (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)}
        return {"tokens": toks}

    @jax.jit
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return M.forward_train(cfg, p, None, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om}

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and mgr and mgr.latest_step() is not None:
        start = mgr.latest_step()
        like = {"params": params, "opt": opt_state}
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), like)
        restored = mgr.restore(like, start)
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    inject = {int(s) for s in args.inject_failures.split(",") if s}
    injected = set()
    holder = {"params": params, "opt": opt_state, "losses": []}

    def step_fn(step):
        if step in inject and step not in injected:
            injected.add(step)
            raise RuntimeError(f"injected failure at step {step}")
        batch = make_batch(step)
        holder["params"], holder["opt"], metrics = train_step(
            holder["params"], holder["opt"], batch
        )
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            holder["losses"].append((step, loss))
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return step + 1

    def save_fn(step, _):
        if mgr:
            mgr.save(step, {"params": holder["params"], "opt": holder["opt"]})

    def restore_fn():
        assert mgr, "failure injected but no --ckpt-dir for recovery"
        step = mgr.latest_step() or 0
        if mgr.latest_step() is not None:
            like = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                {"params": holder["params"], "opt": holder["opt"]},
            )
            restored = mgr.restore(like, step)
            holder["params"], holder["opt"] = restored["params"], restored["opt"]
        print(f"[recovery] restored step {step}", flush=True)
        return step, step

    if mgr:
        mgr.save(0, {"params": params, "opt": opt_state}, blocking=True)
    loop = FaultTolerantLoop(
        step_fn, save_fn, restore_fn,
        FaultConfig(checkpoint_interval=args.ckpt_every, max_restarts=8),
    )
    t0 = time.time()
    loop.run(start, start, args.steps - start)
    wall = time.time() - t0
    if mgr:
        mgr.wait()
    losses = holder["losses"]
    print(json.dumps({
        "arch": cfg.name, "steps": args.steps, "wall_s": round(wall, 1),
        "first_loss": losses[0][1] if losses else None,
        "final_loss": losses[-1][1] if losses else None,
        "restarts": loop.stats.restarts,
        "checkpoints": loop.stats.checkpoints,
    }))


if __name__ == "__main__":
    main()
