"""Epidemic simulation driver (the paper-kind end-to-end entry point).

    PYTHONPATH=src python -m repro.launch.simulate --dataset md-mini \
        --days 200 --tau 8e-6 --ckpt-dir /tmp/epi --replicates 1

Distributed mode engages automatically when multiple JAX devices are
visible (XLA_FLAGS=--xla_force_host_platform_device_count=8 to emulate).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_epidemic
from repro.core import disease as disease_lib
from repro.core import interventions as iv
from repro.core import simulator, simulator_dist, transmission
from repro.launch.mesh import make_worker_mesh

DISEASES = {
    "covid": disease_lib.covid_model,
    "sir": disease_lib.sir_model,
    "seir": disease_lib.seir_model,
}

INTERVENTION_PRESETS = {
    "none": [],
    "school-closure": [iv.Intervention(
        "close-schools", iv.CaseThreshold(on=100), iv.LocTypeIs(2),
        iv.CloseLocations(),
    )],
    "vax-seniors": [iv.Intervention(
        "vaccinate-seniors", iv.DayRange(14), iv.AgeGroupIs(2),
        iv.Vaccinate(0.85),
    )],
    "lockdown": [iv.Intervention(
        "lockdown", iv.CaseThreshold(on=500, off=100),
        iv.RandomFraction(0.8, salt=3), iv.Isolate(),
    )],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="twin-2k")
    ap.add_argument("--days", type=int, default=100)
    ap.add_argument("--tau", type=float, default=None)
    ap.add_argument("--disease", default="covid", choices=sorted(DISEASES))
    ap.add_argument("--interventions", default="none",
                    choices=sorted(INTERVENTION_PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicates", type=int, default=1)
    ap.add_argument("--static-network", action="store_true")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "scan", "compact", "pallas"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    epi = get_epidemic(args.dataset)
    pop = epi.build()
    print(f"dataset={args.dataset} {pop.stats()}")
    tau = args.tau if args.tau is not None else epi.tau
    tm = transmission.TransmissionModel(tau=tau)
    dz = DISEASES[args.disease]()
    ivs = INTERVENTION_PRESETS[args.interventions]

    results = []
    for rep in range(args.replicates):
        seed = args.seed + rep
        t0 = time.time()
        if args.distributed or len(jax.devices()) > 1:
            mesh = make_worker_mesh()
            sim = simulator_dist.DistSimulator(
                pop, dz, mesh, tm, interventions=ivs, seed=seed,
                static_network=args.static_network, backend=args.backend,
            )
            state, hist = sim.run(args.days)
        else:
            sim = simulator.EpidemicSimulator(
                pop, dz, tm, interventions=ivs, seed=seed,
                static_network=args.static_network, backend=args.backend,
            )
            state = sim.init_state()
            mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
            hists = []
            day = 0
            while day < args.days:
                n = min(args.ckpt_every, args.days - day)
                state, h = sim.run(n, state)
                hists.append(h)
                day += n
                if mgr:
                    mgr.save(day, sim.checkpoint_payload(state))
            if mgr:
                mgr.wait()
            hist = {k: np.concatenate([h[k] for h in hists]) for k in hists[0]}
        wall = time.time() - t0
        results.append({
            "replicate": rep,
            "cumulative": int(hist["cumulative"][-1]),
            "peak_infectious": int(hist["infectious"].max()),
            "peak_day": int(np.argmax(hist["infectious"])),
            "interactions": int(np.asarray(hist["contacts"], np.int64).sum()),
            "wall_s": round(wall, 2),
            "s_per_day": round(wall / args.days, 4),
        })
        print(json.dumps(results[-1]), flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
