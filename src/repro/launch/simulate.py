"""Epidemic simulation driver — a thin wrapper over ``repro.api.run``.

    PYTHONPATH=src python -m repro.launch.simulate --spec examples/experiment.toml
    PYTHONPATH=src python -m repro.launch.simulate --dataset md-mini \
        --days 200 --tau 8e-6 --ckpt-dir /tmp/epi --replicates 3

The flags build (or, with ``--spec``, override) a declarative
:class:`~repro.api.ExperimentSpec`; engine selection, checkpoint/resume,
and observables all live behind the facade. Distributed mode engages
automatically when multiple JAX devices are visible
(XLA_FLAGS=--xla_force_host_platform_device_count=8 to emulate), or
explicitly via ``--workers``/``--distributed``.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro import api
from repro.configs.presets import (  # noqa: F401  (legacy import path)
    DISEASES,
    INTERVENTION_PRESETS,
)
from repro.launch import cli


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    cli.add_common_args(ap)
    ap.add_argument("--interventions", default=None,
                    choices=sorted(INTERVENTION_PRESETS),
                    help="single intervention preset for this run")
    ap.add_argument("--distributed", action="store_true",
                    help="force people/location sharding over all devices")
    args = ap.parse_args()

    extra = {}
    if args.interventions is not None:
        extra["interventions"] = (args.interventions,)
    # Auto-distribute over visible devices — but never behind a --spec's
    # back: a spec's declared mesh wins unless a flag explicitly overrides.
    if args.workers is None and (
        args.distributed or (args.spec is None and len(jax.devices()) > 1)
    ):
        extra["workers"] = len(jax.devices())

    spec = cli.build_spec(args, dict(
        name="simulate", days=100, interventions=("none",), replicates=1,
    ), **extra)

    result = api.run(spec)
    print(f"dataset={result.spec.dataset} engine={result.provenance['engine']} "
          f"scenarios={result.num_scenarios} days={result.days}")
    for row in result.summaries:
        print(json.dumps(row), flush=True)
    print(json.dumps({k: result.provenance[k]
                      for k in ("engine", "wall_s", "chunks",
                                "resumed_from_day")}))
    if "resilience" in result.provenance:
        print(json.dumps({"resilience": result.provenance["resilience"]}))
    if args.out:
        result.save(args.out)


if __name__ == "__main__":
    main()
