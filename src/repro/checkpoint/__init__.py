from repro.checkpoint.manager import CheckpointManager, flatten_tree  # noqa: F401
