from repro.checkpoint.manager import (  # noqa: F401
    CheckpointCorruptionError,
    CheckpointManager,
    flatten_tree,
    leaf_digest,
)
