"""Sharded checkpointing with elastic restore and integrity verification.

Design (offline-friendly; tensorstore is unavailable):

  * A checkpoint is a directory: ``manifest.json`` + one ``.npy`` per
    pytree leaf (flattened key paths). Arrays are gathered per-leaf and
    written with numpy — at laptop scale this is exact; on a real cluster
    the same layout extends to per-shard files (manifest records the
    intended PartitionSpec for each leaf).
  * **Integrity**: the manifest records each leaf's shape, dtype, and
    SHA-256 digest. Every read path (``restore``/``restore_flat``/
    ``verify``) re-checks the bytes it loads against the manifest and
    raises :class:`CheckpointCorruptionError` naming the offending leaf —
    a truncated, bit-flipped, or missing ``.npy`` never unflattens into a
    state pytree. ``latest_valid_step`` walks snapshots newest-first,
    quarantining corrupt ones (moved under ``quarantine/``) so a resume
    falls back to the next-older valid step instead of crashing.
  * **Elastic restore**: leaves are loaded as host numpy and re-placed with
    ``jax.device_put`` under the *current* mesh's shardings — restoring a
    512-chip checkpoint onto 256 chips (or 8 CPU workers) is the same code
    path. Combined with counter-based RNG (core/rng.py), restart is
    bitwise-exact regardless of the new topology.
  * Writes are atomic (tmp dir + rename) and asynchronous (background
    thread) so the step loop isn't blocked; ``wait()`` joins outstanding
    writes and **re-raises** any exception the writer hit (disk full,
    permissions) — a failed background write is surfaced at the next
    ``save()``/``wait()``/read, never silently dropped. Readers
    (``latest_step``/``restore``/``restore_flat``/``manifest``) join the
    in-flight writer first, so they never race a half-written snapshot.
    Retention keeps the newest K checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np
import jax


class CheckpointCorruptionError(RuntimeError):
    """A snapshot failed integrity verification (truncated/bit-flipped/
    missing leaf file, or a leaf disagreeing with its manifest entry)."""


def flatten_tree(tree) -> dict[str, Any]:
    """Flatten a pytree to {key-path: leaf}, the on-disk leaf naming.

    Dict keys, dataclass field names, and sequence indices all become path
    segments joined with ``/`` — the same keys ``restore_flat`` returns, so
    callers can round-trip arbitrary pytrees (state dataclasses, observable
    carries, history dicts) through one checkpoint."""
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


_flatten = flatten_tree


def leaf_digest(arr: np.ndarray) -> str:
    """SHA-256 over a leaf's raw bytes (C-contiguous)."""
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


class CheckpointManager:
    QUARANTINE = "quarantine"

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._write_exc: Optional[BaseException] = None
        #: steps moved aside by :meth:`quarantine` over this manager's
        #: lifetime (the resilience report reads this).
        self.quarantined_steps: list[int] = []

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None,
             blocking: bool = False):
        """Snapshot `tree` at `step`. Gathers to host, then writes in a
        background thread (double-buffered: we wait for the previous write,
        re-raising its exception if it failed)."""
        self.wait()
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "sha256": leaf_digest(v)}
                       for k, v in host.items()},
        }

        def write():
            tmp = os.path.join(self.directory, f".tmp-{step}")
            final = os.path.join(self.directory, f"step-{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            for k, v in host.items():
                np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), v)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            def guarded():
                try:
                    write()
                except BaseException as e:  # surfaced at the next wait()
                    self._write_exc = e

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def wait(self):
        """Join the in-flight background write; re-raise its exception if
        it failed (once — the error is cleared after being surfaced)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._write_exc is not None:
            exc, self._write_exc = self._write_exc, None
            raise RuntimeError(
                f"background checkpoint write failed in {self.directory}"
            ) from exc

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        # NOTE: no wait() here — the background writer itself calls
        # all_steps() via _gc(), and a thread must not join itself.
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step-"):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        self.wait()  # a reader never races the in-flight writer
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- integrity ----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step-{step:010d}")

    def _load_leaf(self, step: int, key: str, entry: dict) -> np.ndarray:
        """Load one leaf and verify it against its manifest entry."""
        path = os.path.join(self._step_dir(step), key.replace("/", "__") + ".npy")
        try:
            arr = np.load(path)
        except FileNotFoundError as e:
            raise CheckpointCorruptionError(
                f"step {step}: leaf '{key}' is missing ({path})") from e
        except Exception as e:  # truncated/garbled .npy header or payload
            raise CheckpointCorruptionError(
                f"step {step}: leaf '{key}' is unreadable "
                f"({type(e).__name__}: {e})") from e
        if list(arr.shape) != list(entry.get("shape", arr.shape)):
            raise CheckpointCorruptionError(
                f"step {step}: leaf '{key}' has shape {list(arr.shape)}, "
                f"manifest says {entry['shape']}")
        if str(arr.dtype) != entry.get("dtype", str(arr.dtype)):
            raise CheckpointCorruptionError(
                f"step {step}: leaf '{key}' has dtype {arr.dtype}, "
                f"manifest says {entry['dtype']}")
        want = entry.get("sha256")  # absent in pre-integrity checkpoints
        if want is not None and leaf_digest(arr) != want:
            raise CheckpointCorruptionError(
                f"step {step}: leaf '{key}' failed its SHA-256 digest check "
                "(bit-flip or partial write)")
        return arr

    def verify(self, step: int) -> list[str]:
        """Integrity-check every leaf of a snapshot against its manifest.
        Returns a list of problems (empty = valid); never raises for
        corruption."""
        problems = []
        try:
            meta = self.manifest(step)
        except (CheckpointCorruptionError, FileNotFoundError) as e:
            return [str(e)]
        for k, entry in meta.get("leaves", {}).items():
            try:
                self._load_leaf(step, k, entry)
            except CheckpointCorruptionError as e:
                problems.append(str(e))
        return problems

    def quarantine(self, step: int) -> str:
        """Move a (corrupt) snapshot aside under ``quarantine/`` so it is
        never restored from again, keeping the bytes for post-mortems."""
        qdir = os.path.join(self.directory, self.QUARANTINE)
        os.makedirs(qdir, exist_ok=True)
        dst = os.path.join(qdir, f"step-{step:010d}")
        if os.path.exists(dst):  # re-quarantine of a rewritten step
            dst = f"{dst}.{int(time.time() * 1e6)}"
        os.rename(self._step_dir(step), dst)
        self.quarantined_steps.append(int(step))
        return dst

    def latest_valid_step(self, quarantine: bool = True) -> Optional[int]:
        """Newest step that passes :meth:`verify`, walking older snapshots
        as corrupt ones are found (and, by default, quarantining those).
        Returns None when no valid snapshot remains."""
        self.wait()
        for step in reversed(self.all_steps()):
            if not self.verify(step):
                return step
            if quarantine:
                self.quarantine(step)
        return None

    # -- restore ------------------------------------------------------------
    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Any:
        """Restore into the structure of `tree_like` (arrays or
        ShapeDtypeStructs). If `shardings` (a matching pytree of
        NamedSharding) is given, leaves are placed sharded — this is the
        elastic path: the stored topology is irrelevant.

        Every leaf is verified against the manifest (shape, dtype,
        SHA-256) as it is loaded; a corrupt or missing leaf raises
        :class:`CheckpointCorruptionError` naming it, instead of failing
        deep inside ``tree_unflatten``."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        meta = self.manifest(step)
        flat_like = _flatten(tree_like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for k, like in flat_like.items():
            entry = meta.get("leaves", {}).get(k)
            if entry is None:
                raise CheckpointCorruptionError(
                    f"step {step}: leaf '{k}' requested by the restore "
                    "template is not in the manifest")
            arr = self._load_leaf(step, k, entry)
            expect = tuple(like.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"{k}: checkpoint {arr.shape} != expected {expect}")
            if k in flat_sh and flat_sh[k] is not None:
                loaded[k] = jax.device_put(arr, flat_sh[k])
            else:
                loaded[k] = jax.numpy.asarray(arr)
        # Rebuild the tree in original structure.
        leaves_order = list(_flatten(tree_like).keys())
        treedef = jax.tree.structure(tree_like)
        return jax.tree.unflatten(treedef, [loaded[k] for k in leaves_order])

    def restore_flat(self, step: Optional[int] = None) -> dict[str, np.ndarray]:
        """Load every leaf of a checkpoint as host numpy, keyed by the
        flattened key path (see :func:`flatten_tree`). Unlike ``restore``
        this needs no like-tree, so it also recovers leaves whose shapes
        are unknowable before reading (e.g. a day-chunked run's
        history-so-far, whose day axis length lives in the manifest).
        Leaves are digest-verified as they load."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        meta = self.manifest(step)
        return {
            k: self._load_leaf(step, k, entry)
            for k, entry in meta["leaves"].items()
        }

    def manifest(self, step: Optional[int] = None) -> dict:
        self.wait()
        step = step if step is not None else self.latest_step()
        path = os.path.join(self._step_dir(step), "manifest.json")
        try:
            with open(path) as f:
                return json.load(f)
        except FileNotFoundError:
            raise
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointCorruptionError(
                f"step {step}: manifest.json is unreadable "
                f"({type(e).__name__}: {e})") from e
