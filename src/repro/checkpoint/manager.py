"""Sharded checkpointing with elastic restore.

Design (offline-friendly; tensorstore is unavailable):

  * A checkpoint is a directory: ``manifest.json`` + one ``.npy`` per
    pytree leaf (flattened key paths). Arrays are gathered per-leaf and
    written with numpy — at laptop scale this is exact; on a real cluster
    the same layout extends to per-shard files (manifest records the
    intended PartitionSpec for each leaf).
  * **Elastic restore**: leaves are loaded as host numpy and re-placed with
    ``jax.device_put`` under the *current* mesh's shardings — restoring a
    512-chip checkpoint onto 256 chips (or 8 CPU workers) is the same code
    path. Combined with counter-based RNG (core/rng.py), restart is
    bitwise-exact regardless of the new topology.
  * Writes are atomic (tmp dir + rename) and asynchronous (background
    thread) so the step loop isn't blocked; ``wait()`` joins outstanding
    writes. Retention keeps the newest K checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np
import jax


def flatten_tree(tree) -> dict[str, Any]:
    """Flatten a pytree to {key-path: leaf}, the on-disk leaf naming.

    Dict keys, dataclass field names, and sequence indices all become path
    segments joined with ``/`` — the same keys ``restore_flat`` returns, so
    callers can round-trip arbitrary pytrees (state dataclasses, observable
    carries, history dicts) through one checkpoint."""
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


_flatten = flatten_tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None,
             blocking: bool = False):
        """Snapshot `tree` at `step`. Gathers to host, then writes in a
        background thread (double-buffered: we wait for the previous write)."""
        self.wait()
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = {
            "step": int(step),
            "time": time.time(),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
        }

        def write():
            tmp = os.path.join(self.directory, f".tmp-{step}")
            final = os.path.join(self.directory, f"step-{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            for k, v in host.items():
                np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), v)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step-"):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Any:
        """Restore into the structure of `tree_like` (arrays or
        ShapeDtypeStructs). If `shardings` (a matching pytree of
        NamedSharding) is given, leaves are placed sharded — this is the
        elastic path: the stored topology is irrelevant."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step-{step:010d}")
        flat_like = _flatten(tree_like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for k, like in flat_like.items():
            arr = np.load(os.path.join(d, k.replace("/", "__") + ".npy"))
            expect = tuple(like.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"{k}: checkpoint {arr.shape} != expected {expect}")
            if k in flat_sh and flat_sh[k] is not None:
                loaded[k] = jax.device_put(arr, flat_sh[k])
            else:
                loaded[k] = jax.numpy.asarray(arr)
        # Rebuild the tree in original structure.
        leaves_order = list(_flatten(tree_like).keys())
        treedef = jax.tree.structure(tree_like)
        return jax.tree.unflatten(treedef, [loaded[k] for k in leaves_order])

    def restore_flat(self, step: Optional[int] = None) -> dict[str, np.ndarray]:
        """Load every leaf of a checkpoint as host numpy, keyed by the
        flattened key path (see :func:`flatten_tree`). Unlike ``restore``
        this needs no like-tree, so it also recovers leaves whose shapes
        are unknowable before reading (e.g. a day-chunked run's
        history-so-far, whose day axis length lives in the manifest)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step-{step:010d}")
        meta = self.manifest(step)
        return {
            k: np.load(os.path.join(d, k.replace("/", "__") + ".npy"))
            for k in meta["leaves"]
        }

    def manifest(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(
            self.directory, f"step-{step:010d}", "manifest.json"
        )) as f:
            return json.load(f)
