"""Jitted wrapper: model-layout GQA flash attention.

Takes the model's grouped layout — q (B, Sq, M, G, Dh), k/v (B, Sk, M, Dh)
— flattens (B, M, G) into the kernel's batch axis (k/v indexed per (B, M),
broadcast over G), and calls the Pallas kernel. On non-TPU backends
``interpret=True`` executes the kernel body in Python for validation.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal=True, window=None, blk_q=128,
                    blk_k=128, interpret=True):
    """q: (B, Sq, M, G, Dh); k, v: (B, Sk, M, Dh) -> (B, Sq, M*G, Dh)."""
    B, Sq, M, G, Dh = q.shape
    Sk = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * M * G, Sq, Dh)
    kf = jnp.repeat(
        k.transpose(0, 2, 1, 3).reshape(B * M, Sk, Dh), G, axis=0
    )
    vf = jnp.repeat(
        v.transpose(0, 2, 1, 3).reshape(B * M, Sk, Dh), G, axis=0
    )
    out = flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, interpret=interpret,
    )
    return out.reshape(B, M, G, Sq, Dh).transpose(0, 3, 1, 2, 4).reshape(
        B, Sq, M * G, Dh
    )
