"""Pure-jnp oracle for the flash-attention kernel: naive masked attention
with f32 softmax (same math the kernel performs blockwise)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (BH, Sq, Dh); k, v: (BH, Sk, Dh). Returns (BH, Sq, Dh)."""
    Dh = q.shape[-1]
    scale = scale if scale is not None else Dh**-0.5
    logits = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    Sq, Sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq, dtype=jnp.int32)[:, None] + (Sk - Sq)  # queries end-aligned
    kpos = jnp.arange(Sk, dtype=jnp.int32)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs.astype(q.dtype), v)
