"""Flash-attention forward Pallas kernel (TPU target).

Grid: (BH, Sq/blk_q, Sk/blk_k), row-major in the k-block axis so each
(bh, qi) row streams its k blocks consecutively. Online-softmax running
max / sum / output accumulator live in VMEM scratch; HBM traffic is
exactly Q + K + V + O — the flash contract. Causal and sliding-window
masks are applied **at block granularity first** (`pl.when` skips blocks
entirely above the diagonal or outside the window), then element-wise
inside diagonal blocks — the same two-level skip structure as the
epidemic interaction kernel (block-level short circuit, DESIGN.md §2).

MXU alignment: blk_q/blk_k default 128; Dh ∈ {64, 128, 256} are all
lane-aligned. f32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(meta, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            blk_q: int, blk_k: int, causal: bool, window, scale: float,
            nk: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip: first query position in this q block (absolute),
    # last key position in this k block.
    q_lo = qi * blk_q + q_offset
    q_hi = q_lo + blk_q - 1
    k_lo = ki * blk_k
    k_hi = k_lo + blk_k - 1
    live = True
    if causal:
        live = k_lo <= q_hi  # block not fully above the diagonal
    if window is not None:
        live = live & (k_hi > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (blk_q, blk_k)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), bool)
        if causal:
            mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        m_scr[...] = m_new
        v = v_ref[...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        # detlint: ignore[DET005] — ki == nk-1 holds exactly once per
        # (bh, qi) output block: every o_ref block is written each run.
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret", "scale"),
)
def flash_attention_bhsd(
    q, k, v, *, causal=True, window=None, blk_q=128, blk_k=128,
    scale=None, interpret=True,
):
    """q: (BH, Sq, Dh); k, v: (BH, Sk, Dh); queries end-aligned to keys."""
    BH, Sq, Dh = q.shape
    Sk = k.shape[1]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    nq, nk = Sq // blk_q, Sk // blk_k
    scale = scale if scale is not None else Dh**-0.5
    q_offset = Sk - Sq

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, Dh), lambda b, qi, ki, meta: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, Dh), lambda b, qi, ki, meta: (b, ki, 0)),
            pl.BlockSpec((1, blk_k, Dh), lambda b, qi, ki, meta: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, Dh), lambda b, qi, ki, meta: (b, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, Dh), jnp.float32),
        ],
    )

    kernel = functools.partial(
        _squeeze_kernel,
        blk_q=blk_q, blk_k=blk_k, causal=causal, window=window,
        scale=scale, nk=nk, q_offset=q_offset,
    )
    meta = jnp.zeros((1,), jnp.int32)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        interpret=interpret,
    )(meta, q, k, v)
    return out


def _squeeze_kernel(meta, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                    **kw):
    """Adapter: blocks carry a leading singleton batch dim."""

    class _View:
        def __init__(self, ref):
            self.ref = ref

        def __getitem__(self, idx):
            return self.ref[0] if idx is Ellipsis else self.ref[(0,) + idx]

        def __setitem__(self, idx, val):
            if idx is Ellipsis:
                self.ref[0] = val
            else:
                self.ref[(0,) + idx] = val

        @property
        def dtype(self):
            return self.ref.dtype

    _kernel(
        meta, _View(q_ref), _View(k_ref), _View(v_ref), _View(o_ref),
        m_scr, l_scr, acc_scr, **kw,
    )
