"""Pure-jnp dense oracle for the interaction pass (Algorithm 1, reformulated).

For every ordered pair of visits (i, j) to the same location whose time
windows overlap for T_ij > 0 seconds:

  * the (unordered) pair makes *contact* with probability p_loc — one
    symmetric Bernoulli draw per (day, person-pair, location), counter-based;
  * a contact contributes propensity  T_ij * sus_val_i * inf_val_j  to row
    visit i (the global tau factor is applied by the caller — it is linear).

``sus_val`` is sigma(X_i)*beta_sigma(i) gathered per visit (zero unless the
person is susceptible); ``inf_val`` is iota(X_j)*beta_iota(j) (zero unless
infectious). The product being zero for non- susceptible×infectious pairs is
exactly the paper's optimization (1) in §IV-C2 — here it falls out of the
algebra instead of list bookkeeping.

This O(V^2) dense version is the correctness oracle for the Pallas kernel
and the blocked jnp paths; equivalence to the serial event-queue DES is
argued in DESIGN.md §2 and tested in tests/test_interactions.py against a
literal Python event-queue implementation.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rng


def contact_uniform(seed, day, pid_i, pid_j, loc):
    """Symmetric contact draw: same u for (i, j) and (j, i) at a location."""
    pmin = jnp.minimum(pid_i, pid_j).astype(jnp.uint32)
    pmax = jnp.maximum(pid_i, pid_j).astype(jnp.uint32)
    return rng.uniform(
        seed, rng.CONTACT, day, pmin, pmax, loc.astype(jnp.uint32)
    )


def pair_tile(
    seed,
    day,
    pid_r, loc_r, start_r, end_r, p_r, sus_r,  # row side (susceptible)
    pid_c, loc_c, start_c, end_c, inf_c,  # col side (infectious)
):
    """Compute one (R, C) tile of propensities and contact counts.

    Shared verbatim by the dense oracle, the blocked jnp paths, and the
    Pallas kernel body — a single source of truth for the pair math.
    Returns (rho_rowsum (R,), contact_count_rowsum (R,) int32).
    """
    overlap = jnp.maximum(
        jnp.minimum(end_r[:, None], end_c[None, :])
        - jnp.maximum(start_r[:, None], start_c[None, :]),
        0.0,
    )
    active_r = pid_r >= 0
    active_c = pid_c >= 0
    valid = (
        active_r[:, None]
        & active_c[None, :]
        & (loc_r[:, None] == loc_c[None, :])
        & (pid_r[:, None] != pid_c[None, :])
        & (overlap > 0.0)
    )
    u = contact_uniform(seed, day, pid_r[:, None], pid_c[None, :], loc_r[:, None])
    contact = valid & (u < p_r[:, None])
    rho = overlap * sus_r[:, None] * inf_c[None, :] * contact.astype(jnp.float32)
    cnt = (
        contact & (sus_r[:, None] > 0.0) & (inf_c[None, :] > 0.0)
    ).astype(jnp.int32)
    # Pin the rowsum to int32: under JAX_ENABLE_X64 an int32 sum promotes
    # to int64 (numpy semantics) and would clash with the backends' int32
    # accumulators. A tile rowsum cannot overflow int32; the day step
    # widens to int64 *before* the cross-worker contacts psum (PR 2).
    return rho.sum(axis=1), cnt.sum(axis=1).astype(jnp.int32)


def pair_tile_traced(
    seed,
    day,
    pid_r, loc_r, start_r, end_r, p_r, sus_r,  # row side (susceptible)
    pid_c, loc_c, start_c, end_c, inf_c,  # col side (infectious)
    src_c,  # col side: tracing-source weight (>0 for today's positives)
):
    """`pair_tile` plus the second accumulator: per-row traced-contact
    counts against tracing-*source* columns (contact tracing).

    The tracing condition is a strict subset of the contact-count condition
    (``src_c > 0`` requires ``inf_c > 0`` in practice, and the ``&`` makes
    it so regardless), so tiles that are dead for the exposure accumulator
    are dead for tracing *by algebra* — no extra masking, and the same
    skip/mask bitwise-equality argument the backends rely on carries over.
    Returns (rho_rowsum (R,), cnt_rowsum (R,) i32, trc_rowsum (R,) i32).
    """
    overlap = jnp.maximum(
        jnp.minimum(end_r[:, None], end_c[None, :])
        - jnp.maximum(start_r[:, None], start_c[None, :]),
        0.0,
    )
    active_r = pid_r >= 0
    active_c = pid_c >= 0
    valid = (
        active_r[:, None]
        & active_c[None, :]
        & (loc_r[:, None] == loc_c[None, :])
        & (pid_r[:, None] != pid_c[None, :])
        & (overlap > 0.0)
    )
    u = contact_uniform(seed, day, pid_r[:, None], pid_c[None, :], loc_r[:, None])
    contact = valid & (u < p_r[:, None])
    rho = overlap * sus_r[:, None] * inf_c[None, :] * contact.astype(jnp.float32)
    pair = contact & (sus_r[:, None] > 0.0) & (inf_c[None, :] > 0.0)
    cnt = pair.astype(jnp.int32)
    trc = (pair & (src_c[None, :] > 0.0)).astype(jnp.int32)
    return (
        rho.sum(axis=1),
        cnt.sum(axis=1).astype(jnp.int32),
        trc.sum(axis=1).astype(jnp.int32),
    )


def interactions_dense(
    pid, loc, start, end, p_loc, sus_val, inf_val, seed, day
):
    """Dense all-pairs oracle. Returns (acc (V,), contacts (V,))."""
    return pair_tile(
        seed, day,
        pid, loc, start, end, p_loc, sus_val,
        pid, loc, start, end, inf_val,
    )


def interactions_dense_traced(
    pid, loc, start, end, p_loc, sus_val, inf_val, src_val, seed, day
):
    """Dense oracle with the tracing accumulator.
    Returns (acc (V,), contacts (V,), traced (V,))."""
    return pair_tile_traced(
        seed, day,
        pid, loc, start, end, p_loc, sus_val,
        pid, loc, start, end, inf_val, src_val,
    )
