from repro.kernels.interactions.ops import (  # noqa: F401
    interactions_auto,
    interactions_blocked_jnp,
    interactions_blocked_scan,
    interactions_compact,
    interactions_pallas,
)
from repro.kernels.interactions.ref import interactions_dense  # noqa: F401
