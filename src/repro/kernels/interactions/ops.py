"""Jitted wrappers for the interaction pass.

Six interchangeable implementations, all bitwise-identical in output
(tested against each other and the dense oracle):

  interactions_dense          O(V^2) oracle (ref.py) — tests only.
  interactions_blocked_jnp    vmap over the block-pair schedule; vectorized,
                              no runtime skip — the throughput CPU path at
                              high prevalence.
  interactions_blocked_scan   scan + cond over the schedule; implements the
                              paper's short-circuit (§V-D) with a *runtime*
                              skip — pays one cond per tile, dead or live.
  interactions_compact        the active-set engine: compacts the schedule
                              to the live tiles inside jit (static-shape
                              stable sort) and runs a fori_loop bounded by
                              the *traced* live count, so a day with 0.1%
                              live tiles costs ~0.1% of the tile work.
  interactions_pallas         the TPU kernel (kernel.py); compiled on TPU,
                              interpret mode elsewhere (auto-detected).
  interactions_pallas_compact the fused kernel: the compact backend's
                              schedule compaction feeding the Pallas kernel
                              directly via scalar prefetch, with an
                              in-kernel traversed-edge counter (the
                              measured-TEPS numerator). The TPU analog of
                              `compact` — live-tile-bounded DMA + compute
                              in one launch.

All take the same (V,)-shaped visit arrays (location-sorted, padded with
pid == -1) plus the static BlockSchedule arrays and the two per-block
short-circuit flags (col_has_inf / row_has_sus), and return per-visit
propensity sums (before the global tau factor) and contact counts.

Bitwise equality across backends is structural, not accidental: every
backend accumulates live tiles in the same row-major schedule order, and
dead tiles contribute exact +0.0 (jnp) or are skipped (scan/compact/
pallas) — adding +0.0 to a non-negative f32 is a bitwise no-op, so
skipping and masking produce identical bits.

Contact tracing (PR 7) is a *second accumulator* in the same pass: every
backend takes a keyword-only ``src_val`` — the per-visit tracing-source
weight, >0 for visits by people who tested positive today. When given, the
backend returns a third per-visit output ``trc``: the number of traced
contacts (contact pairs whose column side is a tracing source), sharing the
tiles, schedule compaction and accumulation order of the exposure pass. The
tracing condition is a subset of the contact-count condition, so it is
exactly zero on dead tiles by algebra and inherits the bitwise-equality
contract for free. With ``src_val=None`` (the default) the extra output is
statically compiled out — the traced program is never built, so the
tracing-off path is the pre-PR program, bit for bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.interactions.kernel import (
    interactions_pallas_call,
    interactions_pallas_compact_call,
)
from repro.kernels.interactions.ref import pair_tile, pair_tile_traced


def _block_any_positive(val, pid, num_blocks, block_size):
    flags = ((val > 0.0) & (pid >= 0)).reshape(num_blocks, block_size)
    return jnp.any(flags, axis=1).astype(jnp.int32)


def col_has_infectious(inf_val, pid, num_blocks, block_size):
    """Per column block: does any active visit carry infectivity today?
    This is the runtime input of the short-circuit optimization."""
    return _block_any_positive(inf_val, pid, num_blocks, block_size)


def row_has_susceptible(sus_val, pid, num_blocks, block_size):
    """Per row block: does any active visit carry susceptibility today?
    The symmetric short-circuit flag — early-outbreak days are
    susceptible-heavy (col_has_inf kills most tiles), late days are the
    mirror case (row_has_sus kills them)."""
    return _block_any_positive(sus_val, pid, num_blocks, block_size)


def live_tiles(row_idx, col_idx, pair_active, col_has_inf, row_has_sus):
    """The per-tile liveness predicate shared by every backend: scheduled,
    not padding, and with both an infectious column and susceptible row."""
    return (
        (pair_active == 1)
        & (col_has_inf[col_idx] > 0)
        & (row_has_sus[row_idx] > 0)
    )


def _gather_block(arr, blk, b):
    return jax.lax.dynamic_slice_in_dim(arr, blk * b, b)


@functools.partial(jax.jit, static_argnames=("block_size",))
def interactions_blocked_jnp(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    src_val=None,
):
    b = block_size
    V = pid.shape[0]
    nb = V // b
    seed, day = meta[0], meta[1]

    def one_pair(rb, cb, live):
        rows = [_gather_block(a, rb, b) for a in (pid, loc, start, end, p_loc, sus_val)]
        cols = [_gather_block(a, cb, b) for a in (pid, loc, start, end, inf_val)]
        # Masked (padding or short-circuited) pairs contribute zero; the
        # flops still run — this is the no-skip vectorized variant.
        if src_val is None:
            rho, cnt = pair_tile(seed, day, *rows, *cols)
            return jnp.where(live, rho, 0.0), jnp.where(live, cnt, 0)
        src = _gather_block(src_val, cb, b)
        rho, cnt, trc = pair_tile_traced(seed, day, *rows, *cols, src)
        return (jnp.where(live, rho, 0.0), jnp.where(live, cnt, 0),
                jnp.where(live, trc, 0))

    live = live_tiles(row_idx, col_idx, pair_active, col_has_inf, row_has_sus)
    outs = jax.vmap(one_pair)(row_idx, col_idx, live)
    folded = tuple(
        jax.ops.segment_sum(o, row_idx, num_segments=nb).reshape(V)
        for o in outs
    )
    return folded


@functools.partial(jax.jit, static_argnames=("block_size",))
def interactions_blocked_scan(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    src_val=None,
):
    b = block_size
    V = pid.shape[0]
    seed, day = meta[0], meta[1]

    def _upd(arr, rb, delta):
        return jax.lax.dynamic_update_slice_in_dim(
            arr, jax.lax.dynamic_slice_in_dim(arr, rb * b, b) + delta, rb * b, 0
        )

    def step(carry, sched):
        rb, cb, live = sched

        def body(_):
            rows = [_gather_block(a, rb, b) for a in (pid, loc, start, end, p_loc, sus_val)]
            cols = [_gather_block(a, cb, b) for a in (pid, loc, start, end, inf_val)]
            if src_val is None:
                tile = pair_tile(seed, day, *rows, *cols)
            else:
                src = _gather_block(src_val, cb, b)
                tile = pair_tile_traced(seed, day, *rows, *cols, src)
            return tuple(_upd(a, rb, t) for a, t in zip(carry, tile))

        def skip(_):
            return carry

        # Runtime short circuit: no flops at all for dead tiles — but the
        # scan still visits every tile to evaluate the cond.
        carry = jax.lax.cond(live, body, skip, None)
        return carry, None

    live = live_tiles(row_idx, col_idx, pair_active, col_has_inf, row_has_sus)
    acc0 = jnp.zeros((V,), jnp.float32)
    cnt0 = jnp.zeros((V,), jnp.int32)
    carry0 = (acc0, cnt0) if src_val is None else (
        acc0, cnt0, jnp.zeros((V,), jnp.int32)
    )
    out, _ = jax.lax.scan(step, carry0, (row_idx, col_idx, live))
    return out


@functools.partial(jax.jit, static_argnames=("block_size",))
def interactions_compact(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    src_val=None,
):
    """Active-set backend: per-day work proportional to *live* tiles.

    Inside jit (static shapes throughout), the schedule is compacted with a
    stable argsort on the dead flag — live tiles move to the front keeping
    their row-major order, so accumulation order (and therefore every f32
    bit) matches the jnp/scan backends. A ``fori_loop`` bounded by the
    traced live count then touches only the live prefix: a zero-infectious
    day costs one sort of the (NP,) schedule and no tile math at all. This
    is the paper's §V-D short-circuit realized as wall clock instead of
    masking.
    """
    b = block_size
    V = pid.shape[0]
    seed, day = meta[0], meta[1]

    live = live_tiles(row_idx, col_idx, pair_active, col_has_inf, row_has_sus)
    # Stable partition: live tiles first, original (row-major) order kept.
    order = jnp.argsort(jnp.where(live, 0, 1), stable=True)
    rows_c = row_idx[order]
    cols_c = col_idx[order]
    n_live = live.sum()

    def _upd(arr, rb, delta):
        return jax.lax.dynamic_update_slice_in_dim(
            arr, jax.lax.dynamic_slice_in_dim(arr, rb * b, b) + delta, rb * b, 0
        )

    def body(k, carry):
        rb, cb = rows_c[k], cols_c[k]
        rows = [_gather_block(a, rb, b) for a in (pid, loc, start, end, p_loc, sus_val)]
        cols = [_gather_block(a, cb, b) for a in (pid, loc, start, end, inf_val)]
        if src_val is None:
            tile = pair_tile(seed, day, *rows, *cols)
        else:
            src = _gather_block(src_val, cb, b)
            tile = pair_tile_traced(seed, day, *rows, *cols, src)
        return tuple(_upd(a, rb, t) for a, t in zip(carry, tile))

    acc0 = jnp.zeros((V,), jnp.float32)
    cnt0 = jnp.zeros((V,), jnp.int32)
    carry0 = (acc0, cnt0) if src_val is None else (
        acc0, cnt0, jnp.zeros((V,), jnp.int32)
    )
    return jax.lax.fori_loop(0, n_live, body, carry0)


def interactions_pallas(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    interpret: bool | None = None,
    src_val=None,
):
    """Pallas path. ``interpret=None`` auto-detects: compiled on TPU,
    interpreter everywhere else (the interpreter is the correctness path on
    CPU CI; the compiled kernel is the perf target)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    outs = interactions_pallas_call(
        pid, loc, start, end, p_loc, sus_val, inf_val,
        row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
        meta,
        block_size=block_size, interpret=interpret, src_val=src_val,
    )
    # Row blocks no schedule tile maps to are never written by the kernel
    # (their VMEM output block is never brought in), so their contents are
    # undefined; zero them to honor the shared backend contract. All-padding
    # blocks at the tail of short days hit this.
    nb = pid.shape[0] // block_size
    visited = jnp.zeros((nb,), jnp.int32).at[row_idx].max(
        pair_active.astype(jnp.int32)
    )
    mask = jnp.repeat(visited > 0, block_size)
    return tuple(
        jnp.where(mask, o, jnp.zeros((), o.dtype)) for o in outs
    )


def _pallas_compact_full(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    interpret: bool | None = None,
    src_val=None,
):
    """Fused active-set Pallas path; returns (acc, cnt, edges) — or
    (acc, cnt, trc, edges) when ``src_val`` is given.

    Compaction happens here, inside jit, with the *same* stable sort as
    ``interactions_compact`` — live tiles to the schedule front in original
    row-major order — and the compacted arrays plus the traced live count
    are scalar-prefetched into the kernel, whose grid steps past the live
    prefix clamp their index maps (no DMA, no flops). Accumulation order is
    therefore identical to `compact`, which is identical to `jnp` (dead
    tiles contribute exact +0.0) — bitwise equality by construction.

    ``edges`` is the in-kernel traversed-edge scalar: the sum of contact
    counts over live tiles, i.e. exactly ``cnt.sum()`` of the masked output.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = block_size
    nb = pid.shape[0] // b

    live = live_tiles(row_idx, col_idx, pair_active, col_has_inf, row_has_sus)
    # Stable partition: live tiles first, original (row-major) order kept.
    order = jnp.argsort(jnp.where(live, 0, 1), stable=True)
    rows_c = row_idx[order].astype(jnp.int32)
    cols_c = col_idx[order].astype(jnp.int32)
    n_live = live.sum().astype(jnp.int32).reshape(1)
    # Recompute row-run starts for the compacted order (live tiles of one
    # row block stay consecutive, so a change of row index marks a run).
    prev = jnp.concatenate([rows_c[:1] - 1, rows_c[:-1]])
    row_start_c = (rows_c != prev).astype(jnp.int32)

    outs = interactions_pallas_compact_call(
        pid, loc, start, end, p_loc, sus_val, inf_val,
        rows_c, cols_c, row_start_c, n_live, col_has_inf, row_has_sus,
        meta,
        block_size=block_size, interpret=interpret, src_val=src_val,
    )
    *per_visit, edges = outs
    # Row blocks with no *live* tile are never brought into VMEM, so their
    # output is undefined; zero them (the fused analog of the padded
    # kernel's visited mask — stricter, since liveness implies visited).
    visited = jnp.zeros((nb,), jnp.int32).at[row_idx].max(
        live.astype(jnp.int32)
    )
    mask = jnp.repeat(visited > 0, b)
    masked = tuple(
        jnp.where(mask, o, jnp.zeros((), o.dtype)) for o in per_visit
    )
    return masked + (edges,)


def interactions_pallas_compact(*args, **kwargs):
    """BACKENDS-contract view of the fused kernel: the per-visit outputs
    only — (acc, cnt), plus trc when ``src_val`` is given."""
    *per_visit, _ = _pallas_compact_full(*args, **kwargs)
    return tuple(per_visit)


BACKENDS = {
    "jnp": interactions_blocked_jnp,
    "scan": interactions_blocked_scan,
    "compact": interactions_compact,
    "pallas": interactions_pallas,
    "pallas-compact": interactions_pallas_compact,
}

_PALLAS_BACKENDS = ("pallas", "pallas-compact")


def interactions_auto(*args, backend: str = "jnp", interpret: bool | None = None,
                      **kwargs):
    """Dispatch by backend name.

    'jnp' is the dense-throughput CPU default, 'compact' the active-set
    engine (work ∝ live epidemic activity), 'pallas' the TPU target
    (compiled there, interpret mode elsewhere — override via ``interpret``)
    and 'pallas-compact' the fused active-set kernel (compaction + tile
    math + edge telemetry in one launch).
    """
    if backend in _PALLAS_BACKENDS:
        return BACKENDS[backend](*args, interpret=interpret, **kwargs)
    return BACKENDS[backend](*args, **kwargs)


def interactions_auto_edges(*args, backend: str = "jnp",
                            interpret: bool | None = None, **kwargs):
    """Like ``interactions_auto`` but also returns the traversed-edge count
    (i32 scalar) — the TEPS numerator.

    For 'pallas-compact' the count comes from the in-kernel SMEM
    accumulator; every other backend derives it on the host side as
    ``cnt.sum()``. Both are sums of the same live-tile contact counts, so
    the two routes agree exactly (asserted in tests/test_interactions.py).
    """
    if backend == "pallas-compact":
        return _pallas_compact_full(*args, interpret=interpret, **kwargs)
    if backend == "pallas":
        acc, cnt = BACKENDS[backend](*args, interpret=interpret, **kwargs)
    else:
        acc, cnt = BACKENDS[backend](*args, **kwargs)
    return acc, cnt, cnt.sum().astype(jnp.int32)


def interactions_auto_traced(*args, backend: str = "jnp",
                             interpret: bool | None = None, src_val=None,
                             **kwargs):
    """Traced twin of ``interactions_auto_edges``: runs the interaction
    pass with the second (contact-tracing) accumulator enabled and returns
    ``(acc, cnt, edges, trc)``.

    ``src_val`` is the per-visit tracing-source weight (>0 where the
    visitor tested positive today); ``trc`` is the per-visit count of
    traced contacts, accumulated tile-for-tile alongside ``acc``/``cnt``
    so it is bitwise identical across all five backends. ``edges`` keeps
    its meaning (and, on 'pallas-compact', its in-kernel SMEM route).
    """
    assert src_val is not None
    if backend == "pallas-compact":
        acc, cnt, trc, edges = _pallas_compact_full(
            *args, interpret=interpret, src_val=src_val, **kwargs
        )
        return acc, cnt, edges, trc
    if backend == "pallas":
        acc, cnt, trc = BACKENDS[backend](
            *args, interpret=interpret, src_val=src_val, **kwargs
        )
    else:
        acc, cnt, trc = BACKENDS[backend](*args, src_val=src_val, **kwargs)
    return acc, cnt, cnt.sum().astype(jnp.int32), trc
