"""Jitted wrappers for the interaction pass.

Four interchangeable implementations, all bitwise-identical in output
(tested against each other and the dense oracle):

  interactions_dense        O(V^2) oracle (ref.py) — tests only.
  interactions_blocked_jnp  vmap over the block-pair schedule; vectorized,
                            no runtime skip — the throughput CPU path.
  interactions_blocked_scan scan + cond over the schedule; implements the
                            paper's short-circuit (§V-D) with a *runtime*
                            skip — demonstrates the wall-clock effect of the
                            optimization on CPU (benchmarks/bench_opts.py).
  interactions_pallas       the TPU kernel (kernel.py), interpret=True here.

All take the same (V,)-shaped visit arrays (location-sorted, padded with
pid == -1) plus the static BlockSchedule arrays, and return per-visit
propensity sums (before the global tau factor) and contact counts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.interactions.kernel import interactions_pallas_call
from repro.kernels.interactions.ref import pair_tile


def col_has_infectious(inf_val, pid, num_blocks, block_size):
    """Per column block: does any active visit carry infectivity today?
    This is the runtime input of the short-circuit optimization."""
    flags = ((inf_val > 0.0) & (pid >= 0)).reshape(num_blocks, block_size)
    return jnp.any(flags, axis=1).astype(jnp.int32)


def _gather_block(arr, blk, b):
    return jax.lax.dynamic_slice_in_dim(arr, blk * b, b)


@functools.partial(jax.jit, static_argnames=("block_size",))
def interactions_blocked_jnp(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf,
    meta,
    *,
    block_size: int,
):
    b = block_size
    V = pid.shape[0]
    nb = V // b
    seed, day = meta[0], meta[1]

    def one_pair(rb, cb, active):
        rows = [_gather_block(a, rb, b) for a in (pid, loc, start, end, p_loc, sus_val)]
        cols = [_gather_block(a, cb, b) for a in (pid, loc, start, end, inf_val)]
        rho, cnt = pair_tile(seed, day, *rows, *cols)
        # Masked (padding or short-circuited) pairs contribute zero; the
        # flops still run — this is the no-skip vectorized variant.
        live = (active == 1) & (col_has_inf[cb] > 0)
        return jnp.where(live, rho, 0.0), jnp.where(live, cnt, 0)

    rho_p, cnt_p = jax.vmap(one_pair)(row_idx, col_idx, pair_active)
    acc = jax.ops.segment_sum(rho_p, row_idx, num_segments=nb).reshape(V)
    cnt = jax.ops.segment_sum(cnt_p, row_idx, num_segments=nb).reshape(V)
    return acc, cnt


@functools.partial(jax.jit, static_argnames=("block_size",))
def interactions_blocked_scan(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf,
    meta,
    *,
    block_size: int,
):
    b = block_size
    V = pid.shape[0]
    seed, day = meta[0], meta[1]

    def step(carry, sched):
        acc, cnt = carry
        rb, cb, active = sched

        def live(_):
            rows = [_gather_block(a, rb, b) for a in (pid, loc, start, end, p_loc, sus_val)]
            cols = [_gather_block(a, cb, b) for a in (pid, loc, start, end, inf_val)]
            rho_t, cnt_t = pair_tile(seed, day, *rows, *cols)
            a2 = jax.lax.dynamic_update_slice_in_dim(
                acc, jax.lax.dynamic_slice_in_dim(acc, rb * b, b) + rho_t, rb * b, 0
            )
            c2 = jax.lax.dynamic_update_slice_in_dim(
                cnt, jax.lax.dynamic_slice_in_dim(cnt, rb * b, b) + cnt_t, rb * b, 0
            )
            return a2, c2

        def skip(_):
            return acc, cnt

        # Runtime short circuit: no flops at all for dead tiles.
        carry = jax.lax.cond(
            (active == 1) & (col_has_inf[cb] > 0), live, skip, None
        )
        return carry, None

    acc0 = jnp.zeros((V,), jnp.float32)
    cnt0 = jnp.zeros((V,), jnp.int32)
    (acc, cnt), _ = jax.lax.scan(
        step, (acc0, cnt0), (row_idx, col_idx, pair_active.astype(jnp.int32))
    )
    return acc, cnt


def interactions_pallas(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf,
    meta,
    *,
    block_size: int,
    interpret: bool = True,
):
    return interactions_pallas_call(
        pid, loc, start, end, p_loc, sus_val, inf_val,
        row_idx, col_idx, row_start, pair_active, col_has_inf, meta,
        block_size=block_size, interpret=interpret,
    )


BACKENDS = {
    "jnp": interactions_blocked_jnp,
    "scan": interactions_blocked_scan,
    "pallas": interactions_pallas,
}


def interactions_auto(*args, backend: str = "jnp", **kwargs):
    """Dispatch by backend name; 'jnp' is the CPU default, 'pallas' the TPU
    target (interpret=True when not on TPU)."""
    return BACKENDS[backend](*args, **kwargs)
