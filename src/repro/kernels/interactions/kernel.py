"""Pallas TPU kernel for the interaction pass.

Design (DESIGN.md §2): visits are presorted by location, so same-location
pairs live in a block-diagonal band. The host builds a static *block-pair
schedule* — the (row_block, col_block) tiles containing at least one
same-location pair — and the kernel runs a 1-D grid over that schedule,
streaming column tiles against each row tile and accumulating per-row-visit
propensity sums in VMEM (FlashAttention-style: O(block) memory, no (V, V)
materialization).

TPU mapping:
  * the (b, b) pair tile is pure VPU element-wise math on f32/u32 — at
    b=256 each tile is 256 KiB of operand loads for ~20*b^2 flops, i.e.
    arithmetic intensity ~b/5 flops/byte, comfortably compute-bound;
  * the counter-based hash RNG (core/rng.py) is 10 u32 VPU ops per pair and
    keeps draws identical to the jnp oracle bit-for-bit;
  * scalar-prefetch feeds the schedule (row/col indices) to the BlockSpec
    index_maps, the standard Pallas block-sparse pattern;
  * the paper's short-circuit optimization (§V-D) becomes a `pl.when` guard
    on a per-column-block "has any infectious visitor today" flag — the
    runtime analog of skipping the DES at locations with no infectious
    visitors, at tile granularity.

Accumulation correctness: the schedule is row-major, so all column tiles of
one row block are consecutive grid steps; the output BlockSpec index is
constant over that run (Pallas keeps the block in VMEM) and `row_start`
flags the first step, which zeroes the accumulators. Padding pairs repeat
the last real pair with pair_active=0 so the output index never regresses.

Two kernels live here:

  _kernel        the PR-3 kernel: grid over the *padded* schedule, dead
                 tiles skipped by `pl.when` (no flops, but one grid step —
                 and one potential DMA pair — per scheduled tile).
  _fused_kernel  the fused active-set kernel ("pallas-compact"): the
                 wrapper compacts the schedule inside jit (the same stable
                 sort as ops.interactions_compact) and scalar-prefetches
                 the *compacted* tile order plus the traced live count.
                 Grid steps past `n_live` clamp their BlockSpec index maps
                 to the last live tile, so the pipeline issues **zero new
                 DMAs** for the dead tail and the body is `pl.when`-skipped:
                 the kernel is bounded by live work even though the grid
                 length is static. It also accumulates a per-day traversed-
                 edge counter (SMEM scalar output) — the measured-TEPS
                 numerator — at zero extra memory traffic.

Double-buffering: Pallas's pipeline machinery overlaps the (b,) visit-block
copies for grid step k+1 with compute for step k automatically; because the
compacted schedule puts all live tiles in a contiguous prefix, every
prefetched block is useful work (the padded schedule wastes prefetch slots
on dead tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interactions.ref import pair_tile, pair_tile_traced


def _kernel(
    # scalar prefetch
    row_idx,      # (NP,) i32
    col_idx,      # (NP,) i32
    row_start,    # (NP,) i32 (bool)
    pair_active,  # (NP,) i32 (bool)
    col_has_inf,  # (NB,) i32 — per column block: any infectious visitor today
    row_has_sus,  # (NB,) i32 — per row block: any susceptible visitor today
    meta,         # (2,) u32: [seed, day]
    # row-side blocks (b,)
    pid_r, loc_r, start_r, end_r, p_r, sus_r,
    # col-side blocks (b,)
    pid_c, loc_c, start_c, end_c, inf_c,
    # Either (acc, cnt) — the plain kernel — or (src_c, acc, cnt, trc):
    # one more col-side input and one more VMEM output for the contact-
    # tracing accumulator. The arity is fixed at trace time by the wrapper,
    # so the untraced program contains no tracing code at all.
    *rest,
):
    if len(rest) == 2:
        src_c, (acc, cnt), trc = None, rest, None
    else:
        src_c, acc, cnt, trc = rest
    k = pl.program_id(0)

    @pl.when(row_start[k] == 1)
    def _zero():
        acc[...] = jnp.zeros_like(acc)
        cnt[...] = jnp.zeros_like(cnt)
        if trc is not None:
            trc[...] = jnp.zeros_like(trc)

    # Short-circuit (paper §V-D) both ways: skip tiles whose column block
    # has no infectious visitors or whose row block has no susceptible
    # visitors; also skip schedule padding.
    @pl.when(
        (pair_active[k] == 1)
        & (col_has_inf[col_idx[k]] > 0)
        & (row_has_sus[row_idx[k]] > 0)
    )
    def _body():
        if src_c is None:
            rho_sum, cnt_sum = pair_tile(
                meta[0], meta[1],
                pid_r[...], loc_r[...], start_r[...], end_r[...], p_r[...], sus_r[...],
                pid_c[...], loc_c[...], start_c[...], end_c[...], inf_c[...],
            )
        else:
            rho_sum, cnt_sum, trc_sum = pair_tile_traced(
                meta[0], meta[1],
                pid_r[...], loc_r[...], start_r[...], end_r[...], p_r[...], sus_r[...],
                pid_c[...], loc_c[...], start_c[...], end_c[...], inf_c[...],
                src_c[...],
            )
            trc[...] += trc_sum
        acc[...] += rho_sum
        cnt[...] += cnt_sum


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "interpret"),
)
def interactions_pallas_call(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    row_idx, col_idx, row_start, pair_active, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    interpret: bool = True,
    src_val=None,
):
    """Launch the kernel. All visit arrays are (V,) with V % block_size == 0;
    schedule arrays are (NP,) / (NB,). Returns (acc (V,), cnt (V,)); with
    ``src_val`` (tracing-source weights), (acc, cnt, trc) — one more
    col-side operand and VMEM output under the same ``pl.when`` guard."""
    V = pid.shape[0]
    b = block_size
    assert V % b == 0
    num_pairs = row_idx.shape[0]

    def row_map(k, row_idx, col_idx, row_start, pair_active, col_has_inf,
                row_has_sus, meta):
        return (row_idx[k],)

    def col_map(k, row_idx, col_idx, row_start, pair_active, col_has_inf,
                row_has_sus, meta):
        return (col_idx[k],)

    row_spec = pl.BlockSpec((b,), row_map)
    col_spec = pl.BlockSpec((b,), col_map)

    traced = src_val is not None
    in_specs = [
        row_spec, row_spec, row_spec, row_spec, row_spec, row_spec,
        col_spec, col_spec, col_spec, col_spec, col_spec,
    ] + ([col_spec] if traced else [])
    out_specs = [row_spec, row_spec] + ([row_spec] if traced else [])
    out_shape = [
        jax.ShapeDtypeStruct((V,), jnp.float32),
        jax.ShapeDtypeStruct((V,), jnp.int32),
    ] + ([jax.ShapeDtypeStruct((V,), jnp.int32)] if traced else [])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(num_pairs,),
        in_specs=in_specs,
        out_specs=out_specs,
    )

    operands = (
        row_idx.astype(jnp.int32),
        col_idx.astype(jnp.int32),
        row_start.astype(jnp.int32),
        pair_active.astype(jnp.int32),
        col_has_inf.astype(jnp.int32),
        row_has_sus.astype(jnp.int32),
        meta.astype(jnp.uint32),
        pid.astype(jnp.int32), loc.astype(jnp.int32),
        start.astype(jnp.float32), end.astype(jnp.float32),
        p_loc.astype(jnp.float32), sus_val.astype(jnp.float32),
        pid.astype(jnp.int32), loc.astype(jnp.int32),
        start.astype(jnp.float32), end.astype(jnp.float32),
        inf_val.astype(jnp.float32),
    ) + ((src_val.astype(jnp.float32),) if traced else ())

    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Fused active-set kernel: compacted schedule + in-kernel edge counter
# ---------------------------------------------------------------------------


def _fused_kernel(
    # scalar prefetch
    rows_c,       # (NP,) i32 — compacted schedule: live tiles first,
    cols_c,       # (NP,) i32   original row-major order preserved
    row_start_c,  # (NP,) i32 (bool) — first tile of each live row-block run
    n_live,       # (1,) i32 — traced live-tile count
    col_has_inf,  # (NB,) i32
    row_has_sus,  # (NB,) i32
    meta,         # (2,) u32: [seed, day]
    # row-side blocks (b,)
    pid_r, loc_r, start_r, end_r, p_r, sus_r,
    # col-side blocks (b,)
    pid_c, loc_c, start_c, end_c, inf_c,
    # Either (acc, cnt, edges) — the plain fused kernel — or
    # (src_c, acc, cnt, trc, edges): one more col-side input and one more
    # VMEM output for the contact-tracing accumulator, under the same
    # pl.when guard. Arity is fixed at trace time, so the tracing-off
    # program is the pre-PR kernel, instruction for instruction.
    *rest,
):
    if len(rest) == 3:
        src_c, (acc, cnt, edges), trc = None, rest, None
    else:
        src_c, acc, cnt, trc, edges = rest
    k = pl.program_id(0)
    live = k < n_live[0]

    @pl.when(k == 0)
    def _zero_edges():
        edges[0, 0] = 0

    @pl.when(live & (row_start_c[k] == 1))
    def _zero():
        acc[...] = jnp.zeros_like(acc)
        cnt[...] = jnp.zeros_like(cnt)
        if trc is not None:
            trc[...] = jnp.zeros_like(trc)

    # The live prefix already satisfies both short-circuit flags (liveness
    # includes them), but the guards stay in the kernel so the fused path
    # keeps the padded kernel's §V-D contract even if a caller hands it an
    # uncompacted schedule.
    @pl.when(
        live
        & (col_has_inf[cols_c[k]] > 0)
        & (row_has_sus[rows_c[k]] > 0)
    )
    def _body():
        if src_c is None:
            rho_sum, cnt_sum = pair_tile(
                meta[0], meta[1],
                pid_r[...], loc_r[...], start_r[...], end_r[...], p_r[...], sus_r[...],
                pid_c[...], loc_c[...], start_c[...], end_c[...], inf_c[...],
            )
        else:
            rho_sum, cnt_sum, trc_sum = pair_tile_traced(
                meta[0], meta[1],
                pid_r[...], loc_r[...], start_r[...], end_r[...], p_r[...], sus_r[...],
                pid_c[...], loc_c[...], start_c[...], end_c[...], inf_c[...],
                src_c[...],
            )
            trc[...] += trc_sum
        acc[...] += rho_sum
        cnt[...] += cnt_sum
        # sus x inf contact pairs traversed in this tile — the TEPS
        # numerator, measured where the work happens. dtype pinned: under
        # x64 jnp.sum widens int32 to int64, which the i32 SMEM ref rejects.
        edges[0, 0] += jnp.sum(cnt_sum, dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "interpret"),
)
def interactions_pallas_compact_call(
    pid, loc, start, end, p_loc, sus_val, inf_val,
    rows_c, cols_c, row_start_c, n_live, col_has_inf, row_has_sus,
    meta,
    *,
    block_size: int,
    interpret: bool = True,
    src_val=None,
):
    """Launch the fused kernel on an already-compacted schedule.

    ``rows_c``/``cols_c`` are the live-tiles-first permutation of the block
    schedule, ``row_start_c`` flags the first tile of each live row run and
    ``n_live`` is the (1,)-shaped traced live count. Returns
    (acc (V,), cnt (V,), edges () i32) — with ``src_val``,
    (acc, cnt, trc, edges); row blocks with no live tile carry
    undefined values (never brought into VMEM) — the ops.py wrapper masks
    them, same rule as the padded kernel.
    """
    V = pid.shape[0]
    b = block_size
    assert V % b == 0
    num_pairs = rows_c.shape[0]

    def _clamp(k, n_live):
        # Steps past the live prefix pin every index map to the last live
        # tile: the pipeline sees an unchanged block index, issues no DMA,
        # and the final output flush writes the last live row's block once.
        return jnp.minimum(k, jnp.maximum(n_live[0] - 1, 0))

    def row_map(k, rows_c, cols_c, row_start_c, n_live, col_has_inf,
                row_has_sus, meta):
        return (rows_c[_clamp(k, n_live)],)

    def col_map(k, rows_c, cols_c, row_start_c, n_live, col_has_inf,
                row_has_sus, meta):
        return (cols_c[_clamp(k, n_live)],)

    def edge_map(k, rows_c, cols_c, row_start_c, n_live, col_has_inf,
                 row_has_sus, meta):
        return (0, 0)

    row_spec = pl.BlockSpec((b,), row_map)
    col_spec = pl.BlockSpec((b,), col_map)
    edge_spec = pl.BlockSpec(
        (1, 1), edge_map, memory_space=pltpu.SMEM
    )

    traced = src_val is not None
    in_specs = [
        row_spec, row_spec, row_spec, row_spec, row_spec, row_spec,
        col_spec, col_spec, col_spec, col_spec, col_spec,
    ] + ([col_spec] if traced else [])
    out_specs = (
        [row_spec, row_spec]
        + ([row_spec] if traced else [])
        + [edge_spec]
    )
    out_shape = (
        [
            jax.ShapeDtypeStruct((V,), jnp.float32),
            jax.ShapeDtypeStruct((V,), jnp.int32),
        ]
        + ([jax.ShapeDtypeStruct((V,), jnp.int32)] if traced else [])
        + [jax.ShapeDtypeStruct((1, 1), jnp.int32)]
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(num_pairs,),
        in_specs=in_specs,
        out_specs=out_specs,
    )

    operands = (
        rows_c.astype(jnp.int32),
        cols_c.astype(jnp.int32),
        row_start_c.astype(jnp.int32),
        n_live.astype(jnp.int32),
        col_has_inf.astype(jnp.int32),
        row_has_sus.astype(jnp.int32),
        meta.astype(jnp.uint32),
        pid.astype(jnp.int32), loc.astype(jnp.int32),
        start.astype(jnp.float32), end.astype(jnp.float32),
        p_loc.astype(jnp.float32), sus_val.astype(jnp.float32),
        pid.astype(jnp.int32), loc.astype(jnp.int32),
        start.astype(jnp.float32), end.astype(jnp.float32),
        inf_val.astype(jnp.float32),
    ) + ((src_val.astype(jnp.float32),) if traced else ())

    *per_visit, edges = pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return tuple(per_visit) + (edges[0, 0],)
