"""Scenario-ensemble engine: B scenarios in one jitted day-loop scan.

The paper's framework exists to evaluate candidate interventions, which
means running *ensembles* — Monte Carlo replicate seeds x intervention
configs x disease-parameter perturbations — not single trajectories. This
package runs a whole :class:`repro.configs.ScenarioBatch` as a single
program:

  * :class:`~repro.sweep.engine.EnsembleSimulator` — vmap-over-scenarios:
    stacks every scenario's ``SimParams`` on a leading batch axis and runs
    one ``lax.scan`` whose body is the vmapped ``day_step``.
  * :class:`~repro.sweep.sharded.ShardedEnsemble` — the device-parallel
    path: shards the batch axis across a 1-D mesh via shard_map (scenarios
    are independent, so there are no collectives in the day loop).
  * :class:`~repro.sweep.hybrid.HybridEnsemble` — the 2-D
    (workers × scenarios) mesh: every scenario is itself people/location-
    sharded (the distributed day step vmapped over stacked ``SimParams``),
    for ensembles whose individual scenarios outgrow one device.

Per-scenario trajectories are bitwise identical to sequential
``EpidemicSimulator`` runs with the same configs (tests/test_sweep.py).

All three classes are deprecated facades over the unified engine core
(:mod:`repro.engine`): one topology-parameterized day-loop scan placed on
a local device, a scenario mesh, or the (workers × scenarios) product.
Prefer :class:`repro.engine.EngineCore` or :func:`repro.api.run`.
"""

from repro.sweep.engine import (  # noqa: F401
    EnsembleSimulator,
    index_params,
    stack_params,
)
from repro.sweep.hybrid import HybridEnsemble  # noqa: F401
from repro.sweep.sharded import ShardedEnsemble  # noqa: F401
