"""vmap-over-scenarios ensemble engine.

``core/simulator.py`` factors the day step into a pure function of
``(static, week, contact_prob, params, state)``; this module stacks B
scenarios' ``SimParams``/``SimState`` pytrees on a leading batch axis and
runs

    lax.scan(vmap(day_step), stacked_state, length=days)

— one jitted program for the whole ensemble, the scenario-axis analog of
the simulator's stacked day-of-week trick. The week structure and contact
probabilities are population-level and shared (broadcast) across the
batch; everything scenario-varying lives in the stacked params.

Per-scenario results are bitwise identical to sequential
``EpidemicSimulator`` runs because both paths trace the *same* day-step
code with the same counter-based draws — vmap only adds a batch dimension.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import interactions as inter_lib
from repro.core import population as pop_lib
from repro.core import simulator as sim_lib


def stack_params(params_list: Sequence) -> object:
    """Stack a list of identically-structured pytrees on a new leading
    batch axis (SimParams -> batched SimParams)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def index_params(batched, i: int):
    """Slice scenario ``i`` back out of a stacked pytree (inverse of
    :func:`stack_params` — see the round-trip test in tests/test_sweep.py)."""
    return jax.tree.map(lambda x: x[i], batched)


def _as_batch(batch) -> ScenarioBatch:
    if isinstance(batch, ScenarioBatch):
        return batch
    return ScenarioBatch.from_scenarios(tuple(batch))


@dataclasses.dataclass
class EnsembleSimulator:
    """Run a ScenarioBatch as one vmapped, jitted day-loop scan.

    All scenarios share the population (and therefore the visit schedule
    and interaction block schedule — compiled once) and the trace-time
    structure validated in ``__post_init__``; everything else varies per
    scenario through the stacked ``SimParams``.
    """

    pop: pop_lib.Population
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    backend: str = "jnp"  # interaction backend: jnp | scan | compact | pallas
    block_size: int = 128
    pack_visits: bool = True  # occupancy-aware schedule packing (smaller NP)

    def __post_init__(self):
        self.batch = _as_batch(self.batch)
        self.week = inter_lib.build_week_data(
            self.pop, self.block_size, pack=self.pack_visits
        )
        self.contact_prob = jnp.asarray(self.pop.contact_prob)

        slots0 = None
        params_list = []
        for s in self.batch:
            slots, params = sim_lib.build_params(
                self.pop, s.disease, s.tm, s.interventions, s.seed,
                seed_per_day=s.seed_per_day, seed_days=s.seed_days,
                static_network=s.static_network, iv_enabled=s.iv_enabled,
            )
            if slots0 is None:
                slots0 = slots
            elif slots != slots0:
                raise ValueError(
                    f"scenario '{s.name}' intervention structure {slots} "
                    f"differs from batch structure {slots0}; ensembles vary "
                    "thresholds/factors/enabled, not slot kinds"
                )
            params_list.append(params)
        self.iv_slots = slots0
        self.params = stack_params(params_list)
        self.static = sim_lib.SimStatic(
            num_people=self.pop.num_people,
            num_locations=self.pop.num_locations,
            iv_slots=self.iv_slots,
            backend=self.backend,
        )

        def scan_fn(params, state, *, days: int):
            step = jax.vmap(
                lambda p, st: sim_lib.day_step(
                    self.static, self.week, self.contact_prob, p, st
                )
            )

            def body(st, _):
                return step(params, st)

            return jax.lax.scan(body, state, None, length=days)

        self._run_scan = jax.jit(scan_fn, static_argnames=("days",))

    # ------------------------------------------------------------------
    @property
    def num_scenarios(self) -> int:
        return len(self.batch)

    def init_state(self) -> sim_lib.SimState:
        """Stacked initial state — leading axis is the scenario axis."""
        states = [
            sim_lib.init_state(s.disease, self.pop.num_people, len(self.iv_slots))
            for s in self.batch
        ]
        return stack_params(states)

    def run(self, days: int, state: Optional[sim_lib.SimState] = None):
        """Run the whole ensemble for ``days`` days in one jitted scan.

        Returns ``(final_state, history)`` where every history array has
        shape ``(days, B)`` (scan's time axis leading, scenario axis
        second) and every final-state leaf has a leading ``(B, ...)`` axis.
        """
        state = state if state is not None else self.init_state()
        final, hist = self._run_scan(self.params, state, days=days)
        return final, jax.device_get(hist)

    def scenario_params(self, i: int):
        """Scenario ``i``'s un-stacked SimParams (round-trip helper)."""
        return index_params(self.params, i)
