"""vmap-over-scenarios ensemble engine (deprecated facade).

``EnsembleSimulator`` is now a thin shim over
``repro.engine.EngineCore(layout="local")``: the engine core runs one
jitted ``lax.scan`` whose body is the vmapped topology-parameterized day
step — the same program every other layout executes, with identity
collectives. Per-scenario results remain bitwise identical to sequential
``EpidemicSimulator`` runs (tests/test_sweep.py, tests/test_engine.py).

``stack_params``/``index_params`` live in :mod:`repro.engine.core` now and
are re-exported here for compatibility.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Union

import jax.numpy as jnp

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import simulator as sim_lib
from repro.engine.core import (  # noqa: F401  (compat re-exports)
    as_batch as _as_batch,
    index_params,
    stack_params,
)


@dataclasses.dataclass
class EnsembleSimulator:
    """Run a ScenarioBatch as one vmapped, jitted day-loop scan.

    Deprecated facade over ``EngineCore(layout="local")`` — all scenarios
    share the population (visit schedule and block schedule compiled
    once) and the trace-time structure; everything else varies per
    scenario through the stacked ``SimParams``.
    """

    pop: object
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    backend: str = "jnp"  # interaction backend: jnp | scan | compact | pallas
    block_size: int = 128
    pack_visits: bool = True  # occupancy-aware schedule packing (smaller NP)

    def __post_init__(self):
        warnings.warn(
            "EnsembleSimulator is a deprecated facade; use "
            "repro.engine.EngineCore(layout='local') or repro.api.run()",
            DeprecationWarning, stacklevel=2,
        )
        from repro.engine import EngineCore

        self._core = EngineCore(
            self.pop, self.batch, layout="local", backend=self.backend,
            block_size=self.block_size, pack_visits=self.pack_visits,
        )
        self.batch = self._core.batch
        self.week = self._core.week_data
        self.contact_prob = jnp.asarray(self.pop.contact_prob)
        self.iv_slots = self._core.iv_slots
        self.params = self._core.params
        self.static = sim_lib.SimStatic(
            num_people=self.pop.num_people,
            num_locations=self.pop.num_locations,
            iv_slots=self.iv_slots,
            backend=self.backend,
        )

    # ------------------------------------------------------------------
    @property
    def num_scenarios(self) -> int:
        return len(self.batch)

    def init_state(self) -> sim_lib.SimState:
        """Stacked initial state — leading axis is the scenario axis."""
        return self._core.init_state()

    def run(self, days: int, state: Optional[sim_lib.SimState] = None):
        """Run the whole ensemble for ``days`` days in one jitted scan.

        Returns ``(final_state, history)`` where every history array has
        shape ``(days, B)`` (scan's time axis leading, scenario axis
        second) and every final-state leaf has a leading ``(B, ...)`` axis.
        """
        final, _, hist, _ = self._core.run_days(days, state=state)
        return final, hist

    def scenario_params(self, i: int):
        """Scenario ``i``'s un-stacked SimParams (round-trip helper)."""
        return index_params(self.params, i)
