"""Hybrid (workers × scenarios) ensembles: 2-D mesh, one jitted scan.

:class:`ShardedEnsemble` shards mutually-independent scenarios over a 1-D
mesh; :class:`~repro.core.simulator_dist.DistSimulator` shards the people
and locations of a *single* run. This module composes the two: a 2-D mesh
with axes ``("workers", "scenarios")`` where every scenario of the batch
is itself people/location-sharded over the worker axis — the workload
shape large intervention studies need once a single scenario outgrows one
device.

Mechanically it is the same move the 1-D engines make, applied twice:
``core/simulator_dist.py:dist_day_step`` is pure in its ``SimParams`` /
``SimState`` pytrees, so stacking B scenarios' params on a leading axis
and vmapping the distributed day step gives a (B-local × worker-sharded)
step whose collectives (the visit/exposure all_to_alls, trigger psums,
seeding all_gather) run over the ``workers`` axis only — scenarios on the
same worker column never communicate. The whole run is one jitted
``lax.scan`` under one ``shard_map`` over the 2-D mesh.

Per-scenario results are bitwise identical to sequential ``DistSimulator``
runs *and* to the single-device ``EnsembleSimulator`` (tests/test_dist.py,
tests/test_sweep.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import compat
from repro.core import simulator as sim_lib
from repro.core import simulator_dist as sd
from repro.sweep import engine as engine_lib
from repro.sweep.sharded import _pad_batch

AXIS_WORKERS = sd.AXIS  # "workers"
AXIS_SCENARIOS = "scenarios"


@dataclasses.dataclass
class HybridEnsemble:
    """Run a ScenarioBatch on a 2-D (workers × scenarios) mesh.

    Every scenario is people/location-sharded over the ``workers`` axis
    (same partition plan for all scenarios — they share the population and
    therefore the visit schedule and exchange routing), and the batch axis
    is sharded over the ``scenarios`` axis. The batch is padded (by
    repeating the final scenario) to a multiple of the scenario-axis size;
    padding scenarios are dropped from results.
    """

    pop: object
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    mesh: Mesh = None
    backend: str = "jnp"  # interaction backend: jnp | scan | compact | pallas
    block_size: int = 128
    balanced: bool = True
    pack_visits: bool = True  # occupancy-aware schedule packing (smaller NP)

    def __post_init__(self):
        assert self.mesh is not None and self.mesh.axis_names == (
            AXIS_WORKERS, AXIS_SCENARIOS,
        ), (
            "HybridEnsemble expects a 2-D mesh with axes ('workers', "
            "'scenarios'); see launch/mesh.py:make_hybrid_mesh"
        )
        self.batch = engine_lib._as_batch(self.batch)
        self.num_real = len(self.batch)
        self.num_workers = int(self.mesh.shape[AXIS_WORKERS])
        scen_devs = int(self.mesh.shape[AXIS_SCENARIOS])
        self.padded = _pad_batch(self.batch, scen_devs)

        self.plan = sd.build_dist_plan(
            self.pop, self.num_workers, self.block_size, self.balanced,
            pack=self.pack_visits,
        )
        slots0 = None
        params_list = []
        for s in self.padded:
            slots, params = sim_lib.build_params(
                self.pop, s.disease, s.tm, s.interventions, s.seed,
                seed_per_day=s.seed_per_day, seed_days=s.seed_days,
                static_network=s.static_network, iv_enabled=s.iv_enabled,
            )
            if slots0 is None:
                slots0 = slots
            elif slots != slots0:
                raise ValueError(
                    f"scenario '{s.name}' intervention structure {slots} "
                    f"differs from batch structure {slots0}; ensembles vary "
                    "thresholds/factors/enabled, not slot kinds"
                )
            params_list.append(sd.pad_params(params, self.plan))
        self.iv_slots = slots0
        self.params = engine_lib.stack_params(params_list)
        self.static = sd.make_dist_static(
            self.plan, self.pop.num_locations, self.iv_slots,
            backend=self.backend,
            max_seed_per_day=max(s.seed_per_day for s in self.padded),
        )
        self._week, self._route = sd.week_device_arrays(self.plan)
        self._runners: dict[int, object] = {}

    # ------------------------------------------------------------------
    @property
    def num_scenarios(self) -> int:
        return self.num_real

    @property
    def names(self):
        return self.batch.names

    def init_state(self) -> sim_lib.SimState:
        """Stacked worker-padded initial state — leading axis scenarios."""
        return engine_lib.stack_params([
            sd.dist_init_state(s.disease, self.plan, len(self.iv_slots))
            for s in self.padded
        ])

    # ------------------------------------------------------------------
    def _runner(self, days: int):
        """Build (and cache) the 2-D shard_mapped scan for a run length."""
        if days in self._runners:
            return self._runners[days]
        static = self.static

        def worker(params, state, week, route):
            # Local leaves: params/state carry a leading (B_local,) scenario
            # axis; week/route are worker shards replicated over scenarios.
            wk = jax.tree.map(lambda a: a.squeeze(1), week)
            rt = jax.tree.map(lambda a: a.squeeze(1), route)
            step = jax.vmap(
                lambda p, st: sd.dist_day_step(static, rt, wk, p, st)
            )

            def body(st, _):
                return step(params, st)

            return jax.lax.scan(body, state, None, length=days)

        wspec = jax.tree.map(lambda _: P(None, AXIS_WORKERS), self._week)
        rspec = jax.tree.map(lambda _: P(None, AXIS_WORKERS), self._route)
        hist_spec = {k: P(None, AXIS_SCENARIOS) for k in sd.STAT_KEYS}
        runner = jax.jit(
            compat.shard_map(
                worker,
                mesh=self.mesh,
                in_specs=(
                    sd.dist_param_specs(batch_axis=AXIS_SCENARIOS),
                    sd.dist_state_specs(batch_axis=AXIS_SCENARIOS),
                    wspec,
                    rspec,
                ),
                out_specs=(
                    sd.dist_state_specs(batch_axis=AXIS_SCENARIOS),
                    hist_spec,
                ),
            )
        )
        self._runners[days] = runner
        return runner

    def run(self, days: int, state: Optional[sim_lib.SimState] = None,
            *, drop_padding: bool = True):
        """Run the whole hybrid ensemble as ONE jitted scan.

        Same contract as ``EnsembleSimulator.run``: history arrays are
        ``(days, B)`` (padding scenarios dropped) and final-state person
        leaves are ``(B, W*Pw)`` worker-padded arrays. Pass
        ``drop_padding=False`` to keep the pad scenarios — required when
        the returned state is fed back into a later ``run`` call
        (day-chunked checkpointing): the runner always expects the full
        padded batch axis.
        """
        state = state if state is not None else self.init_state()
        runner = self._runner(days)
        final, hist = runner(self.params, state, self._week, self._route)
        hist = {k: np.asarray(v) for k, v in jax.device_get(hist).items()}
        if drop_padding:
            B = self.num_real
            final = jax.tree.map(lambda x: x[:B], final)
            hist = {k: v[:, :B] for k, v in hist.items()}
        return final, hist

    def scenario_params(self, i: int):
        """Scenario ``i``'s un-stacked (worker-padded) SimParams."""
        return engine_lib.index_params(self.params, i)
