"""Hybrid (workers × scenarios) ensembles (deprecated facade).

``HybridEnsemble`` is now a thin shim over
``repro.engine.EngineCore(layout="hybrid")``: the engine core places the
one topology-parameterized day-loop scan on the product topology
``MeshTopology("workers") * ScenarioTopology("scenarios")`` — every
scenario people/location-sharded over the worker axis, the batch axis
sharded over the scenario axis, one jitted ``lax.scan`` under one
``shard_map`` over the 2-D mesh. Collectives (the visit/exposure
exchanges, trigger psums, seeding gather) run over ``workers`` only;
in-scan cross-scenario observables gather over ``scenarios``.

Per-scenario results are bitwise identical to sequential ``DistSimulator``
runs *and* to the single-device ``EnsembleSimulator`` (tests/test_dist.py,
tests/test_sweep.py, tests/test_engine.py). The batch is padded to a
multiple of the scenario-axis size with inert no-op scenarios that never
appear in returned histories.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import simulator as sim_lib
from repro.core import simulator_dist as sd

AXIS_WORKERS = sd.AXIS  # "workers"
AXIS_SCENARIOS = "scenarios"


@dataclasses.dataclass
class HybridEnsemble:
    """Run a ScenarioBatch on a 2-D (workers × scenarios) mesh.

    Every scenario is people/location-sharded over the ``workers`` axis
    (same partition plan for all scenarios — they share the population and
    therefore the visit schedule and exchange routing), and the batch axis
    is sharded over the ``scenarios`` axis.
    """

    pop: object
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    mesh: Mesh = None
    backend: str = "jnp"  # interaction backend: jnp | scan | compact | pallas
    block_size: int = 128
    balanced: bool = True
    pack_visits: bool = True  # occupancy-aware schedule packing (smaller NP)

    def __post_init__(self):
        assert self.mesh is not None and self.mesh.axis_names == (
            AXIS_WORKERS, AXIS_SCENARIOS,
        ), (
            "HybridEnsemble expects a 2-D mesh with axes ('workers', "
            "'scenarios'); see launch/mesh.py:make_hybrid_mesh"
        )
        warnings.warn(
            "HybridEnsemble is a deprecated facade; use "
            "repro.engine.EngineCore(layout='hybrid') or repro.api.run()",
            DeprecationWarning, stacklevel=2,
        )
        from repro.engine import EngineCore, index_params

        self._index_params = index_params
        self._core = EngineCore(
            self.pop, self.batch, layout="hybrid", mesh=self.mesh,
            backend=self.backend, block_size=self.block_size,
            balanced=self.balanced, pack_visits=self.pack_visits,
        )
        self.batch = self._core.batch
        self.num_real = self._core.num_real
        self.num_workers = self._core.workers
        self.padded = self._core.padded
        self.plan = self._core.plan
        self.iv_slots = self._core.iv_slots
        self.params = self._core.params
        self.static = self._core.static
        self._week, self._route = self._core.week, self._core.route

    # ------------------------------------------------------------------
    @property
    def num_scenarios(self) -> int:
        return self.num_real

    @property
    def names(self):
        return self.batch.names

    def init_state(self) -> sim_lib.SimState:
        """Stacked worker-padded initial state — leading axis scenarios."""
        return self._core.init_state()

    def run(self, days: int, state: Optional[sim_lib.SimState] = None,
            *, drop_padding: bool = True):
        """Run the whole hybrid ensemble as ONE jitted scan.

        Same contract as ``EnsembleSimulator.run``: history arrays are
        ``(days, B)`` (padding scenarios always dropped — they are inert
        no-ops) and final-state person leaves are ``(B, W*Pw)``
        worker-padded arrays. Pass ``drop_padding=False`` to keep the pad
        slots in the final state — required when the returned state is
        fed back into a later ``run`` call (day-chunked checkpointing).
        """
        final, _, hist, _ = self._core.run_days(days, state=state)
        if drop_padding:
            final = jax.tree.map(lambda x: x[: self.num_real], final)
        return final, hist

    def scenario_params(self, i: int):
        """Scenario ``i``'s un-stacked (worker-padded) SimParams."""
        return self._index_params(self.params, i)
