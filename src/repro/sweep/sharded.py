"""Device-parallel ensembles: shard the scenario axis across a mesh
(deprecated facade).

``ShardedEnsemble`` is now a thin shim over
``repro.engine.EngineCore(layout="scenarios")``: the engine core wraps the
one topology-parameterized day-loop scan in a shard_map over a 1-D
``("scenarios",)`` mesh — scenarios are mutually independent, so the day
loop itself has zero collectives; only in-scan cross-scenario observables
gather over the axis. Prefer this layout when every scenario fits on one
device, and the hybrid layout once a single scenario outgrows it.

The batch is padded to a multiple of the mesh size with *no-op* scenarios
(zero betas, zero seeding, interventions disabled — epidemiologically
inert and nearly free under the ``compact`` backend); padding slots never
appear in returned histories.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Union

import jax

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import simulator as sim_lib
from repro.engine.core import pad_batch as _pad_batch  # noqa: F401 (compat)
from repro.launch.mesh import make_scenario_mesh  # noqa: F401 (compat)

AXIS = "scenarios"


@dataclasses.dataclass
class ShardedEnsemble:
    """shard_map-parallel ScenarioBatch runner (1-D mesh, axis 'scenarios')."""

    pop: object
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    mesh: Optional[object] = None
    backend: str = "jnp"
    block_size: int = 128
    pack_visits: bool = True

    def __post_init__(self):
        warnings.warn(
            "ShardedEnsemble is a deprecated facade; use "
            "repro.engine.EngineCore(layout='scenarios') or repro.api.run()",
            DeprecationWarning, stacklevel=2,
        )
        from repro.engine import EngineCore

        if self.mesh is None:
            self.mesh = make_scenario_mesh()
        assert self.mesh.axis_names == (AXIS,), (
            f"ShardedEnsemble expects a 1-D mesh with axis '{AXIS}'; "
            "see launch/mesh.py:make_scenario_mesh()"
        )
        self._core = EngineCore(
            self.pop, self.batch, layout="scenarios", mesh=self.mesh,
            backend=self.backend, block_size=self.block_size,
            pack_visits=self.pack_visits,
        )
        self.batch = self._core.batch
        self.num_real = self._core.num_real
        self.padded = self._core.padded
        self.iv_slots = self._core.iv_slots
        self.params = self._core.params

    # ------------------------------------------------------------------
    def init_state(self) -> sim_lib.SimState:
        return self._core.init_state()

    def run(self, days: int, state: Optional[sim_lib.SimState] = None,
            *, drop_padding: bool = True):
        """Run the ensemble with the batch axis sharded over the mesh.

        Same contract as ``EnsembleSimulator.run`` — history arrays are
        ``(days, B)`` with padding scenarios always dropped (they are
        inert no-ops and never leave the engine core). Pass
        ``drop_padding=False`` to keep the pad slots in the *final state*
        — required when the returned state is fed back into a later
        ``run`` call (day-chunked checkpointing): the runner always
        expects the full padded batch axis.
        """
        final, _, hist, _ = self._core.run_days(days, state=state)
        if drop_padding:
            final = jax.tree.map(lambda x: x[: self.num_real], final)
        return final, hist

    @property
    def names(self):
        return self.batch.names
