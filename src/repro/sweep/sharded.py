"""Device-parallel ensembles: shard the scenario axis across a mesh.

Scenarios are mutually independent, so the batch axis shards perfectly —
each device runs a vmapped day-loop scan over its local slice of the
stacked params/state, with *zero* collectives in the day loop. This is the
ensemble analog of ``core/simulator_dist.py`` (which shards people and
locations of a *single* run): there the mesh buys population scale, here
it buys scenario throughput. The composition of the two — a 2-D
(workers x scenarios) mesh where each scenario is itself people/location-
sharded — is implemented in :mod:`repro.sweep.hybrid`; prefer this module
when every scenario fits on one device (no collectives at all), and
``HybridEnsemble`` once a single scenario outgrows it.

The batch is padded (by repeating the final scenario) to a multiple of the
mesh size; padding scenarios are dropped from results before they are
returned.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import compat
from repro.core import simulator as sim_lib
from repro.sweep import engine as engine_lib

AXIS = "scenarios"


def make_scenario_mesh(num_devices: Optional[int] = None) -> Mesh:
    devs = jax.devices() if num_devices is None else jax.devices()[:num_devices]
    return Mesh(np.array(devs), (AXIS,))


def _pad_batch(batch: ScenarioBatch, multiple: int) -> ScenarioBatch:
    B = len(batch)
    pad = (-B) % multiple
    if pad == 0:
        return batch
    filler = tuple(
        dataclasses.replace(batch[-1], name=f"__pad{i}") for i in range(pad)
    )
    return ScenarioBatch(scenarios=batch.scenarios + filler)


@dataclasses.dataclass
class ShardedEnsemble:
    """shard_map-parallel ScenarioBatch runner (1-D mesh, axis 'scenarios')."""

    pop: object
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    mesh: Optional[Mesh] = None
    backend: str = "jnp"
    block_size: int = 128
    pack_visits: bool = True

    def __post_init__(self):
        self.batch = engine_lib._as_batch(self.batch)
        self.mesh = self.mesh if self.mesh is not None else make_scenario_mesh()
        assert self.mesh.axis_names == (AXIS,), (
            f"ShardedEnsemble expects a 1-D mesh with axis '{AXIS}'; "
            "see make_scenario_mesh()"
        )
        self.num_real = len(self.batch)
        self.ens = engine_lib.EnsembleSimulator(
            self.pop,
            _pad_batch(self.batch, int(self.mesh.shape[AXIS])),
            backend=self.backend,
            block_size=self.block_size,
            pack_visits=self.pack_visits,
        )
        self._runners: dict[int, object] = {}

    # ------------------------------------------------------------------
    def _runner(self, days: int):
        """Build (and cache) the shard_mapped scan for a given length."""
        if days in self._runners:
            return self._runners[days]
        ens = self.ens

        def worker(params, state, week, contact_prob):
            step = jax.vmap(
                lambda p, st: sim_lib.day_step(
                    ens.static, week, contact_prob, p, st
                )
            )

            def body(st, _):
                return step(params, st)

            return jax.lax.scan(body, state, None, length=days)

        batch_spec = jax.tree.map(lambda _: P(AXIS), ens.params)
        state_spec = jax.tree.map(lambda _: P(AXIS), ens.init_state())
        week_spec = jax.tree.map(lambda _: P(), ens.week)
        hist_spec = {k: P(None, AXIS) for k in sim_lib.STAT_KEYS}
        runner = jax.jit(
            compat.shard_map(
                worker,
                mesh=self.mesh,
                in_specs=(batch_spec, state_spec, week_spec, P()),
                out_specs=(state_spec, hist_spec),
            )
        )
        self._runners[days] = runner
        return runner

    def init_state(self) -> sim_lib.SimState:
        return self.ens.init_state()

    def run(self, days: int, state: Optional[sim_lib.SimState] = None,
            *, drop_padding: bool = True):
        """Run the ensemble with the batch axis sharded over the mesh.

        Same contract as ``EnsembleSimulator.run`` — history arrays are
        ``(days, B)`` with padding scenarios already dropped. Pass
        ``drop_padding=False`` to keep the pad scenarios in both the final
        state and the history — required when the returned state is fed
        back into a later ``run`` call (day-chunked checkpointing): the
        runner always expects the full padded batch axis.
        """
        state = state if state is not None else self.init_state()
        runner = self._runner(days)
        final, hist = runner(self.ens.params, state, self.ens.week,
                             self.ens.contact_prob)
        hist = {k: np.asarray(v) for k, v in jax.device_get(hist).items()}
        if drop_padding:
            B = self.num_real
            final = jax.tree.map(lambda x: x[:B], final)
            hist = {k: v[:, :B] for k, v in hist.items()}
        return final, hist

    @property
    def names(self):
        return self.batch.names
