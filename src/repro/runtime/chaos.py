"""Deterministic chaos harness: seeded fault schedules for resilient runs.

Production failure modes — a peer raising out of a collective, a snapshot
half-written when a node died, NaNs escaping a broken kernel, a device
dropping out of the mesh, one worker suddenly 10x slower — are simulated
here as *scheduled events at chunk boundaries*, so the whole recovery
matrix of runtime/resilience.py runs deterministically in CI and every
recovered run can be asserted bitwise-equal to a fault-free one.

Event kinds (all fire exactly once, at the boundary *entering* the chunk
that starts at ``day``):

  ==============  =====================================================
  ``raise``       raise :class:`ChaosError` — a node failure at a chunk
                  boundary; recovery = restore newest snapshot + replay.
  ``corrupt``     flip bytes inside the newest on-disk snapshot, then
                  raise — recovery must quarantine it and fall back to
                  the next-older valid step.
  ``truncate``    truncate a leaf file of the newest snapshot, then
                  raise — same fallback path, different failure shape.
  ``nan``         poison the in-memory state with NaNs *after* the chunk
                  runs — the invariant guards must catch it before it is
                  checkpointed.
  ``device_loss`` raise :class:`DeviceLossError` — the elastic path
                  rebuilds the engine on fewer workers and continues.
  ``slow``        sleep inside the chunk's timed section — the straggler
                  detector must flag it (and may trigger repartition).
  ==============  =====================================================

Schedules are plain data: build them explicitly for targeted tests, or
:meth:`ChaosSchedule.random` draws a reproducible mix from a seed.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import numpy as np
import jax.numpy as jnp

KINDS = ("raise", "corrupt", "truncate", "nan", "device_loss", "slow")


class ChaosError(RuntimeError):
    """An injected, recoverable fault (simulated node failure)."""


class DeviceLossError(RuntimeError):
    """A worker device dropped out of the mesh; carries how many."""

    def __init__(self, workers_lost: int = 1,
                 message: str = "simulated device loss"):
        super().__init__(f"{message} ({workers_lost} worker(s))")
        self.workers_lost = int(workers_lost)


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    kind: str  # one of KINDS
    day: int  # chunk boundary the event fires at
    workers_lost: int = 1  # device_loss only
    sleep_s: float = 0.25  # slow only
    leaf: Optional[str] = None  # corrupt/truncate/nan target (None = pick)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"chaos kind must be one of {KINDS}, "
                             f"got '{self.kind}'")


@dataclasses.dataclass
class ChaosSchedule:
    """An ordered set of one-shot fault events, consumed by the resilient
    chunk loop's hooks. ``fired`` tracks which events already went off —
    replayed chunks do not re-fire them, which is what makes recovery
    terminate and stay bitwise-comparable."""

    events: tuple = ()

    def __post_init__(self):
        self.events = tuple(self.events)
        self.fired: set = set()
        self.log: list = []

    @classmethod
    def random(cls, seed: int, days: int, every: int,
               kinds: tuple = KINDS, n_events: int = 3) -> "ChaosSchedule":
        """A reproducible schedule: ``n_events`` faults drawn (without
        replacement over boundaries) from ``kinds`` at interior chunk
        boundaries of a ``days``-day run chunked ``every`` days."""
        # detlint: ignore[DET001] — fault-schedule generator: seeded PCG64
        # on the host; schedules replay identically, events never re-fire.
        rng = np.random.Generator(np.random.PCG64(seed))
        boundaries = list(range(every, days, every)) or [0]
        picks = rng.choice(len(boundaries),
                           size=min(n_events, len(boundaries)), replace=False)
        events = tuple(
            ChaosEvent(kind=str(rng.choice(list(kinds))),
                       day=int(boundaries[int(i)]))
            for i in sorted(picks)
        )
        return cls(events=events)

    # ------------------------------------------------------------------
    def _take(self, day: int, kinds: tuple) -> list:
        out = []
        for i, ev in enumerate(self.events):
            if i not in self.fired and ev.day == day and ev.kind in kinds:
                self.fired.add(i)
                self.log.append((ev.kind, int(day)))
                out.append(ev)
        return out

    # -- hook surface consumed by runtime/resilience.py -----------------
    def before_chunk(self, day: int, manager=None) -> None:
        """Fire boundary events for the chunk starting at ``day``. Disk
        events need ``manager`` (the run's CheckpointManager)."""
        for ev in self._take(day, ("slow",)):
            time.sleep(ev.sleep_s)
        for ev in self._take(day, ("corrupt", "truncate")):
            if manager is not None:
                _damage_newest(manager, ev)
            raise ChaosError(
                f"injected {ev.kind}-snapshot fault at day {day}")
        for ev in self._take(day, ("device_loss",)):
            raise DeviceLossError(ev.workers_lost)
        for ev in self._take(day, ("raise",)):
            raise ChaosError(f"injected node failure at day {day}")

    def poison_state(self, day: int, state):
        """Apply any ``nan`` event scheduled for the boundary *ending* at
        ``day``: overwrite the first dwell entry with NaN (a float leaf
        the guards sweep)."""
        for _ in self._take(day, ("nan",)):
            flat_nan = jnp.ravel(state.dwell).at[0].set(jnp.nan)
            state = dataclasses.replace(
                state, dwell=flat_nan.reshape(state.dwell.shape))
        return state


def _damage_newest(manager, ev: ChaosEvent) -> None:
    """Corrupt or truncate one leaf file of the newest on-disk snapshot."""
    manager.wait()
    steps = manager.all_steps()
    if not steps:
        return
    d = os.path.join(manager.directory, f"step-{steps[-1]:010d}")
    names = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    if not names:
        return
    if ev.leaf is not None:
        target = ev.leaf.replace("/", "__") + ".npy"
    else:  # the largest leaf: damage is guaranteed to land in array bytes
        target = max(names, key=lambda f: os.path.getsize(os.path.join(d, f)))
    path = os.path.join(d, target)
    size = os.path.getsize(path)
    if ev.kind == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    else:  # corrupt: invert trailing payload bytes (guaranteed to change)
        pos = max(size - 8, 0)
        with open(path, "r+b") as f:
            f.seek(pos)
            chunk = f.read(4)
            f.seek(pos)
            f.write(bytes(b ^ 0xFF for b in chunk))
