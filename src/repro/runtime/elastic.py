"""Elastic rescaling: continue a run on a different worker count.

Both state families support this exactly:

  * **Epidemic**: the simulation state is (P,)-shaped person arrays plus
    scalars; re-partitioning is a pure host-side reshuffle
    (``plan_elastic_rescale``) followed by a new worker-layout EngineCore
    build with the new worker count. Counter-based RNG makes the continued run
    bitwise identical to an uninterrupted one at any worker count
    (tests/test_elastic.py proves this).
  * **Training**: checkpoints store full logical arrays; restore places
    them under the new mesh's NamedShardings (checkpoint/manager.py).
"""

from __future__ import annotations

import numpy as np


def plan_elastic_rescale(num_people: int, old_workers: int, new_workers: int):
    """Mapping between padded (W, Pw) person-sharded layouts.

    Returns (old_layout, new_layout, copy_plan) where copy_plan is a list
    of (old_flat_slice, new_flat_slice) for the real (unpadded) people."""
    old_pw = int(np.ceil(num_people / old_workers))
    new_pw = int(np.ceil(num_people / new_workers))
    return (
        {"workers": old_workers, "per_worker": old_pw},
        {"workers": new_workers, "per_worker": new_pw},
        [(slice(0, num_people), slice(0, num_people))],
    )


def repartition_person_array(arr, num_people: int, new_workers: int, fill=0):
    """(W_old, Pw_old) -> (W_new, Pw_new), preserving the first P entries."""
    flat = np.asarray(arr).reshape(-1)[:num_people]
    new_pw = int(np.ceil(num_people / new_workers))
    out = np.full((new_workers * new_pw,) + flat.shape[1:], fill, flat.dtype)
    out[:num_people] = flat
    return out.reshape(new_workers, new_pw, *flat.shape[1:])
