"""Fault-tolerant *step* loop: checkpoint/restart, failure handling,
straggler detection.

This is the step-granular (LM-train-loop) prototype of the recovery
policy; the epidemic engine's chunk-granular production version — with
checkpoint integrity, invariant guards, elastic degradation, and a
deterministic chaos harness — lives in :mod:`repro.runtime.resilience`.

On a real multi-pod deployment, failures surface as raised exceptions from
the collective runtime (a peer died), watchdog timeouts, or preemption
notices. The loop below encodes the recovery policy in a
backend-independent way and is exercised in tests with *injected* faults:

  * **checkpoint cadence** — day-/step-granular snapshots via
    checkpoint/manager.py; deterministic counter-based RNG (core/rng.py)
    makes replay from the last snapshot bitwise-exact, so a restart costs
    at most `interval` steps of recompute and zero correctness risk.
  * **failure → restore → replay** — on exception the loop restores the
    newest checkpoint and replays; repeated failures back off and are
    capped by `max_restarts`.
  * **straggler mitigation** — per-step wall times feed a robust z-score
    (median/MAD); sustained outliers above `straggler_factor`× median
    trigger a callback. For the epidemic engine the callback re-partitions
    locations (the static balancer is cheap to re-run with updated load
    measurements); for synchronous SPMD training the callback is a hook
    for requesting a replacement slice from the cluster scheduler.
    Detection here, policy at the launcher.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class FaultConfig:
    checkpoint_interval: int = 50
    max_restarts: int = 10
    straggler_window: int = 20
    straggler_factor: float = 2.0
    backoff_s: float = 0.0  # kept 0 in tests


@dataclasses.dataclass
class LoopStats:
    steps_run: int = 0
    restarts: int = 0
    checkpoints: int = 0
    straggler_events: int = 0
    step_times: list = dataclasses.field(default_factory=list)


class FaultTolerantLoop:
    """Drives `step_fn(state) -> state` for `num_steps` with recovery.

    `save_fn(step, state)` / `restore_fn() -> (step, state)` wrap the
    checkpoint manager. `fault_injector(step)` (tests only) may raise to
    simulate a node failure at a step boundary.
    """

    def __init__(
        self,
        step_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        cfg: FaultConfig = FaultConfig(),
        on_straggler: Optional[Callable] = None,
        fault_injector: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.fault_injector = fault_injector
        self.stats = LoopStats()

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        restarts = 0
        while step < start_step + num_steps:
            try:
                t0 = time.perf_counter()
                if self.fault_injector is not None:
                    self.fault_injector(step)
                state = self.step_fn(state)
                dt = time.perf_counter() - t0
                self._track_straggler(dt, step)
                step += 1
                self.stats.steps_run += 1
                if step % self.cfg.checkpoint_interval == 0:
                    self.save_fn(step, state)
                    self.stats.checkpoints += 1
            except Exception:
                restarts += 1
                self.stats.restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                if self.cfg.backoff_s:
                    time.sleep(min(self.cfg.backoff_s * restarts, 30.0))
                step, state = self.restore_fn()
        return step, state

    def _track_straggler(self, dt: float, step: int):
        times = self.stats.step_times
        times.append(dt)
        w = self.cfg.straggler_window
        if len(times) >= w:
            window = np.asarray(times[-w:])
            med = np.median(window)
            if med > 0 and dt > self.cfg.straggler_factor * med:
                self.stats.straggler_events += 1
                if self.on_straggler is not None:
                    self.on_straggler(step, dt, med)
