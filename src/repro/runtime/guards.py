"""Post-chunk invariant guards: catch a poisoned state *before* it is
checkpointed.

A silent corruption (NaN creeping out of a bad kernel, a health code
outside the disease table, an isolation window travelling backwards in
time) is worse than a crash: the chunk loop would snapshot the poisoned
state and every later restart would faithfully replay garbage. The
resilient driver (runtime/resilience.py) runs :class:`GuardContext` after
every chunk and treats a violation exactly like an injected node failure —
restore the newest *valid* snapshot and replay — so the poisoned state
never reaches disk.

The checks are O(state) host-side numpy sweeps at chunk boundaries (tens
of days apart), so their cost is noise next to the chunk scan itself:

  * ``health`` codes lie in ``[0, num_states)`` — the disease-table range;
  * counters are non-negative (``cumulative``, ``day``) and ``cumulative``
    never decreases across chunks;
  * ``isolated_until`` is per-agent monotone non-decreasing (isolation
    windows only ever extend, PR 7 semantics);
  * every float leaf is NaN/Inf-free (``dwell`` uses the finite
    ``ABSORBING_DWELL`` sentinel, so a true Inf is always a bug).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax


class InvariantViolation(RuntimeError):
    """A state invariant failed; carries the full list of violations."""

    def __init__(self, violations: list):
        super().__init__(
            "state invariant violation: " + "; ".join(violations))
        self.violations = list(violations)


def check_state(state, *, num_states: int,
                prev: Optional[dict] = None) -> list:
    """Sweep a (stacked or unstacked) SimState for invariant violations.

    ``prev`` carries the previous boundary's monotonicity baselines
    (``{"cumulative": ..., "isolated_until": ...}``); pass None on the
    first call or after any event that legitimately changes shapes
    (elastic repartition re-pads the person axis).

    Returns a list of human-readable violations (empty = healthy).
    """
    s = {f.name: np.asarray(jax.device_get(getattr(state, f.name)))
         for f in dataclasses.fields(state)}
    out = []

    health = s["health"]
    if health.size and (health.min() < 0 or health.max() >= num_states):
        bad = int(((health < 0) | (health >= num_states)).sum())
        out.append(
            f"health: {bad} code(s) outside the disease-table range "
            f"[0, {num_states})")

    for k in ("cumulative", "day"):
        if np.any(s[k] < 0):
            out.append(f"{k}: negative counter (min {s[k].min()})")
    if np.any(s["isolated_until"] < 0):
        out.append("isolated_until: negative day "
                   f"(min {int(s['isolated_until'].min())})")

    for k, v in s.items():
        if np.issubdtype(v.dtype, np.floating) and not np.all(np.isfinite(v)):
            bad = int((~np.isfinite(v)).sum())
            out.append(f"{k}: {bad} non-finite value(s) (NaN/Inf sweep)")

    if prev is not None:
        pc = prev.get("cumulative")
        if pc is not None and pc.shape == s["cumulative"].shape and \
                np.any(s["cumulative"] < pc):
            out.append("cumulative: decreased across a chunk boundary")
        pi = prev.get("isolated_until")
        if pi is not None and pi.shape == s["isolated_until"].shape and \
                np.any(s["isolated_until"] < pi):
            bad = int((s["isolated_until"] < pi).sum())
            out.append(
                f"isolated_until: {bad} isolation window(s) moved backwards "
                "(windows may only extend)")
    return out


@dataclasses.dataclass
class GuardContext:
    """Stateful wrapper around :func:`check_state` that threads the
    monotonicity baselines between chunk boundaries.

    ``num_states`` is the disease table's state count (e.g.
    ``core.params.sus_table.shape[-1]``)."""

    num_states: int
    prev: Optional[dict] = None

    def reset(self, state=None) -> None:
        """Drop the baselines (fresh run) or rebase them on ``state``
        (after a restore or an elastic repartition)."""
        if state is None:
            self.prev = None
        else:
            self.prev = self._baseline(state)

    @staticmethod
    def _baseline(state) -> dict:
        return {
            "cumulative": np.asarray(jax.device_get(state.cumulative)),
            "isolated_until": np.asarray(jax.device_get(state.isolated_until)),
        }

    def check(self, state) -> None:
        """Raise :class:`InvariantViolation` if ``state`` is poisoned;
        otherwise advance the baselines to it."""
        violations = check_state(state, num_states=self.num_states,
                                 prev=self.prev)
        if violations:
            raise InvariantViolation(violations)
        self.prev = self._baseline(state)
