from repro.runtime.fault import FaultTolerantLoop, FaultConfig  # noqa: F401
from repro.runtime.elastic import plan_elastic_rescale  # noqa: F401
