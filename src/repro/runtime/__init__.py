from repro.runtime.fault import FaultTolerantLoop, FaultConfig  # noqa: F401
from repro.runtime.elastic import (  # noqa: F401
    plan_elastic_rescale,
    repartition_person_array,
)
from repro.runtime.guards import GuardContext, InvariantViolation  # noqa: F401
from repro.runtime.chaos import (  # noqa: F401
    ChaosError,
    ChaosEvent,
    ChaosSchedule,
    DeviceLossError,
)
from repro.runtime.resilience import (  # noqa: F401
    ResiliencePolicy,
    ResilienceReport,
    run_resilient,
)
