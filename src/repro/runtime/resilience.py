"""Resilient chunked runs: recovery policy around the engine's chunk loop.

``repro.engine.core.run_chunked`` already gives every layout bitwise
day-chunked checkpoint/resume; this module wraps it with the recovery
policy a multi-hour campaign needs (the policy prototyped for the LM
train loop in runtime/fault.py, re-homed onto the epidemic engine):

  * **failure → restore → replay** — any fault at a chunk boundary (a
    raised collective error, an injected chaos fault, an invariant
    violation from runtime/guards.py) restores the newest *valid*
    snapshot — corrupt ones are digest-detected and quarantined by the
    checkpoint layer — and replays. Deterministic counter RNG makes the
    replay bitwise, so a recovered run equals an uninterrupted one
    exactly. Restarts are capped and backed off.
  * **invariant guards** — after every chunk (and before its snapshot is
    written) the state passes the :mod:`repro.runtime.guards` invariant
    pack; a violation is treated as a fault, so a poisoned state is
    replayed away instead of checkpointed.
  * **straggler detection** — per-chunk wall times feed a robust
    median/MAD outlier test; sustained outliers surface the adaptive
    repartition hook (rebuild the driver — re-running the static balancer
    — at a safe chunk boundary) from the ROADMAP open item.
  * **elastic degradation** — on device loss the driver is rebuilt on
    fewer workers (``plan_elastic_rescale`` + ``repartition_person_array``
    re-pad the person axis inside ``EngineCore.adopt_state``) and the run
    continues from the newest snapshot; layout-independence of the day
    loop keeps the continued trajectory bitwise-equal.

Everything is driven deterministically by :mod:`repro.runtime.chaos` in
tests/CI; :class:`ResilienceReport` records what recovery did so
``RunResult.provenance["resilience"]`` can show it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.checkpoint import CheckpointCorruptionError  # noqa: F401 (re-export)
from repro.runtime.chaos import ChaosSchedule, DeviceLossError
from repro.runtime.guards import GuardContext, InvariantViolation


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """The recovery policy for a resilient chunked run."""

    max_restarts: int = 3  # restore+replay attempts before giving up
    backoff_s: float = 0.0  # restart backoff (linear in attempt; 0 in tests)
    guards: bool = True  # run the post-chunk invariant pack
    elastic: bool = True  # shrink workers on device loss (vs. re-raise)
    straggler_window: int = 5  # chunk-time window for the median/MAD test
    straggler_factor: float = 4.0  # flag dt > factor * median ...
    straggler_z: float = 8.0  # ... and dt > median + z * 1.4826 * MAD
    repartition_on_straggler: bool = False  # rebuild driver on detection
    max_repartitions: int = 2


@dataclasses.dataclass
class ResilienceReport:
    """What recovery actually did, for ``RunResult.provenance``."""

    restarts: int = 0
    chunks_replayed: int = 0
    snapshots_quarantined: int = 0
    straggler_events: list = dataclasses.field(default_factory=list)
    guard_violations: list = dataclasses.field(default_factory=list)
    device_losses: list = dataclasses.field(default_factory=list)
    repartitions: int = 0
    faults: list = dataclasses.field(default_factory=list)
    final_workers: int = 1
    final_layout: str = "local"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _RepartitionSignal(Exception):
    """Control flow: the straggler policy asked for a driver rebuild at
    the next safe boundary (internal to run_resilient)."""

    def __init__(self, day: int):
        super().__init__(f"repartition requested at day {day}")
        self.day = day


class _ChunkHooks:
    """The hook object handed to ``run_chunked``: chaos injection, the
    invariant guards, straggler timing, and replay accounting."""

    def __init__(self, policy: ResiliencePolicy, report: ResilienceReport,
                 manager, guard: Optional[GuardContext],
                 chaos: Optional[ChaosSchedule],
                 on_straggler: Optional[Callable]):
        self.policy = policy
        self.report = report
        self.manager = manager
        self.guard = guard
        self.chaos = chaos
        self.on_straggler = on_straggler
        self.chunk_times: list = []
        self.max_end = 0  # furthest chunk boundary completed (any attempt)
        self.saved_any = False

    # -- run_chunked hook surface ---------------------------------------
    def on_start(self, state, day: int) -> None:
        if self.guard is not None:
            self.guard.reset(state)

    def before_chunk(self, day: int, n: int) -> None:
        if self.chaos is not None:
            self.chaos.before_chunk(day, self.manager)

    def after_chunk(self, end_day: int, state, dt: float):
        if end_day <= self.max_end:
            self.report.chunks_replayed += 1
        else:
            self.max_end = end_day
        if self.chaos is not None:
            state = self.chaos.poison_state(end_day, state)
        if self.guard is not None:
            self.guard.check(state)  # raises InvariantViolation on poison
        self._track_straggler(end_day, dt)
        return state

    def after_save(self, day: int) -> None:
        self.saved_any = True

    # -- straggler detection (median/MAD over per-chunk wall time) ------
    def _track_straggler(self, end_day: int, dt: float) -> None:
        times = self.chunk_times
        times.append(dt)
        w = self.policy.straggler_window
        if len(times) < w:
            return
        window = np.asarray(times[-w:])
        med = float(np.median(window))
        mad = float(np.median(np.abs(window - med)))
        slow = dt > max(self.policy.straggler_factor * med,
                        med + self.policy.straggler_z * 1.4826 * mad)
        if med > 0 and slow:
            self.report.straggler_events.append(
                {"day": int(end_day), "chunk_s": round(dt, 4),
                 "median_s": round(med, 4)})
            if self.on_straggler is not None:
                self.on_straggler(end_day, dt, med)
            if (self.policy.repartition_on_straggler
                    and self.report.repartitions < self.policy.max_repartitions):
                raise _RepartitionSignal(end_day)


def run_resilient(
    make_driver: Callable,
    days: int,
    observables: tuple,
    ctx,
    *,
    manager,
    every: int = 50,
    resume: bool = True,
    resume_key: Optional[dict] = None,
    policy: Optional[ResiliencePolicy] = None,
    chaos: Optional[ChaosSchedule] = None,
    on_straggler: Optional[Callable] = None,
):
    """Run ``run_chunked`` under the recovery policy.

    ``make_driver(workers=None)`` builds (or rebuilds) the chunk driver —
    a :class:`~repro.engine.core.CoreDriver` or ``SequentialDriver`` whose
    ``.core`` exposes ``workers``/``layout``/``params``. Passing a worker
    count rebuilds the engine on that many workers (the elastic
    degradation path); ``None`` means the spec's own mesh.

    Returns ``run_chunked``'s tuple plus a :class:`ResilienceReport`:
    ``(state, hist, carries, dailies, resumed_from, num_chunks, report)``.
    """
    from repro.engine.core import ResumeKeyError, run_chunked

    if manager is None:
        raise ValueError(
            "resilient runs need checkpointing: recovery restores from "
            "snapshots (set checkpoint.directory)")
    policy = policy if policy is not None else ResiliencePolicy()
    report = ResilienceReport()
    driver = make_driver(None)
    guard = None
    if policy.guards:
        guard = GuardContext(
            num_states=int(driver.core.params.sus_table.shape[-1]))
    hooks = _ChunkHooks(policy, report, manager, guard, chaos, on_straggler)

    restarts = 0
    while True:
        try:
            out = run_chunked(
                driver, days, observables, ctx, manager=manager,
                every=every, resume=resume or hooks.saved_any,
                resume_key=resume_key, hooks=hooks,
            )
            break
        except ResumeKeyError:
            raise  # a config error, not a fault — never retried
        except _RepartitionSignal as sig:
            # Straggler policy: rebuild the driver (re-running the static
            # balancer) on the same worker count; the next attempt resumes
            # from the newest snapshot — a safe repartition point.
            report.repartitions += 1
            report.faults.append(
                {"kind": "repartition", "day": sig.day})
            driver = make_driver(int(getattr(driver.core, "workers", 1)))
            hooks.chunk_times.clear()  # fresh program => fresh timing baseline
        except DeviceLossError as e:
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            old_w = int(getattr(driver.core, "workers", 1))
            new_w = old_w - e.workers_lost
            if not policy.elastic or new_w < 1 or old_w <= 1:
                raise
            report.device_losses.append(
                {"workers_before": old_w, "workers_after": new_w})
            report.faults.append({"kind": "device_loss", "error": str(e)})
            driver = make_driver(new_w)
            hooks.chunk_times.clear()  # fresh program => fresh timing baseline
            _backoff(policy, restarts)
        except Exception as e:  # noqa: BLE001 — the recovery boundary
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            if isinstance(e, InvariantViolation):
                report.guard_violations.extend(e.violations)
            report.faults.append(
                {"kind": type(e).__name__, "error": str(e)})
            _backoff(policy, restarts)
        if guard is not None:
            guard.reset()  # rebased on the restored state at on_start

    report.restarts = restarts
    report.snapshots_quarantined = len(manager.quarantined_steps)
    report.final_workers = int(getattr(driver.core, "workers", 1))
    report.final_layout = str(getattr(driver.core, "layout", "local"))
    return out + (report,)


def _backoff(policy: ResiliencePolicy, attempt: int) -> None:
    if policy.backoff_s:
        time.sleep(min(policy.backoff_s * attempt, 30.0))
