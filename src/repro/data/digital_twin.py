"""Digital-twin-style realistic population generator (paper §IV-A1).

The paper's MD/VA datasets come from a census-fusion pipeline (ACS PUMS,
NHTS, NAICS, building data) that is not reproducible offline. This module
generates populations with the same *structural* properties the simulator
and its load balancer care about:

  * hierarchical geography (state → county → tract → block group) giving
    meaningful geo-sort keys for the static load-balancing scheme;
  * households (home locations) holding 1–6 people;
  * age-typed activity schedules: children attend schools (large, heavy
    locations), adults attend workplaces (lognormal sizes — a few very
    heavy locations, the load-imbalance driver in Fig 2), everyone makes
    random "other" visits (shopping etc.);
  * weekday/weekend structure: work/school visits Mon–Fri only.

Scale is a parameter; the MD/VA configs instantiate ``*-mini`` versions at
CPU-runnable scale while the dry-run configs keep the paper's full entity
counts (Table II) as shapes.
"""

from __future__ import annotations

import numpy as np

from repro.core import contact as contact_lib
from repro.core import population as pop_lib

SECONDS_PER_HOUR = 3600.0

LOC_HOME, LOC_WORK, LOC_SCHOOL, LOC_OTHER = 0, 1, 2, 3


def digital_twin_population(
    num_people: int,
    seed: int = 0,
    name: str = "twin",
    locations_per_person: float = 0.525,  # MD: 2.896M locs / 5.513M people
    pad_multiple: int = 128,
) -> pop_lib.Population:
    # detlint: ignore[DET001] — host-side population builder: deterministic
    # via the explicit seed; builds inputs, draws no simulation randomness.
    rs = np.random.default_rng(seed)
    P = num_people

    # --- people & households -------------------------------------------------
    age_group = rs.choice(3, size=P, p=[0.22, 0.62, 0.16]).astype(np.int8)
    hh_sizes = rs.choice([1, 2, 3, 4, 5, 6], size=P, p=[0.28, 0.35, 0.15, 0.13, 0.06, 0.03])
    # Build households until all people assigned.
    cum = np.cumsum(hh_sizes)
    n_homes = int(np.searchsorted(cum, P) + 1)
    home_of_person = np.repeat(np.arange(n_homes), hh_sizes[:n_homes])[:P]

    # --- locations -----------------------------------------------------------
    L = max(int(round(P * locations_per_person)), n_homes + 8)
    n_work = max(int(0.55 * (L - n_homes)), 1)
    n_school = max(int(0.02 * (L - n_homes)), 1)
    n_other = L - n_homes - n_work - n_school
    assert n_other > 0, "population too small for the location mix"
    loc_type = np.concatenate(
        [
            np.full(n_homes, LOC_HOME, np.int8),
            np.full(n_work, LOC_WORK, np.int8),
            np.full(n_school, LOC_SCHOOL, np.int8),
            np.full(n_other, LOC_OTHER, np.int8),
        ]
    )
    work0, school0, other0 = n_homes, n_homes + n_work, n_homes + n_work + n_school

    # Hierarchical geography: block groups of ~600 people, tracts of ~4 BGs,
    # counties of ~50 tracts. Locations are scattered near their community.
    bg_of_person = home_of_person * 0  # placeholder, computed from home below
    n_bg = max(P // 600, 1)
    bg_of_home = (np.arange(n_homes) * n_bg // n_homes).astype(np.int64)
    bg_of_person = bg_of_home[home_of_person]
    # Non-home locations: assigned to block groups roughly uniformly, with
    # heavy workplaces concentrated in "commercial" block groups.
    bg_of_loc = np.empty((L,), np.int64)
    bg_of_loc[:n_homes] = bg_of_home
    bg_of_loc[n_homes:] = rs.integers(0, n_bg, size=L - n_homes)
    tract = bg_of_loc // 4
    county = tract // 50
    geo_key = county * 1_000_000 + tract * 1_000 + bg_of_loc % 1_000

    # --- assignment of people to work/school --------------------------------
    # Workplace sizes ~ lognormal: a few giant sites (hospitals, campuses).
    work_of_person = work0 + rs.choice(
        n_work, size=P, p=_lognormal_weights(n_work, rs)
    )
    school_of_person = school0 + rs.choice(
        n_school, size=P, p=_lognormal_weights(n_school, rs, sigma=0.8)
    )

    # Commute locality: 70% of workers work within their home county — remap
    # a fraction of assignments to a nearby workplace (ACS commute-flow-ish).
    # (Structural only; enough to make geo-sorted partitions meaningful.)

    beta_sus = rs.uniform(0.8, 1.2, size=P).astype(np.float32)
    beta_inf = rs.uniform(0.8, 1.2, size=P).astype(np.float32)
    # Children slightly more susceptible at school-age mixing rates.
    beta_sus[age_group == 0] *= 1.1

    # --- weekly activity schedules -------------------------------------------
    is_child = age_group == 0
    is_adult = age_group == 1
    week = []
    for dow in range(pop_lib.DAYS_PER_WEEK):
        weekday = dow < 5
        persons, locs, starts, ends = [], [], [], []

        def add(mask, loc_ids, t0_h, t1_h, jitter_h=0.75):
            idx = np.flatnonzero(mask)
            if len(idx) == 0:
                return
            j0 = rs.uniform(-jitter_h, jitter_h, size=len(idx))
            j1 = rs.uniform(-jitter_h, jitter_h, size=len(idx))
            persons.append(idx)
            locs.append(loc_ids[idx] if loc_ids.shape == (P,) else loc_ids)
            starts.append(((t0_h + j0) * SECONDS_PER_HOUR).astype(np.float32))
            ends.append(((t1_h + j1) * SECONDS_PER_HOUR).astype(np.float32))

        # Home: everyone, morning and evening blocks.
        add(np.ones(P, bool), home_of_person.astype(np.int64), 0.0, 7.5)
        add(np.ones(P, bool), home_of_person.astype(np.int64), 18.0, 24.0)
        if weekday:
            work_attend = is_adult & (rs.random(P) < 0.72)
            add(work_attend, work_of_person, 9.0, 17.0)
            school_attend = is_child & (rs.random(P) < 0.95)
            add(school_attend, school_of_person, 8.0, 15.0)
        # Other visits: shopping/leisure, more on weekends (but the
        # work/school structure keeps weekdays busier overall).
        n_other_visits = rs.poisson(0.5 if weekday else 1.1, size=P)
        for v in range(int(n_other_visits.max())):
            m = n_other_visits > v
            dest = other0 + rs.integers(0, n_other, size=P)
            s = rs.uniform(10, 20, size=P)
            d = rs.exponential(1.2, size=P) + 0.25
            idx = np.flatnonzero(m)
            persons.append(idx)
            locs.append(dest[idx])
            starts.append((s[idx] * SECONDS_PER_HOUR).astype(np.float32))
            ends.append(((s[idx] + d[idx]) * SECONDS_PER_HOUR).astype(np.float32))

        person_arr = np.concatenate(persons)
        loc_arr = np.concatenate(locs).astype(np.int64)
        start_arr = np.clip(np.concatenate(starts), 0, 86400).astype(np.float32)
        end_arr = np.clip(np.concatenate(ends), 0, 86400).astype(np.float32)
        keep = end_arr > start_arr
        week.append(
            pop_lib.pack_day(
                person_arr[keep], loc_arr[keep], start_arr[keep], end_arr[keep],
                pad_multiple=pad_multiple,
            )
        )

    pop = pop_lib.Population(
        name=name,
        num_people=P,
        num_locations=L,
        age_group=age_group,
        beta_sus=beta_sus,
        beta_inf=beta_inf,
        home_loc=home_of_person.astype(np.int32),
        loc_type=loc_type,
        geo_key=geo_key,
        max_occupancy=np.zeros((L,), np.int32),
        contact_prob=np.zeros((L,), np.float32),
        week=pop_lib.pad_week_uniform(week, pad_multiple),
    )
    pop.finalize_contact_model(contact_lib.MinMaxAlpha())
    return pop


def _lognormal_weights(n: int, rs: np.random.Generator, sigma: float = 1.4):
    w = rs.lognormal(mean=0.0, sigma=sigma, size=n)
    return w / w.sum()
