"""Synthetic LM token pipeline — deterministic, sharded, restart-exact.

Streams batches of a learnable synthetic language (first-order Markov
structure + noise) so end-to-end training drivers show real loss movement
offline. Batch b of step s is a pure function of (seed, step) via the
counter-based hash (core/rng.py), so the pipeline needs no state beyond
the step counter: restart/elastic-rescale resume exactly, and any worker
can compute any shard (no data redistribution on failure).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import rng


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8  # fraction of deterministic transitions

    def _successor(self, tok):
        return (tok * 31 + 17) % self.vocab_size

    def batch(self, step: int) -> np.ndarray:
        """(global_batch, seq_len) int32 for `step` — pure function."""
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        rows = np.arange(B, dtype=np.uint64) + np.uint64(step) * np.uint64(B)
        # initial tokens
        u0 = rng.np_uniform(self.seed, int(rng.VISIT_SAMPLE), 0, rows)
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = (u0 * V).astype(np.int64)
        for t in range(1, S):
            u = rng.np_uniform(self.seed, int(rng.VISIT_SAMPLE), t, rows)
            u2 = rng.np_uniform(self.seed + 1, int(rng.VISIT_SAMPLE), t, rows)
            det = self._successor(toks[:, t - 1])
            rnd = (u2 * V).astype(np.int64)
            toks[:, t] = np.where(u < self.structure, det, rnd)
        return toks.astype(np.int32)

    def shard(self, step: int, worker: int, num_workers: int) -> np.ndarray:
        """This worker's rows of the global batch (contiguous split)."""
        full = self.batch(step)
        per = self.global_batch // num_workers
        return full[worker * per : (worker + 1) * per]
