"""Purely synthetic population generators (paper §IV-A2).

Two generators, mirroring the paper exactly:

1. **Watts–Strogatz**: a WS small-world random graph over locations is
   treated as a location–location graph and expanded to a people–location
   bipartite visit graph: each location homes ~Poisson(P/L) people (adjusted
   to exactly P, min 1); each person, each day, sets aside U(6,10) hours of
   sleep centered on midnight and partitions the remaining time between
   U{5..7} visits whose destinations are sampled with replacement from the
   home location's WS neighbors. Used for the WS-20M / WS-100M / WS-US
   strong-scaling datasets (we generate *-mini variants at runnable scale;
   the full shapes exist as configs for the dry-run).

2. **Grid (on-the-fly)**: locations on a W×H grid, `density` people per
   location; each day each person makes N~Poisson(lambda_visits) visits to
   locations ~Poisson(lambda_hops) grid-hops from home (paper: 5.2 and 8).
   Used for weak scaling (per-core loads of Table III).

All randomness is a deterministic function of the dataset seed.
"""

from __future__ import annotations

import numpy as np

from repro.core import contact as contact_lib
from repro.core import population as pop_lib

SECONDS_PER_DAY = 86400.0


def _person_attrs(P: int, rs: np.random.Generator):
    age_group = rs.choice(3, size=P, p=[0.22, 0.62, 0.16]).astype(np.int8)
    beta_sus = np.ones((P,), np.float32)
    beta_inf = np.ones((P,), np.float32)
    return age_group, beta_sus, beta_inf


def _ws_graph(L: int, k: int, beta: float, rs: np.random.Generator) -> np.ndarray:
    """Watts–Strogatz ring lattice with rewiring. Returns (L, k) neighbor
    table (directed view; sampling with replacement, so a table is enough)."""
    offsets = np.concatenate([np.arange(1, k // 2 + 1), -np.arange(1, k - k // 2 + 1)])
    nbrs = (np.arange(L)[:, None] + offsets[None, :]) % L
    rewire = rs.random(nbrs.shape) < beta
    nbrs = np.where(rewire, rs.integers(0, L, nbrs.shape), nbrs)
    # avoid self loops
    self_loop = nbrs == np.arange(L)[:, None]
    nbrs = np.where(self_loop, (nbrs + 1) % L, nbrs)
    return nbrs.astype(np.int64)


def watts_strogatz_population(
    num_people: int,
    num_locations: int,
    k: int = 6,
    beta: float = 0.1,
    seed: int = 0,
    name: str = "ws",
    pad_multiple: int = 128,
) -> pop_lib.Population:
    # detlint: ignore[DET001] — host-side population builder: deterministic
    # via the explicit seed; builds inputs, draws no simulation randomness.
    rs = np.random.default_rng(seed)
    P, L = num_people, num_locations
    nbrs = _ws_graph(L, k, beta, rs)

    # People per location ~ Poisson(P/L), adjusted to exactly P, min 1.
    counts = np.maximum(rs.poisson(P / L, size=L), 1).astype(np.int64)
    diff = counts.sum() - P
    while diff != 0:
        idx = rs.integers(0, L, size=abs(diff))
        if diff > 0:
            np.subtract.at(counts, idx, 1)
            counts = np.maximum(counts, 1)
        else:
            np.add.at(counts, idx, 1)
        diff = counts.sum() - P
    home = np.repeat(np.arange(L, dtype=np.int64), counts)[:P]

    age_group, beta_sus, beta_inf = _person_attrs(P, rs)

    week = []
    for _ in range(pop_lib.DAYS_PER_WEEK):
        sleep_h = rs.uniform(6.0, 10.0, size=P)
        awake_start = sleep_h / 2.0 * 3600.0
        awake_end = SECONDS_PER_DAY - awake_start
        nv = rs.integers(5, 8, size=P)  # U{5,6,7}
        vmax = int(nv.max())
        # Partition awake time: sorted uniform draws are the visit boundaries.
        u = np.sort(rs.random((P, vmax)), axis=1)
        starts = awake_start[:, None] + u * (awake_end - awake_start)[:, None]
        ends = np.concatenate([starts[:, 1:], awake_end[:, None]], axis=1)
        valid = np.arange(vmax)[None, :] < nv[:, None]
        choice = rs.integers(0, nbrs.shape[1], size=(P, vmax))
        dest = nbrs[home[:, None], choice]
        person_idx = np.broadcast_to(np.arange(P)[:, None], (P, vmax))
        sel = valid.ravel()
        week.append(
            pop_lib.pack_day(
                person_idx.ravel()[sel],
                dest.ravel()[sel],
                starts.ravel()[sel].astype(np.float32),
                ends.ravel()[sel].astype(np.float32),
                pad_multiple=pad_multiple,
            )
        )

    geo_key = np.arange(L, dtype=np.int64)  # ring order is the geography
    pop = pop_lib.Population(
        name=name,
        num_people=P,
        num_locations=L,
        age_group=age_group,
        beta_sus=beta_sus,
        beta_inf=beta_inf,
        home_loc=home.astype(np.int32),
        loc_type=np.full((L,), 3, np.int8),
        geo_key=geo_key,
        max_occupancy=np.zeros((L,), np.int32),
        contact_prob=np.zeros((L,), np.float32),
        week=pop_lib.pad_week_uniform(week, pad_multiple),
    )
    # Purely synthetic data: fixed contact probability (paper §IV-C3), since
    # max occupancy "cannot be computed in advance" in the on-the-fly case;
    # for precomputed WS data we *can* and do compute min/max/alpha.
    pop.finalize_contact_model(contact_lib.MinMaxAlpha())
    return pop


def grid_population(
    grid_width: int,
    grid_height: int,
    density: float = 4.0,
    lambda_visits: float = 5.2,
    lambda_hops: float = 8.0,
    seed: int = 0,
    name: str = "grid",
    pad_multiple: int = 128,
) -> pop_lib.Population:
    # detlint: ignore[DET001] — host-side population builder: deterministic
    # via the explicit seed; builds inputs, draws no simulation randomness.
    rs = np.random.default_rng(seed)
    L = grid_width * grid_height
    P = int(round(L * density))
    home = rs.integers(0, L, size=P).astype(np.int64)
    hx, hy = home % grid_width, home // grid_width
    age_group, beta_sus, beta_inf = _person_attrs(P, rs)

    week = []
    for _ in range(pop_lib.DAYS_PER_WEEK):
        nv = rs.poisson(lambda_visits, size=P)
        vmax = max(int(nv.max()), 1)
        hops = rs.poisson(lambda_hops, size=(P, vmax))
        theta = rs.uniform(0, 2 * np.pi, size=(P, vmax))
        dx = np.rint(hops * np.cos(theta)).astype(np.int64)
        dy = np.rint(hops * np.sin(theta)).astype(np.int64)
        gx = np.clip(hx[:, None] + dx, 0, grid_width - 1)
        gy = np.clip(hy[:, None] + dy, 0, grid_height - 1)
        dest = gy * grid_width + gx
        start = rs.uniform(6 * 3600, 22 * 3600, size=(P, vmax)).astype(np.float32)
        dur = rs.exponential(5400.0, size=(P, vmax)).astype(np.float32)
        end = np.minimum(start + np.maximum(dur, 300.0), SECONDS_PER_DAY)
        valid = np.arange(vmax)[None, :] < nv[:, None]
        person_idx = np.broadcast_to(np.arange(P)[:, None], (P, vmax))
        sel = valid.ravel()
        week.append(
            pop_lib.pack_day(
                person_idx.ravel()[sel],
                dest.ravel()[sel],
                start.ravel()[sel],
                end.ravel()[sel],
                pad_multiple=pad_multiple,
            )
        )

    # Geography: Morton-ish key preserving 2-D locality for partitioning.
    lx = np.arange(L) % grid_width
    ly = np.arange(L) // grid_width
    geo_key = (ly // 4) * grid_width * 4 + (lx // 4) * 16 + (ly % 4) * 4 + lx % 4

    pop = pop_lib.Population(
        name=name,
        num_people=P,
        num_locations=L,
        age_group=age_group,
        beta_sus=beta_sus,
        beta_inf=beta_inf,
        home_loc=home.astype(np.int32),
        loc_type=np.full((L,), 3, np.int8),
        geo_key=geo_key.astype(np.int64),
        max_occupancy=np.zeros((L,), np.int32),
        contact_prob=np.zeros((L,), np.float32),
        week=pop_lib.pad_week_uniform(week, pad_multiple),
    )
    pop.finalize_contact_model(contact_lib.FixedProbability(0.3))
    return pop
