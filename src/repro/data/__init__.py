from repro.data.synthetic import (  # noqa: F401
    grid_population,
    watts_strogatz_population,
)
from repro.data.digital_twin import digital_twin_population  # noqa: F401
