"""The one epidemic day loop, written against the Topology protocol.

This module is the entire runtime core: :func:`day_step` is Algorithm 2's
per-day body (visits → interactions → update) expressed once over
topology collectives, and :func:`run_days` is the whole run as a single
``lax.scan`` over the vmapped step, with observable reductions updating
*inside* the scan body. Every legacy engine layout is this scan placed on
a different :class:`~repro.engine.topology.Topology` — composition, not
per-layout loops (see repro/engine/core.py for the placement machinery).

Bitwise contract: on :class:`LocalTopology` the step performs the exact
arithmetic of the pre-refactor ``core/simulator.py:day_step``, and on
:class:`MeshTopology` the exact arithmetic of
``core/simulator_dist.py:dist_day_step`` — same counter-based draws on
global person ids, same accumulation orders, masks applied as exact
0.0/1.0 multiplies. tests/test_engine.py pins this against hand-rolled
scans over the legacy pure steps for all five layouts × backends.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import disease as disease_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import simulator as sim_lib
from repro.core import transmission as tx_lib
from repro.engine.topology import Topology
from repro.kernels.interactions import ops as iops

STAT_KEYS = sim_lib.STAT_KEYS


@dataclasses.dataclass(frozen=True)
class EngineStatic:
    """Trace-time structure of the unified step: local-shard geometry plus
    the intervention slot layout and kernel backend. Identical across
    every scenario of a batch; on LocalTopology the "shard" is the whole
    population (``people_per_worker == num_people``)."""

    num_people: int  # global P (pre-padding)
    num_locations: int
    people_per_worker: int  # Pw — local person-shard width
    visits_per_worker: int  # Vw — local visit-slot width
    block_size: int
    seed_topk: int  # static per-worker top-k width for outbreak seeding
    iv_slots: tuple  # tuple[iv_lib.IvSlotStatic, ...]
    backend: str = "jnp"
    # Per-agent intervention structure (PR 7). Empty = the whole TTI layer
    # is statically compiled out: the traced program is the pre-PR one.
    pa_slots: tuple = ()  # tuple[iv_lib.PaSlotStatic, ...]
    test_topk: int = 1  # static per-worker top-k width for the test budget


def day_step(
    topo: Topology,
    static: EngineStatic,
    route,  # None (local) | dict of (7, W, C) exchange routing arrays
    week,  # dict of (7, ...) local weekly schedule + block schedules
    params: sim_lib.SimParams,  # per-person leaves are local (Pw,) shards
    state: sim_lib.SimState,
):
    """One simulated day on one local shard; pure in (params, state).

    vmappable over a leading scenario axis of (params, state) — that is
    how a batch rides along on any topology.
    """
    Pw, Vw = static.people_per_worker, static.visits_per_worker
    day = state.day
    dow = day % pop_lib.DAYS_PER_WEEK
    take = lambda a: jax.lax.dynamic_index_in_dim(a, dow, 0, keepdims=False)
    pid = take(week["pid"])  # (Vw,) global person ids, -1 pad
    loc = take(week["loc"])  # (Vw,) global location ids
    vstart, vend = take(week["start"]), take(week["end"])
    p_v = take(week["p"])  # per-visit contact probability
    row_i, col_i = take(week["row"]), take(week["col"])
    row_s, pair_a = take(week["rs"]), take(week["pa"])
    day_route = (
        None if route is None else (take(route["send"]), take(route["recv"]))
    )

    w = topo.worker_index()
    gpid = (w * Pw + jnp.arange(Pw, dtype=jnp.int32)).astype(jnp.uint32)

    # ---- phase 1: interventions + per-person epidemiological channels ----
    visit_ok, loc_open, sus_mult, inf_mult, vaccinated = iv_lib.apply_iv_params(
        static.iv_slots,
        params.iv,
        state.iv_active,
        state.vaccinated,
        Pw,
        static.num_locations,
    )

    # ---- phase 1b: per-agent interventions (test-trace-isolate) ----------
    # Statically compiled out when no TestTraceIsolate slot exists: the
    # traced program below is then the exact pre-PR one (3 dispatch
    # channels, single-channel combine, constant-zero TTI stats).
    K2 = len(static.pa_slots)
    tracing_on = any(ps.trace for ps in static.pa_slots)
    takes, take_any = [], None
    tests_used = jnp.zeros((), jnp.int32)
    if K2:
        in_iso = day < state.isolated_until
        visit_ok = visit_ok & ~in_iso
        sym = params.sym_table[state.health] > 0.0
        detectable = params.inf_table[state.health] > 0.0
        take_any = jnp.zeros((Pw,), bool)
        for k2 in range(K2):
            act = params.iv.pa_enabled[k2] & (day >= params.iv.pa_start[k2])
            elig = (
                act
                & params.iv.pa_people[k2]
                & ~state.tested
                & ~in_iso
                & (sym | state.traced)
            )
            # Symptomatic candidates draw in (0,1), traced-only in (2,3),
            # ineligible sit at 4.0 — one lexicographic top-k over
            # (score, gpid) is then an exact priority-tiered budget.
            u = rng.uniform(params.seed, rng.TEST, day, k2, gpid)
            score = jnp.where(elig & sym, u, jnp.where(elig, u + 2.0, 4.0))
            T, G = topo.rank_threshold(
                score, gpid, params.iv.pa_tests[k2], static.num_people,
                static.test_topk,
            )
            take_k = (
                elig
                & (params.iv.pa_tests[k2] > 0)
                & ((score < T) | ((score == T) & (gpid <= G)))
            )
            takes.append(take_k)
            take_any = take_any | take_k
            tests_used = tests_used + topo.psum(
                take_k.sum().astype(jnp.int32)
            )
        # Result latency: positives circulate today as tracing sources and
        # enter isolation from day+1 (see docs/interventions.md).
        positives = take_any & detectable

    person_sus = params.sus_table[state.health] * params.beta_sus * sus_mult
    person_inf = params.inf_table[state.health] * params.beta_inf * inf_mult

    # ---- visit dispatch (halo exchange): person channels to visit slots --
    person_chans = [person_sus, person_inf, visit_ok.astype(jnp.float32)]
    if tracing_on:
        person_chans.append(positives.astype(jnp.float32))
    chans = jnp.stack(person_chans, axis=-1)
    visit_vals = topo.dispatch(day_route, pid, chans)
    sus_v, inf_v, ok_v = visit_vals[:, 0], visit_vals[:, 1], visit_vals[:, 2]

    # Location-side closures: loc_open is (L,) replicated; gather per visit.
    open_v = loc_open[jnp.minimum(loc, static.num_locations - 1)]
    active = (pid >= 0) & (ok_v > 0.0) & open_v
    eff_pid = jnp.where(active, pid, -1)
    sus_v = sus_v * active
    inf_v = inf_v * active

    # ---- phase 2: block-scheduled interactions ---------------------------
    contact_day = jnp.where(params.static_network, dow, day)
    col_inf = iops.col_has_infectious(
        inf_v, eff_pid, Vw // static.block_size, static.block_size
    )
    row_sus = iops.row_has_susceptible(
        sus_v, eff_pid, Vw // static.block_size, static.block_size
    )
    meta = jnp.stack(
        [params.seed.astype(jnp.uint32), contact_day.astype(jnp.uint32)]
    )
    if tracing_on:
        # Second accumulator: per-visit traced-contact counts ride the
        # same tiles and accumulation order as exposure (bitwise-identical
        # across all five backends, zero extra passes).
        src_v = visit_vals[:, 3] * active
        acc, cnt, edges, trc = iops.interactions_auto_traced(
            eff_pid, loc, vstart, vend, p_v, sus_v, inf_v,
            row_i, col_i, row_s, pair_a, col_inf, row_sus, meta,
            block_size=static.block_size, backend=static.backend,
            src_val=src_v,
        )
    else:
        acc, cnt, edges = iops.interactions_auto_edges(
            eff_pid, loc, vstart, vend, p_v, sus_v, inf_v,
            row_i, col_i, row_s, pair_a, col_inf, row_sus, meta,
            block_size=static.block_size, backend=static.backend,
        )

    # ---- phase 3: exposure combine (adjoint exchange) + update -----------
    if tracing_on:
        # Traced-contact halo rides the exposure combine: channel 0 is
        # bitwise identical to the single-channel combine.
        combined = topo.combine_many(
            day_route, pid, active,
            jnp.stack([acc, trc.astype(jnp.float32)], axis=-1), Pw,
        )
        A = combined[:, 0] * params.tau_eff
        trc_p = combined[:, 1]
    else:
        A = topo.combine(day_route, pid, active, acc, Pw) * params.tau_eff

    infected = tx_lib.sample_infections(A, params.seed, day, pid=gpid)

    def with_seeding(_):
        us = rng.uniform(params.seed, rng.SEED_CHOICE, day, gpid)
        sus_ok = params.sus_table[state.health] > 0.0
        us = jnp.where(sus_ok, us, 2.0)
        thresh = topo.seed_threshold(
            us, params.seed_per_day, static.num_people, static.seed_topk
        )
        return (us <= thresh) & sus_ok & (params.seed_per_day > 0)

    seeded = jax.lax.cond(
        day < params.seed_days,
        with_seeding,
        lambda _: jnp.zeros((Pw,), bool),
        None,
    )

    can_infect = params.sus_table[state.health] > 0.0
    new_mask = (infected | seeded) & can_infect
    health, dwell = disease_lib.update_health_tables(
        params.cum_trans,
        params.dwell_mean,
        params.sus_table,
        params.entry_state,
        state.health,
        state.dwell,
        new_mask,
        params.seed,
        day,
        pid=gpid,
    )

    # ---- global reductions (Algorithm 2 line 34) -------------------------
    new_count = topo.psum(new_mask.sum().astype(jnp.int32))
    cumulative = state.cumulative + new_count
    infectious = topo.psum(
        (params.inf_table[health] > 0.0).sum().astype(jnp.int32)
    )
    susceptible = topo.psum(
        (params.sus_table[health] > 0.0).sum().astype(jnp.int32)
    )
    # Widen before the cross-worker accumulation: at paper scale an int32
    # contacts psum wraps within one day.
    cdtype = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32
    contacts = topo.psum(cnt.sum().astype(cdtype))

    # ---- per-agent state advance (result-latency TTI semantics) ----------
    if K2:
        tested = state.tested | take_any
        iso_until = state.isolated_until
        newly_traced = jnp.zeros((Pw,), bool)
        for k2, ps in enumerate(static.pa_slots):
            pos_k = takes[k2] & detectable
            iso_until = jnp.maximum(
                iso_until,
                jnp.where(pos_k, day + 1 + params.iv.pa_iso[k2], 0),
            )
            if ps.trace:
                act = params.iv.pa_enabled[k2] & (
                    day >= params.iv.pa_start[k2]
                )
                nt_k = (trc_p > 0.0) & params.iv.pa_people[k2] & act
                newly_traced = newly_traced | nt_k
                iso_until = jnp.maximum(
                    iso_until,
                    jnp.where(nt_k, day + 1 + params.iv.pa_trace_iso[k2], 0),
                )
        traced_next = state.traced | newly_traced
        isolated = topo.psum(in_iso.sum().astype(jnp.int32))
        traced_new = topo.psum(newly_traced.sum().astype(jnp.int32))
    else:
        tested = state.tested
        traced_next = state.traced
        iso_until = state.isolated_until
        isolated = jnp.zeros((), jnp.int32)
        traced_new = jnp.zeros((), jnp.int32)

    stats = {
        "day": day,
        "new_infections": new_count,
        "cumulative": cumulative,
        "infectious": infectious,
        "susceptible": susceptible,
        "contacts": contacts,
        # Traversed-edge counter (TEPS numerator). On pallas-compact this
        # is the kernel's SMEM accumulator; elsewhere it is cnt.sum() —
        # both equal `contacts` exactly, which tests assert, making the
        # in-kernel telemetry a cross-checked measurement rather than a
        # trusted one.
        "edges": topo.psum(edges.astype(cdtype)),
        "tests_used": tests_used,
        "isolated": isolated,
        "traced": traced_new,
    }
    iv_active = iv_lib.evaluate_iv_triggers(
        static.iv_slots, params.iv, day, stats, state.iv_active
    )
    new_state = sim_lib.SimState(
        day=day + 1,
        health=health,
        dwell=dwell,
        cumulative=cumulative,
        iv_active=iv_active,
        vaccinated=vaccinated,
        tested=tested,
        traced=traced_next,
        isolated_until=iso_until,
    )
    return new_state, stats


def run_days(
    topo: Topology,
    static: EngineStatic,
    route,
    week,
    params: sim_lib.SimParams,  # leaves carry a leading (B_local,) axis
    state: sim_lib.SimState,  # likewise
    days: int,
    observables: tuple = (),
    carries: tuple = (),
    num_real: int = None,
):
    """A whole run as ONE ``lax.scan`` over the vmapped day step, with
    observable reductions updating inside the scan body.

    ``params``/``state`` carry a leading local scenario axis (B_local >= 1
    on every topology — B=1 single runs included, so downstream code never
    branches on batch-ness). Observables see the *full* real scenario
    batch each day via ``topo.scen_gather`` (a collective over the
    scenario mesh axis when the batch is sharded, identity otherwise), so
    cross-scenario reductions are bitwise-identical on every topology.

    Returns ``(final_state, carries, hist, dailies)`` — ``hist`` leaves
    are day-major ``(days, B_local)``, ``dailies`` are the stacked per-day
    observable outputs over the real batch.
    """
    from repro.api import observables as obs_lib  # cycle-free at call time

    step = jax.vmap(
        lambda p, st: day_step(topo, static, route, week, p, st)
    )

    def body(carry, _):
        st, oc = carry
        st, stats = step(params, st)
        gstats = jax.tree.map(
            lambda x: topo.scen_gather(x, num_real), stats
        )
        oc, daily = obs_lib.update_all(observables, oc, gstats)
        return (st, oc), (stats, daily)

    (state, carries), (hist, dailies) = jax.lax.scan(
        body, (state, carries), None, length=days
    )
    return state, carries, hist, dailies
