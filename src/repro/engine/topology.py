"""The Topology protocol: where the one day loop runs.

The engine core (:mod:`repro.engine.day`) writes the epidemic day step
*once*, against this small protocol, and the runtime "places" it — the
paper's Charm++ move (PAPER.md §IV) translated to SPMD JAX. A topology
answers four questions:

  * **worker collectives** — ``psum``/``pmax`` over the people/location
    partition axis, and the visit/exposure halo exchange (``dispatch`` /
    ``combine``: person-partition → location-partition value routing and
    its additive adjoint). On :class:`LocalTopology` these are identity
    collectives: dispatch is a direct gather by person id, combine a
    segment-sum, psum the value itself.
  * **order statistics** — ``seed_threshold``, the global k-th smallest
    uniform draw that outbreak seeding thresholds on. Local: a full sort.
    Worker-sharded: the union of per-worker top-k candidates gathered over
    the axis (bitwise-equal by construction, see core/simulator_dist.py).
  * **scenario-axis reductions** — ``scen_gather`` reassembles the full
    scenario batch from a shard of it, so cross-scenario observables
    (mean/CI bands, Sobol indices) run *inside* the scan body on every
    topology and are bitwise-identical to a host-side reference: every
    shard sees the identical full ``(B,)`` stats vector and applies the
    identical jnp reduction.
  * **mesh placement** — which named axes exist, so the engine core knows
    which shard_map to wrap around the one scan.

The five legacy engine layouts are products of three topologies:

  ==========  =============================================  ===========
  layout      topology                                       batch axis
  ==========  =============================================  ===========
  single      ``LocalTopology()``                            B = 1
  ensemble    ``LocalTopology()``                            B > 1 (vmap)
  dist        ``MeshTopology("workers")``                    B = 1 (vmap)
  sharded     ``ScenarioTopology("scenarios", B)``           sharded
  hybrid      ``MeshTopology * ScenarioTopology``            sharded
  ==========  =============================================  ===========

vmap and shard_map are applied by *composition* around the one scan
(:func:`repro.engine.day.run_days`); no layout hand-writes its own loop.

Adding a new layout = writing a new Topology (see docs/architecture.md).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import exchange as ex_lib


@dataclasses.dataclass(frozen=True)
class Topology:
    """Identity collectives — the single-device placement, and the base
    class every other topology layers named-axis collectives onto.

    Frozen and field-light so instances hash (they are closed over by
    jitted programs and participate in compilation-cache keys).
    """

    #: mesh axis the people/location partition lives on (None = unsharded)
    worker_axis: Optional[str] = None
    #: mesh axis the scenario batch lives on (None = unsharded)
    scenario_axis: Optional[str] = None

    # -- mesh placement -------------------------------------------------
    @property
    def axis_names(self) -> tuple:
        """Named mesh axes, in (workers, scenarios) order."""
        return tuple(
            a for a in (self.worker_axis, self.scenario_axis) if a is not None
        )

    # -- worker collectives ---------------------------------------------
    def worker_index(self):
        """This worker's position on the worker axis (0 when unsharded)."""
        return jnp.asarray(0, jnp.int32)

    def psum(self, x):
        """Sum over the worker axis; identity on the local topology."""
        return x

    def pmax(self, x):
        """Max over the worker axis; identity on the local topology."""
        return x

    # -- halo exchange (visit dispatch / exposure combine) ---------------
    def dispatch(self, route, pid, chans):
        """Route per-person channels to per-visit slots.

        ``chans`` is ``(P_local, ch)``; returns ``(V_local, ch)`` with
        zeros in inactive slots. Locally the visit schedule indexes people
        directly, so dispatch is a gather masked by the ``pid >= 0``
        padding sentinel; worker-sharded it is the capacity-bucketed
        all_to_all of core/exchange.py (``route`` carries send/recv).
        """
        del route
        return chans[jnp.maximum(pid, 0)] * (pid >= 0)[:, None]

    def combine(self, route, pid, active, acc, num_people_local: int):
        """Adjoint of :meth:`dispatch`: additive per-visit propensities
        back to their owning people. Returns ``(P_local,)``."""
        del route
        return jax.ops.segment_sum(
            jnp.where(active, acc, 0.0),
            jnp.maximum(pid, 0),
            num_segments=num_people_local,
        )

    def combine_many(self, route, pid, active, accs, num_people_local: int):
        """Channel-stacked :meth:`combine`: ``accs`` is ``(V_local, C)``,
        returns ``(P_local, C)``. Each channel folds independently in the
        same per-visit order as the single-channel combine, so channel 0 of
        the result is bitwise identical to ``combine`` of ``accs[:, 0]`` —
        the traced-contact halo rides the exposure halo for free.
        """
        del route
        return jax.ops.segment_sum(
            jnp.where(active[:, None], accs, 0.0),
            jnp.maximum(pid, 0),
            num_segments=num_people_local,
        )

    # -- global order statistic for outbreak seeding ----------------------
    def seed_threshold(self, u, seed_per_day, num_people: int, topk: int):
        """The k-th smallest of the global draw vector ``u`` (k =
        min(seed_per_day, num_people)), computed from this worker's local
        shard of ``u``. Local: a full sort. Sharded: see MeshTopology."""
        del topk
        k = jnp.minimum(seed_per_day, num_people) - 1
        return jnp.sort(u)[jnp.maximum(k, 0)]

    # -- global order statistic for the testing-capacity budget ------------
    def rank_threshold(self, score, gpid, k, num_people: int, topk: int):
        """The k-th smallest *(score, gpid)* pair of the global score
        vector, lexicographically — ``(T, G)`` such that exactly
        ``min(k, count(score < 4.0))`` entries satisfy
        ``score < T or (score == T and gpid <= G)``.

        Because ``gpid`` is globally unique, the lexicographic order is
        total: f32 score ties cannot over-select, which makes the
        capacity-limited test budget *exact* (never exceeds k), not
        approximate — and bitwise identical across mesh shapes, the same
        argument as :meth:`seed_threshold`. Local: one full lexsort.
        Sharded: see MeshTopology.
        """
        del topk
        order = jnp.lexsort((gpid, score))
        idx = jnp.clip(jnp.minimum(k, num_people) - 1, 0, order.shape[0] - 1)
        pick = order[idx]
        return score[pick], gpid[pick]

    # -- scenario-axis reductions -----------------------------------------
    def scen_gather(self, x, num_real: Optional[int] = None):
        """Reassemble the full scenario batch from this shard's slice
        (leading axis), dropping padding slots. Identity when the batch
        axis is unsharded (the local batch IS the full batch)."""
        return x if num_real is None else x[:num_real]

    # -- composition ------------------------------------------------------
    def __mul__(self, other: "Topology"):
        """Product of a worker topology and a scenario topology — the
        hybrid placement. ``MeshTopology() * ScenarioTopology()`` is
        today's 2-D hybrid mesh. Returns ``NotImplemented`` for
        unsupported pairs so reflected compositions (``LocalTopology() *
        ScenarioTopology()``) can resolve via ``__rmul__``."""
        if (self.worker_axis is not None and self.scenario_axis is None
                and other.scenario_axis is not None
                and other.worker_axis is None):
            return ProductTopology(
                worker_axis=self.worker_axis,
                scenario_axis=other.scenario_axis,
            )
        return NotImplemented


class LocalTopology(Topology):
    """Single-device placement: every collective is the identity."""


@dataclasses.dataclass(frozen=True)
class MeshTopology(Topology):
    """People/locations sharded over a named worker axis: psums are real,
    the halo exchange is the capacity-bucketed all_to_all, and the seeding
    order statistic gathers per-worker top-k unions."""

    worker_axis: Optional[str] = "workers"

    def worker_index(self):
        return jax.lax.axis_index(self.worker_axis)

    def psum(self, x):
        return jax.lax.psum(x, self.worker_axis)

    def pmax(self, x):
        return jax.lax.pmax(x, self.worker_axis)

    def dispatch(self, route, pid, chans):
        send, recv = route
        return ex_lib.dispatch(send, recv, chans, pid.shape[0],
                               self.worker_axis)

    def combine(self, route, pid, active, acc, num_people_local: int):
        send, recv = route
        return ex_lib.combine(
            send, recv, acc[:, None] * active[:, None], num_people_local,
            self.worker_axis,
        )[:, 0]

    def combine_many(self, route, pid, active, accs, num_people_local: int):
        send, recv = route
        return ex_lib.combine(
            send, recv, accs * active[:, None], num_people_local,
            self.worker_axis,
        )

    def seed_threshold(self, u, seed_per_day, num_people: int, topk: int):
        # Union of per-worker top-k smallest draws: topk >=
        # min(seed_per_day, P_local) guarantees the global k-th smallest
        # is inside the gathered union, so the threshold is bitwise
        # identical to the local full sort (tests/test_dist.py).
        local_small = -jax.lax.top_k(-u, topk)[0]
        all_small = jnp.sort(
            jax.lax.all_gather(local_small, self.worker_axis).reshape(-1)
        )
        k = jnp.minimum(seed_per_day, num_people) - 1
        return all_small[jnp.clip(k, 0, all_small.shape[0] - 1)]

    def rank_threshold(self, score, gpid, k, num_people: int, topk: int):
        # Per-worker lexicographic top-k candidates, gathered and re-ranked
        # globally. topk >= min(k, P_local) guarantees the global k-th
        # smallest pair is inside the union (identical argument to
        # seed_threshold), so the result is bitwise equal to the local
        # full lexsort on the unsharded score vector.
        order = jnp.lexsort((gpid, score))
        cand = order[:topk]
        g_score = jax.lax.all_gather(
            score[cand], self.worker_axis
        ).reshape(-1)
        g_gpid = jax.lax.all_gather(
            gpid[cand], self.worker_axis
        ).reshape(-1)
        g_order = jnp.lexsort((g_gpid, g_score))
        idx = jnp.clip(
            jnp.minimum(k, num_people) - 1, 0, g_order.shape[0] - 1
        )
        pick = g_order[idx]
        return g_score[pick], g_gpid[pick]


@dataclasses.dataclass(frozen=True)
class ScenarioTopology(Topology):
    """Scenario batch sharded over a named axis; people stay local.
    Scenarios are independent, so the day loop itself needs no
    collectives — only the in-scan cross-scenario observables do, through
    :meth:`scen_gather`."""

    scenario_axis: Optional[str] = "scenarios"

    def scen_gather(self, x, num_real: Optional[int] = None):
        full = jax.lax.all_gather(x, self.scenario_axis, axis=0, tiled=True)
        return full if num_real is None else full[:num_real]

    def __rmul__(self, other):  # Local * Scenario == Scenario
        if isinstance(other, LocalTopology):
            return self
        return NotImplemented


@dataclasses.dataclass(frozen=True)
class ProductTopology(MeshTopology):
    """workers × scenarios: worker collectives from MeshTopology plus the
    scenario gather from ScenarioTopology (the hybrid placement)."""

    scenario_axis: Optional[str] = "scenarios"

    scen_gather = ScenarioTopology.scen_gather


def make_topology(worker_axis: Optional[str],
                  scenario_axis: Optional[str]) -> Topology:
    """The four placements, by which named axes exist."""
    if worker_axis and scenario_axis:
        return ProductTopology(worker_axis=worker_axis,
                               scenario_axis=scenario_axis)
    if worker_axis:
        return MeshTopology(worker_axis=worker_axis)
    if scenario_axis:
        return ScenarioTopology(scenario_axis=scenario_axis)
    return LocalTopology()
