"""BoundedLRU: the one eviction policy for compiled-executable caches.

Two caches in the tree hold XLA executables and must not grow without
bound: ``EngineCore._runners`` (one compiled scan per ``(days,
observables)`` key) and the serving tier's warm shape-bucket table
(:mod:`repro.serve.server`, one resident ``EngineCore`` per bucket).
Both are keyed by hashables, both want least-recently-used eviction
under a max-entries budget, and both need eviction *stats* surfaced to
telemetry — so the policy lives here once and is shared.

Deterministic by construction: recency order is the only state, and it
is driven purely by the caller's get/put sequence (no clocks).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional


class BoundedLRU:
    """An ordered mapping with least-recently-used eviction.

    ``max_entries=None`` means unbounded (the stats still work).
    ``on_evict(key, value)`` observes every eviction — the serve tier
    uses it to count bucket teardowns and drop references promptly.
    """

    def __init__(self, max_entries: Optional[int] = None,
                 on_evict: Optional[Callable] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._on_evict = on_evict
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- mapping surface -------------------------------------------------
    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def __iter__(self):
        """Iterate keys in recency order (least recent first), dict-like."""
        return iter(self._data)

    def keys(self):
        return self._data.keys()

    def get(self, key, default=None):
        """Recency-bumping lookup; counts a hit or a miss."""
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return default

    def peek(self, key, default=None):
        """Lookup without touching recency or the hit/miss counters."""
        return self._data.get(key, default)

    def put(self, key, value) -> None:
        """Insert/overwrite ``key`` as most-recent, evicting the least
        recently used entry if the budget is exceeded."""
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while self.max_entries is not None and len(self._data) > self.max_entries:
            old_key, old_val = self._data.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_key, old_val)

    def pop(self, key, default=None):
        """Remove ``key`` without counting it as an eviction (caller-
        driven invalidation, not budget pressure)."""
        return self._data.pop(key, default)

    # -- telemetry -------------------------------------------------------
    def stats(self) -> dict:
        """The counters the serve metrics and the core introspection
        expose: size/budget plus lifetime hit/miss/eviction counts."""
        return {
            "size": len(self._data),
            "max_entries": self.max_entries,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
