"""One engine core: the topology-parameterized day loop behind every layout.

One ``lax.scan`` (:func:`repro.engine.day.run_days`) written against the
:class:`~repro.engine.topology.Topology` protocol, placed by
:class:`~repro.engine.core.EngineCore` on a local device, a worker mesh, a
scenario mesh, or their product. ``EngineCore.single(...)`` builds the
B=1 case; :func:`repro.api.run` is the declarative front door. See
docs/architecture.md.
"""

from repro.engine.cache import BoundedLRU  # noqa: F401
from repro.engine.core import (  # noqa: F401
    CORE_VERSION,
    CoreDriver,
    EngineCore,
    SequentialDriver,
    build_batch_params,
    index_params,
    no_op_params,
    pad_batch,
    run_chunked,
    stack_params,
)
from repro.engine.day import EngineStatic, day_step, run_days  # noqa: F401
from repro.engine.topology import (  # noqa: F401
    LocalTopology,
    MeshTopology,
    ProductTopology,
    ScenarioTopology,
    Topology,
    make_topology,
)
