"""EngineCore: place the one day loop on a topology, batch it, chunk it.

``repro.engine.day.run_days`` is the single scan every layout executes;
this module owns everything around it:

  * **building** — one shared path compiles a ScenarioBatch into stacked
    ``SimParams``/``SimState`` pytrees (worker-padded when the people
    axis is sharded, scenario-padded with *no-op* params when the batch
    axis is sharded) plus the week/route device arrays the step consumes.
  * **placement** — the four layouts are four ``(topology, mesh)`` pairs;
    vmap is applied inside :func:`repro.engine.day.run_days` and
    shard_map is applied here, by composition, never per-layout loops.
  * **chunking** — :func:`run_chunked` is the day-chunked checkpoint /
    resume loop (moved here from repro.api.runner so every layout resumes
    bitwise, not just single + ensemble).

Scenario padding is *inert*: padded batch slots run with
:func:`no_op_params` (zero betas, zero seeding, every intervention slot
disabled), so no one is ever seeded or infected in a pad slot — under the
``compact`` interaction backend the live-tile count is 0 and the pad
column costs almost nothing. Padded slots are sliced off before any
history leaves the core.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.sweep import Scenario, ScenarioBatch
from repro.core import compat
from repro.engine.cache import BoundedLRU
from repro.core import interactions as inter_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import simulator as sim_lib
from repro.core import simulator_dist as sd
from repro.engine import day as day_lib
from repro.engine.topology import Topology, make_topology

WORKER_AXIS = sd.AXIS  # "workers"
SCENARIO_AXIS = "scenarios"

LAYOUTS = ("local", "workers", "scenarios", "hybrid")

#: Engine-core generation marker; part of every checkpoint's resume key so
#: checkpoints written by incompatible engine generations are refused
#: rather than silently spliced into a trajectory. v2: history gained the
#: "edges" stat (in-kernel traversed-edge telemetry). v3: per-agent
#: interventions — SimState gained tested/traced/isolated_until and
#: history gained the "tests_used"/"isolated"/"traced" stats.
CORE_VERSION = "engine-v3"

_STATE_FIELDS = tuple(f.name for f in dataclasses.fields(sim_lib.SimState))

#: SimState fields with a (worker-padded) person axis — the leaves an
#: elastic repartition must re-pad when the worker count changes.
PERSON_STATE_FIELDS = ("health", "dwell", "vaccinated", "tested", "traced",
                       "isolated_until")


class ResumeKeyError(ValueError):
    """A checkpoint exists but must not be resumed from under this spec
    (incompatible science/engine generation, or beyond the run length).
    A config error, not a fault — the resilient loop never retries it."""


def state_to_tree(state: sim_lib.SimState) -> dict:
    """SimState -> plain dict (stable checkpoint key paths)."""
    return {f: getattr(state, f) for f in _STATE_FIELDS}


def state_from_flat(flat: dict) -> sim_lib.SimState:
    return sim_lib.SimState(**{f: flat[f"state/{f}"] for f in _STATE_FIELDS})


# ---------------------------------------------------------------------------
# batch compilation (the one copy of the slot-structure loop)
# ---------------------------------------------------------------------------


def as_batch(batch: Union[ScenarioBatch, Sequence[Scenario]]) -> ScenarioBatch:
    if isinstance(batch, ScenarioBatch):
        return batch
    return ScenarioBatch.from_scenarios(tuple(batch))


def build_batch_params(pop, batch: ScenarioBatch):
    """Compile every scenario's configs into
    ``(iv_slots, pa_slots, [SimParams, ...])``, validating that the batch
    shares one trace-time slot structure (both intervention families)."""
    slots0, pa0, params_list = None, None, []
    for s in batch:
        slots, pa_slots, params = sim_lib.build_params(
            pop, s.disease, s.tm, s.interventions, s.seed,
            seed_per_day=s.seed_per_day, seed_days=s.seed_days,
            static_network=s.static_network, iv_enabled=s.iv_enabled,
        )
        if slots0 is None:
            slots0, pa0 = slots, pa_slots
        elif slots != slots0 or pa_slots != pa0:
            raise ValueError(
                f"scenario '{s.name}' intervention structure "
                f"{slots + pa_slots} differs from batch structure "
                f"{slots0 + pa0}; ensembles vary thresholds/factors/"
                "enabled, not slot kinds"
            )
        params_list.append(params)
    return slots0, pa0, params_list


def no_op_params(params: sim_lib.SimParams) -> sim_lib.SimParams:
    """An epidemiologically inert SimParams with the same structure:
    zero betas, zero outbreak seeding, every intervention slot disabled.
    A scenario run with these never seeds or infects anyone — the filler
    for padded batch slots."""
    return dataclasses.replace(
        params,
        beta_sus=jnp.zeros_like(params.beta_sus),
        beta_inf=jnp.zeros_like(params.beta_inf),
        seed_per_day=jnp.zeros_like(params.seed_per_day),
        seed_days=jnp.zeros_like(params.seed_days),
        iv=dataclasses.replace(
            params.iv,
            enabled=jnp.zeros_like(params.iv.enabled),
            pa_enabled=jnp.zeros_like(params.iv.pa_enabled),
        ),
    )


def pad_batch(batch: ScenarioBatch, multiple: int) -> ScenarioBatch:
    """Pad a batch to a multiple of the scenario-axis size by repeating
    the final scenario under ``__pad`` names. The *params* of pad slots
    are replaced by :func:`no_op_params` at build time — the repeated
    scenario only supplies trace-time structure."""
    B = len(batch)
    pad = (-B) % multiple
    if pad == 0:
        return batch
    filler = tuple(
        dataclasses.replace(batch[-1], name=f"__pad{i}") for i in range(pad)
    )
    return ScenarioBatch(scenarios=batch.scenarios + filler)


def local_week_arrays(pop, week: inter_lib.WeekData) -> dict:
    """The unified step's ``week`` dict for the unsharded layout: the
    stacked (7, ...) schedule plus per-visit contact probabilities
    gathered once (location attributes are static)."""
    contact_prob = jnp.asarray(pop.contact_prob)
    return {
        "pid": week.pid,
        "loc": week.loc,
        "start": week.start,
        "end": week.end,
        "p": contact_prob[week.loc],
        "row": week.row_idx,
        "col": week.col_idx,
        "rs": week.row_start,
        "pa": week.pair_active,
    }


# ---------------------------------------------------------------------------
# the core
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineCore:
    """One ScenarioBatch placed on one topology, ready to scan.

    ``layout`` picks the placement:

      * ``"local"`` — no mesh; B scenarios vmapped (single runs are B=1).
      * ``"workers"`` — 1-D mesh, people/locations sharded per scenario.
      * ``"scenarios"`` — 1-D mesh, the batch axis sharded.
      * ``"hybrid"`` — 2-D (workers × scenarios) mesh, both.

    All placements execute the identical :func:`repro.engine.day.run_days`
    scan; per-scenario trajectories are bitwise-equal across layouts.
    """

    pop: pop_lib.Population
    batch: Union[ScenarioBatch, Sequence[Scenario]]
    layout: str = "local"
    mesh: Optional[Mesh] = None
    workers: int = 1
    scen_shards: int = 1
    backend: str = "jnp"
    block_size: int = 128
    balanced: bool = True
    pack_visits: bool = True
    max_seed_per_day: Optional[int] = None
    #: Max compiled runners held per core (one per ``(days, observables)``
    #: key), LRU-evicted beyond it. The serve tier's bucket table shares
    #: the same :class:`repro.engine.cache.BoundedLRU` policy. ``None`` =
    #: unbounded (the pre-PR behavior).
    max_runners: Optional[int] = 8

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, "
                             f"got '{self.layout}'")
        self.batch = as_batch(self.batch)
        self.num_real = len(self.batch)
        self._resolve_mesh()
        self.topo: Topology = make_topology(
            WORKER_AXIS if self._worker_sharded else None,
            SCENARIO_AXIS if self._scen_sharded else None,
        )
        self.padded = pad_batch(self.batch, self.scen_shards)

        self.iv_slots, self.pa_slots, params_list = build_batch_params(
            self.pop, self.padded
        )
        num_slots = len(self.iv_slots)

        if self._worker_sharded:
            self.plan = sd.build_dist_plan(
                self.pop, self.workers, self.block_size, self.balanced,
                pack=self.pack_visits,
            )
            self.week, self.route = sd.week_device_arrays(self.plan)
            self.week_data = None
            params_list = [sd.pad_params(p, self.plan) for p in params_list]
            people_per_worker = self.plan.people_per_worker
            visits_per_worker = self.plan.visits_per_worker
            self._init_one = lambda s: sd.dist_init_state(
                s.disease, self.plan, num_slots
            )
        else:
            self.plan = None
            self.week_data = inter_lib.build_week_data(
                self.pop, self.block_size, pack=self.pack_visits
            )
            self.week = local_week_arrays(self.pop, self.week_data)
            self.route = None
            people_per_worker = self.pop.num_people
            visits_per_worker = self.week_data.visits_per_day
            self._init_one = lambda s: sim_lib.init_state(
                s.disease, self.pop.num_people, num_slots
            )

        # Pad slots carry inert params: nothing is seeded or infected
        # there, so the compact backend's live-tile count stays 0.
        for i in range(self.num_real, len(self.padded)):
            params_list[i] = no_op_params(params_list[i])
        self.params = stack_params(params_list)

        max_spd = (self.max_seed_per_day
                   if self.max_seed_per_day is not None
                   else max(s.seed_per_day for s in self.padded))
        # Static top-k width for the testing budget's order statistic:
        # the largest daily capacity any scenario asks for, clamped to the
        # shard width (MeshTopology.rank_threshold is exact as long as
        # test_topk >= min(budget, people_per_worker)).
        max_tests = max(
            [iv.tests_per_day for s in self.padded
             for iv in s.interventions
             if isinstance(iv, iv_lib.TestTraceIsolate)] or [1]
        )
        self.static = day_lib.EngineStatic(
            num_people=self.pop.num_people,
            num_locations=self.pop.num_locations,
            people_per_worker=people_per_worker,
            visits_per_worker=visits_per_worker,
            block_size=self.block_size,
            seed_topk=max(1, min(int(max_spd), people_per_worker)),
            iv_slots=self.iv_slots,
            backend=self.backend,
            pa_slots=self.pa_slots,
            test_topk=max(1, min(int(max_tests), people_per_worker)),
        )
        self._specs = self._build_specs()
        self._runners = BoundedLRU(max_entries=self.max_runners)

    # ------------------------------------------------------------------
    def _resolve_mesh(self):
        from repro.launch import mesh as mesh_lib  # jax-device-state free

        self._worker_sharded = self.layout in ("workers", "hybrid")
        self._scen_sharded = self.layout in ("scenarios", "hybrid")
        if self.layout == "local":
            self.mesh = None
            self.workers, self.scen_shards = 1, 1
            return
        if self.mesh is None:
            if self.layout == "workers":
                self.mesh = mesh_lib.make_worker_mesh(self.workers)
            elif self.layout == "scenarios":
                self.mesh = mesh_lib.make_scenario_mesh(self.scen_shards)
            else:
                self.mesh = mesh_lib.make_hybrid_mesh(
                    self.workers, self.scen_shards
                )
        expect = {
            "workers": (WORKER_AXIS,),
            "scenarios": (SCENARIO_AXIS,),
            "hybrid": (WORKER_AXIS, SCENARIO_AXIS),
        }[self.layout]
        if self.mesh.axis_names != expect:
            raise ValueError(
                f"layout '{self.layout}' expects mesh axes {expect}, "
                f"got {self.mesh.axis_names}"
            )
        self.workers = (int(self.mesh.shape[WORKER_AXIS])
                        if self._worker_sharded else 1)
        self.scen_shards = (int(self.mesh.shape[SCENARIO_AXIS])
                            if self._scen_sharded else 1)

    def _build_specs(self):
        if self.mesh is None:
            return None
        batch = SCENARIO_AXIS if self._scen_sharded else None
        if self._worker_sharded:
            pbase = sd.dist_param_specs()
            sbase = sd.dist_state_specs()
            wspec = P(None, WORKER_AXIS)
        else:
            pbase = jax.tree.map(lambda _: P(), self.params)
            # SimState's structure is static — build the spec tree directly
            # rather than materializing a throwaway device state.
            sbase = sim_lib.SimState(
                day=P(), health=P(), dwell=P(), cumulative=P(),
                iv_active=P(), vaccinated=P(),
                tested=P(), traced=P(), isolated_until=P(),
            )
            wspec = P()
        prepend = lambda tree: jax.tree.map(lambda sp: P(batch, *sp), tree)
        pspec, sspec = prepend(pbase), prepend(sbase)
        hspec = P(None, SCENARIO_AXIS) if self._scen_sharded else P()
        return pspec, sspec, wspec, hspec

    # ------------------------------------------------------------------
    def init_state(self) -> sim_lib.SimState:
        """Stacked initial state over the padded batch (leading axis =
        scenarios; worker-padded person leaves when people are sharded)."""
        return stack_params([self._init_one(s) for s in self.padded])

    def scenario_params(self, i: int) -> sim_lib.SimParams:
        """Scenario ``i``'s un-stacked (possibly worker-padded) params."""
        return index_params(self.params, i)

    def adopt_state(self, state: sim_lib.SimState) -> sim_lib.SimState:
        """Re-home a stacked SimState (possibly from another worker
        layout) onto this core's person padding — the elastic-degradation
        seam: a checkpoint written on W workers continues on this core's
        worker count with the real people bitwise-preserved.

        Person leaves are repartitioned with
        :func:`repro.runtime.elastic.repartition_person_array` (real
        people occupy the first ``num_people`` flat slots in every
        layout — ``person_owner = arange // Pw``); pad entries are
        refilled from this core's :meth:`init_state` template (absorbing
        health, ``ABSORBING_DWELL``, cleared masks), so pad people stay
        epidemiologically inert. States already in this layout pass
        through untouched."""
        from repro.runtime.elastic import (
            plan_elastic_rescale, repartition_person_array,
        )

        tmpl = self.init_state()
        P = self.pop.num_people
        new_layout = plan_elastic_rescale(P, self.workers, self.workers)[1]
        ppad_new = new_layout["workers"] * new_layout["per_worker"]

        def adopt(name):
            old = np.asarray(jax.device_get(getattr(state, name)))
            t = np.asarray(jax.device_get(getattr(tmpl, name)))
            if name not in PERSON_STATE_FIELDS or old.shape == t.shape:
                return getattr(state, name)
            if old.ndim < 2 or old.shape[0] != t.shape[0]:
                raise ValueError(
                    f"adopt_state: cannot re-home leaf '{name}' of shape "
                    f"{old.shape} onto batch template {t.shape}")
            out = []
            for i in range(old.shape[0]):  # per scenario in the batch
                fill = t[i, -1] if ppad_new > P else 0
                out.append(repartition_person_array(
                    old[i], P, self.workers, fill=fill).reshape(-1))
            new = np.stack(out)
            assert new.shape == t.shape, (name, new.shape, t.shape)
            return jnp.asarray(new)

        return sim_lib.SimState(**{f: adopt(f) for f in _STATE_FIELDS})

    # ------------------------------------------------------------------
    def _runner(self, days: int, observables: tuple):
        key = (days, observables)
        cached = self._runners.get(key)
        if cached is not None:
            return cached
        topo, static, num_real = self.topo, self.static, self.num_real
        worker_sharded = self._worker_sharded

        def worker(params, state, carries, week, route):
            if worker_sharded:
                week = jax.tree.map(lambda a: a.squeeze(1), week)
                route = jax.tree.map(lambda a: a.squeeze(1), route)
            return day_lib.run_days(
                topo, static, route, week, params, state, days,
                observables, carries, num_real,
            )

        if self.mesh is None:
            runner = jax.jit(worker)
        else:
            pspec, sspec, wspec, hspec = self._specs
            runner = jax.jit(
                compat.shard_map(
                    worker,
                    mesh=self.mesh,
                    # carries/dailies ride replicated: every shard sees the
                    # full gathered stats, so their reductions are identical.
                    in_specs=(pspec, sspec, P(), wspec, wspec),
                    out_specs=(sspec, P(), hspec, P()),
                )
            )
        self._runners.put(key, runner)
        return runner

    # ------------------------------------------------------------------
    # runner-cache introspection (the serve tier's compile-once seam)
    # ------------------------------------------------------------------

    def runner_fn(self, days: int, observables: tuple = ()):
        """The compiled runner for ``(days, observables)`` — built (and
        cached) on first request. Public so the serving tier can wrap the
        steady-state loop in :class:`repro.analysis.hlo.recompile_sentinel`
        around the *actual* jitted callable, not a re-wrapped copy."""
        return self._runner(days, tuple(observables))

    def runner_cached(self, days: int, observables: tuple = ()) -> bool:
        """Whether the ``(days, observables)`` runner is already resident
        (no recency bump, no stats churn) — the warm/cold probe."""
        return self._runners.peek((days, tuple(observables))) is not None

    def runner_cache_stats(self) -> dict:
        """Size/budget and lifetime hit/miss/eviction counters of the
        per-core runner cache (see :class:`repro.engine.cache.BoundedLRU`)."""
        return self._runners.stats()

    def bench_fn(self, days: int, observables: tuple = ()):
        """A zero-argument timed callable for benchmarks: runs the whole
        compiled scan and returns a device scalar (no host transfer of
        the history), so ``block_until_ready``-style timers measure the
        program, not the gather."""
        runner = self._runner(days, tuple(observables))
        params, state = self.params, self.init_state()
        week, route = self.week, self.route
        carries = ()
        if observables:
            from repro.api import observables as obs_lib

            carries = obs_lib.init_carries(
                tuple(observables),
                obs_lib.ObsContext(num_people=self.pop.num_people,
                                   num_scenarios=self.num_real),
            )
        return lambda: runner(params, state, carries, week, route)[0].day

    def run_days(
        self,
        days: int,
        *,
        params: Optional[sim_lib.SimParams] = None,
        state: Optional[sim_lib.SimState] = None,
        observables: tuple = (),
        carries: tuple = (),
    ):
        """Run ``days`` days as one jitted scan on this core's topology.

        Returns ``(final_state, carries, hist, dailies)``: ``hist`` maps
        STAT_KEYS to host ``(days, B_real)`` arrays (padded slots sliced
        off — they never leave the core), ``carries``/``dailies`` are the
        threaded observable reductions (device carries, host dailies).
        ``params`` substitutes other same-structure params (it is a traced
        argument — one compiled program serves any same-shape batch).
        """
        params = params if params is not None else self.params
        state = state if state is not None else self.init_state()
        runner = self._runner(days, tuple(observables))
        state, carries, hist, dailies = runner(
            params, state, carries, self.week, self.route
        )
        hist = {
            k: np.asarray(v)[:, : self.num_real]
            for k, v in jax.device_get(hist).items()
        }
        return state, carries, hist, jax.device_get(dailies)

    # ------------------------------------------------------------------
    # convenience front doors (what the removed legacy engine classes
    # exposed; repro.api.run() remains the spec-driven entry point)
    # ------------------------------------------------------------------

    @classmethod
    def single(
        cls,
        pop: pop_lib.Population,
        disease,
        tm=None,
        *,
        interventions: Sequence = (),
        iv_enabled: Sequence = (),
        seed: int = 0,
        seed_per_day: int = 10,
        seed_days: int = 7,
        static_network: bool = False,
        name: str = "single",
        **core_kw,
    ) -> "EngineCore":
        """A one-scenario core — the single-run construction in one call. ``core_kw`` passes
        through the placement fields (``layout``, ``mesh``, ``workers``,
        ``backend``, ``block_size``, ``balanced``, ``pack_visits``,
        ``max_seed_per_day``); pair with :meth:`run1` for unbatched
        results."""
        from repro.core import transmission as tx_lib  # cycle-free late

        scen = Scenario(
            name=name, disease=disease,
            tm=tm if tm is not None else tx_lib.TransmissionModel(),
            interventions=tuple(interventions),
            iv_enabled=tuple(iv_enabled), seed=seed,
            seed_per_day=seed_per_day, seed_days=seed_days,
            static_network=static_network,
        )
        return cls(pop, [scen], **core_kw)

    def run(
        self,
        days: int,
        *,
        state: Optional[sim_lib.SimState] = None,
        params: Optional[sim_lib.SimParams] = None,
    ):
        """``(final_state, hist)`` over the batch — the legacy ensemble
        ``.run`` contract. ``hist`` arrays are ``(days, B_real)``; pad
        slots are dropped from the final state too (feed states back
        through :meth:`run_days` instead when day-chunking a padded
        batch)."""
        final, _, hist, _ = self.run_days(days, state=state, params=params)
        final = jax.tree.map(lambda x: x[: self.num_real], final)
        return final, hist

    def run1(
        self,
        days: int,
        *,
        state: Optional[sim_lib.SimState] = None,
        params: Optional[sim_lib.SimParams] = None,
    ):
        """B=1 convenience: :meth:`run` with the scenario axis squeezed —
        the legacy single-scenario ``.run`` contract. Accepts and returns
        *unbatched* state/params; ``hist`` arrays are ``(days,)``.

        ``params`` substitutes another scenario's :class:`SimParams`
        (same trace-time structure) without recompiling — params is a
        traced argument of the compiled scan, so one program serves a
        scenario batch run sequentially."""
        assert self.num_real == 1, "run1() needs a batch of exactly 1"
        add_b = lambda t: (
            None if t is None else jax.tree.map(lambda x: x[None], t)
        )
        final, _, hist, _ = self.run_days(
            days, state=add_b(state), params=add_b(params)
        )
        final = jax.tree.map(lambda x: x[0], final)
        return final, {k: v[:, 0] for k, v in hist.items()}

    def init_state1(self) -> sim_lib.SimState:
        """Unbatched initial state (B=1 cores; pairs with :meth:`run1`)."""
        assert self.num_real == 1, "init_state1() needs a batch of exactly 1"
        return index_params(self.init_state(), 0)


# ---------------------------------------------------------------------------
# stacked-pytree helpers
# ---------------------------------------------------------------------------


def stack_params(params_list: Sequence) -> object:
    """Stack identically-structured pytrees on a new leading batch axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def index_params(batched, i: int):
    """Slice scenario ``i`` back out of a stacked pytree (inverse of
    :func:`stack_params`)."""
    return jax.tree.map(lambda x: x[i], batched)


# ---------------------------------------------------------------------------
# the day-chunked checkpoint/resume loop (engine-level: all layouts)
# ---------------------------------------------------------------------------


def concat_hists(hists: list) -> dict:
    return {k: np.concatenate([h[k] for h in hists], axis=0)
            for k in hists[0]}


def concat_dailies(chunks: list):
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *chunks)


def run_chunked(
    driver,
    days: int,
    observables: tuple,
    ctx,
    *,
    manager=None,
    every: int = 50,
    resume: bool = True,
    resume_key: Optional[dict] = None,
    hooks=None,
):
    """Scan ``every``-day chunks through ``driver``, checkpointing state +
    history-so-far at each boundary and resuming bitwise from the latest
    compatible checkpoint.

    ``driver`` is the minimal chunk surface: ``init_state()``,
    ``run_chunk(n, state, carries) -> (state, hist, carries, dailies)``,
    and an ``in_scan`` flag (False only for the sequential
    one-scenario-at-a-time facade, whose cross-scenario reductions replay
    post-run). Observable carries are never checkpointed: on resume the
    pure updates replay over the restored history, reconstructing them
    exactly (see repro.api.observables).

    Resume picks the newest snapshot that passes integrity verification —
    corrupt/truncated snapshots are quarantined by the checkpoint manager
    and the next-older valid step is used. If the driver exposes
    ``adapt_state`` (the engine drivers do), the restored state is passed
    through it, so a snapshot written under another worker layout
    continues on this one (elastic degradation).

    ``hooks`` (optional; see :mod:`repro.runtime.resilience`) observes the
    loop at chunk granularity: ``on_start(state, day)``,
    ``before_chunk(day, n)``, ``after_chunk(end_day, state, dt) -> state``
    (called *before* the boundary snapshot, so invariant guards can veto a
    poisoned state reaching disk), ``after_save(day)``. Hook exceptions
    propagate — they are the fault-injection and guard-violation surface.

    Returns ``(state, hist, carries, dailies, resumed_from, num_chunks)``.
    """
    from repro.api import observables as obs_lib  # cycle-free at call time

    state, carries, hists, daily_chunks = None, None, [], []
    day, resumed_from = 0, None
    step = manager.latest_valid_step() if manager is not None and resume \
        else None
    if step is not None:
        if step > days:
            raise ResumeKeyError(
                f"checkpoint at day {step} is beyond spec.days={days}")
        saved_key = manager.manifest(step).get("extra", {}).get("resume_key")
        if saved_key != resume_key:
            raise ResumeKeyError(
                f"checkpoint at day {step} in {manager.directory} was "
                + ("written by an incompatible spec or engine generation "
                   "(different parameters, sweep axes, mesh, or a "
                   "pre-refactor engine)" if saved_key is not None
                   else "not written by repro.api.run (no resume_key in "
                        "its manifest)")
                + "; refusing to splice trajectories — point "
                "checkpoint.directory elsewhere or set "
                "checkpoint.resume=false")
        flat = manager.restore_flat(step)
        state = state_from_flat(flat)
        if hasattr(driver, "adapt_state"):
            state = driver.adapt_state(state)
        hists = [{k: flat[f"hist/{k}"] for k in sim_lib.STAT_KEYS}]
        if driver.in_scan:
            # Replay the pure reductions over the restored history so the
            # carries continue exactly where the interrupted scan left off.
            carries, pre = obs_lib.scan_history(observables, hists[0], ctx)
            daily_chunks = [jax.device_get(pre)]
        day, resumed_from = step, step
    if state is None:
        state = driver.init_state()
    if carries is None and driver.in_scan:
        carries = obs_lib.init_carries(observables, ctx)
    if hooks is not None:
        hooks.on_start(state, day)

    chunk = every if manager is not None else days
    num_chunks = 0
    while day < days:
        n = min(chunk, days - day)
        t0 = time.perf_counter()
        if hooks is not None:
            hooks.before_chunk(day, n)
        state, hist, carries, dl = driver.run_chunk(n, state, carries)
        if hooks is not None:
            # May raise (guard veto of a poisoned state) — nothing below
            # runs, so the poison is never appended or checkpointed.
            state = hooks.after_chunk(day + n, state,
                                      time.perf_counter() - t0)
        hists.append(hist)
        if dl is not None:
            daily_chunks.append(dl)
        day += n
        num_chunks += 1
        if manager is not None:
            # Each boundary rewrites the full history-so-far: O(days^2)
            # bytes over a run, but history is ~6 scalars/scenario/day and
            # a self-contained latest checkpoint keeps restore trivial.
            manager.save(day, {
                "day": np.asarray(day, np.int32),
                "state": state_to_tree(state),
                "hist": concat_hists(hists),
            }, extra={"resume_key": resume_key})
            if hooks is not None:
                hooks.after_save(day)
    if manager is not None:
        manager.wait()

    hist = concat_hists(hists)
    dailies = concat_dailies(daily_chunks) if daily_chunks else None
    return state, hist, carries, dailies, resumed_from, num_chunks


# ---------------------------------------------------------------------------
# chunk drivers over the core
# ---------------------------------------------------------------------------


class CoreDriver:
    """One-program driver: the whole batch lives in one scan on one
    topology, so the observable updates run inside the scan body."""

    in_scan = True

    def __init__(self, core: EngineCore, observables: tuple):
        self.core = core
        self.observables = tuple(observables)

    def init_state(self):
        return self.core.init_state()

    def adapt_state(self, state):
        return self.core.adopt_state(state)

    def run_chunk(self, n, state, carries):
        state, carries, hist, dailies = self.core.run_days(
            n, state=state, observables=self.observables, carries=carries
        )
        return state, hist, carries, dailies


class SequentialDriver:
    """One scenario at a time through a B=1 slice of the core's program —
    the pinned single/dist layout with B > 1 (lowest memory footprint; one
    compiled scan serves the whole batch). Cross-scenario observables
    cannot live inside per-scenario scans, so reductions replay post-run
    (``in_scan = False``)."""

    in_scan = False

    def __init__(self, core: EngineCore):
        self.core = core
        self.params_list = [
            jax.tree.map(lambda x: x[i: i + 1], core.params)
            for i in range(core.num_real)
        ]

    def init_state(self):
        return self.core.init_state()

    def adapt_state(self, state):
        return self.core.adopt_state(state)

    def run_chunk(self, n, state, carries):
        finals, hists = [], []
        for i, params_i in enumerate(self.params_list):
            state_i = jax.tree.map(lambda x: x[i: i + 1], state)
            f, _, h, _ = self.core.run_days(n, params=params_i, state=state_i)
            finals.append(jax.tree.map(lambda x: x[0], f))
            hists.append({k: v[:, 0] for k, v in h.items()})
        state = stack_params(finals)
        hist = {k: np.stack([h[k] for h in hists], axis=1)
                for k in sim_lib.STAT_KEYS}
        return state, hist, carries, None
