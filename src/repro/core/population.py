"""Population container, visit schedules, and partitioning (paper §IV-A, §V-B).

A population is a bipartite people–location graph with a weekly visit
schedule (visits repeat every 7 days unless interventions change them). For
the TPU formulation every day's visits are stored as flat arrays **presorted
by location id** and padded to a static size, so a day step is a fixed-shape
jitted program. Interventions never change shapes — they toggle per-visit
``active`` masks and per-person attribute multipliers.

Static load balancing (paper §V-B) is reproduced exactly: locations are
sorted by a geographic key, load is estimated by visit counts, and locations
are greedily packed into partitions until each reaches the mean load. The
same packing drives (a) the shard_map location sharding and (b) the active
block-pair schedule of the interaction kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import contact as contact_lib

DAYS_PER_WEEK = 7


@dataclasses.dataclass
class DayVisits:
    """One day-of-week's visits, sorted by (loc, start), padded to length V.

    Padding entries have ``person == -1`` and ``active == False`` and sort to
    the end (loc == num_locations sentinel is avoided; padding keeps the last
    real loc id so sortedness holds, but active=False removes it from all
    math)."""

    person: np.ndarray  # (V,) int32
    loc: np.ndarray  # (V,) int32, non-decreasing over active prefix
    start: np.ndarray  # (V,) float32 seconds since midnight
    end: np.ndarray  # (V,) float32
    active: np.ndarray  # (V,) bool
    num_real: int

    def __len__(self) -> int:
        return len(self.person)


@dataclasses.dataclass
class Population:
    """People, locations, and a weekly visit schedule."""

    name: str
    num_people: int
    num_locations: int
    # Person attributes
    age_group: np.ndarray  # (P,) int8 (0: child, 1: adult, 2: senior)
    beta_sus: np.ndarray  # (P,) f32 susceptibility multiplier beta_sigma
    beta_inf: np.ndarray  # (P,) f32 infectivity multiplier beta_iota
    home_loc: np.ndarray  # (P,) int32
    # Location attributes
    loc_type: np.ndarray  # (L,) int8 (0 home, 1 work, 2 school, 3 other)
    geo_key: np.ndarray  # (L,) int64 sort key (state/county/tract/blockgroup)
    max_occupancy: np.ndarray  # (L,) int32
    contact_prob: np.ndarray  # (L,) f32, from the contact model
    # Weekly schedule
    week: list  # list[DayVisits] of length 7

    @property
    def visits_per_week(self) -> int:
        return int(sum(d.num_real for d in self.week))

    def day(self, day_index: int) -> DayVisits:
        return self.week[day_index % DAYS_PER_WEEK]

    def finalize_contact_model(self, model=None) -> None:
        """Compute per-location max occupancy (pre-processing, §IV-C3) and
        contact probabilities. Mutates ``max_occupancy``/``contact_prob``."""
        model = model or contact_lib.MinMaxAlpha()
        occ = np.zeros((self.num_locations,), np.int32)
        for d in self.week:
            n = d.num_real
            occ = np.maximum(
                occ,
                contact_lib.max_occupancy_fast(
                    self.num_locations, d.loc[:n], d.start[:n], d.end[:n]
                ),
            )
        self.max_occupancy = occ
        self.contact_prob = np.asarray(model.probability(occ), np.float32)

    def stats(self) -> dict:
        return {
            "people": self.num_people,
            "locations": self.num_locations,
            "visits_per_week": self.visits_per_week,
            "mean_visits_per_person_day": self.visits_per_week
            / max(1, self.num_people) / DAYS_PER_WEEK,
            "max_occupancy_p99": int(np.percentile(self.max_occupancy, 99))
            if len(self.max_occupancy) else 0,
        }

    def preprocess(self, model=None, block_size: int = 128,
                   pack: bool = True) -> dict:
        """Full pre-processing pass (§IV-C3): contact model finalization
        plus, when ``pack``, the occupancy-aware schedule-packing summary
        for ``block_size`` — NP (block-pair tiles) before/after packing per
        week, aggregated. The dict is also stored as ``preprocess_stats``.
        """
        self.finalize_contact_model(model)
        stats = self.stats()
        if pack:
            stats["packing"] = week_packing_stats(self, block_size)
        self.preprocess_stats = stats
        return stats


def pack_day(
    person: np.ndarray,
    loc: np.ndarray,
    start: np.ndarray,
    end: np.ndarray,
    pad_to: Optional[int] = None,
    pad_multiple: int = 128,
) -> DayVisits:
    """Sort one day's raw visits by (loc, start) and pad to a static size."""
    order = np.lexsort((start, loc))
    person, loc = person[order], loc[order]
    start, end = start[order], end[order]
    n = len(person)
    size = pad_to if pad_to is not None else n
    size = int(np.ceil(max(size, 1) / pad_multiple) * pad_multiple)
    assert size >= n, (size, n)

    def pad(a, fill):
        out = np.full((size,), fill, a.dtype)
        out[:n] = a
        return out

    last_loc = loc[-1] if n else 0
    return DayVisits(
        person=pad(person.astype(np.int32), -1),
        loc=pad(loc.astype(np.int32), last_loc),
        start=pad(start.astype(np.float32), 0.0),
        end=pad(end.astype(np.float32), 0.0),
        active=pad(np.ones((n,), np.bool_), False),
        num_real=n,
    )


def pad_week_uniform(week: list, pad_multiple: int = 128) -> list:
    """Re-pad all 7 days to one common size so a single jit serves the week."""
    size = max(len(d) for d in week)
    size = int(np.ceil(size / pad_multiple) * pad_multiple)
    out = []
    for d in week:
        n = d.num_real
        out.append(
            pack_day(d.person[:n], d.loc[:n], d.start[:n], d.end[:n], pad_to=size,
                     pad_multiple=pad_multiple)
        )
    return out


# ----------------------------------------------------------------------------
# Occupancy-aware visit packing (active-set schedule compaction)
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class PackedDayVisits:
    """One day's visits in the *occupancy-packed* layout.

    Unlike :class:`DayVisits` (globally (loc, start)-sorted, padding only at
    the end), the packed layout reorders whole location runs so that small
    locations never straddle a block boundary and giant locations start on
    one, which shrinks the block-pair schedule NP. Alignment padding may sit
    *inside* the array: padding slots carry ``person == -1`` and repeat the
    preceding run's loc id, so run detection in
    :func:`build_block_schedule` merges them into that run without growing
    its block span. ``extent`` is the prefix length containing every real
    visit — trailing padding beyond it must not be scanned for runs.
    """

    person: np.ndarray  # (V,) int32, -1 on padding (interior or trailing)
    loc: np.ndarray  # (V,) int32; padding repeats the preceding run's loc
    start: np.ndarray  # (V,) float32
    end: np.ndarray  # (V,) float32
    active: np.ndarray  # (V,) bool
    extent: int  # slots [0, extent) hold all real visits + alignment pads
    num_real: int  # count of real visits
    np_before: int = 0  # schedule tiles of the canonical layout
    np_after: int = 0  # schedule tiles of this layout (<= np_before)

    def __len__(self) -> int:
        return len(self.person)


def occupancy_pack_order(
    loc_sorted: np.ndarray,  # (n,) run-contiguous visit loc ids
    block_size: int,
) -> tuple[np.ndarray, int]:
    """Greedy occupancy-aware packing of location runs into block-aligned
    segments. Returns ``(slot_src, extent)``: ``slot_src`` maps output slot
    -> input visit index (-1 = alignment padding) for the first ``extent``
    slots.

    Strategy (first-fit decreasing):
      * runs with >= block_size visits start on a block boundary, so their
        O((run/b)^2) tile band absorbs no neighbors;
      * the partial tail block of a big run becomes an open bin — small
        runs placed there add **zero** tiles (the (tail, tail) tile is
        already in the band);
      * remaining small runs are first-fit-decreasing bin-packed into
        whole blocks, so none straddles a boundary.
    """
    b = block_size
    n = len(loc_sorted)
    if n == 0:
        return np.full((0,), -1, np.int64), 0
    change = np.flatnonzero(np.diff(loc_sorted)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [n]])
    counts = ends - starts
    run_order = sorted(range(len(starts)), key=lambda i: (-counts[i], i))

    segments: list[list[int]] = []  # each: run indices, emitted in order
    bins: list[list[int]] = []  # [segment_index, free_slots]
    for r in run_order:
        c = int(counts[r])
        if c >= b:
            segments.append([r])
            free = (-c) % b
            if free:
                bins.append([len(segments) - 1, free])
        else:
            for entry in bins:
                if entry[1] >= c:
                    segments[entry[0]].append(r)
                    entry[1] -= c
                    break
            else:
                segments.append([r])
                bins.append([len(segments) - 1, b - c])

    slot_src: list[int] = []
    for seg in segments:
        seg_start = len(slot_src)
        for r in seg:
            slot_src.extend(range(int(starts[r]), int(ends[r])))
        pad = (-(len(slot_src) - seg_start)) % b
        slot_src.extend([-1] * pad)
    return np.asarray(slot_src, np.int64), len(slot_src)


def pack_day_occupancy(
    day: DayVisits,
    block_size: int,
    pad_to: Optional[int] = None,
) -> PackedDayVisits:
    """Re-layout one (loc, start)-sorted day into the occupancy-packed
    order. Epidemiologically a no-op: the counter-based RNG keys every draw
    on (pid, pid, day, loc), so visit layout is a free variable — validated
    against the dense oracle in tests/test_interactions.py."""
    n = day.num_real
    src, extent = occupancy_pack_order(np.asarray(day.loc[:n]), block_size)
    size = max(extent, pad_to or 0, block_size)
    size = int(np.ceil(size / block_size) * block_size)
    assert size >= extent, (size, extent)

    def take(a, fill):
        out = np.full((size,), fill, a.dtype)
        sel = src >= 0
        out[: extent][sel] = a[:n][src[sel]]
        return out

    person = take(day.person, np.int32(-1))
    start = take(day.start, np.float32(0.0))
    end = take(day.end, np.float32(0.0))
    loc = take(day.loc, np.int32(0))
    # Padding repeats the preceding run's loc id (forward fill) so the
    # diff-based run detection merges it without extending any block span.
    pad_mask = np.ones((size,), bool)
    pad_mask[: extent] = src < 0
    if pad_mask.any() and not pad_mask.all():
        idx = np.where(pad_mask, 0, np.arange(size))
        idx = np.maximum.accumulate(idx)
        loc = loc[idx]
    if n == 0:
        return PackedDayVisits(
            person=person, loc=loc, start=start, end=end,
            active=person >= 0, extent=extent, num_real=n,
            np_before=1, np_after=1,  # build_block_schedule's (0,0) fallback
        )
    # First-fit-decreasing can (rarely) lose to a lucky sorted layout whose
    # run boundaries happen to coincide with block boundaries; guard so
    # "packing never grows NP" is an invariant, not a heuristic outcome.
    # The two schedule sizes are kept on the result so callers
    # (week_packing_stats, benches) don't rebuild schedules to report them.
    v0 = int(np.ceil(n / block_size) * block_size)
    base_loc = np.concatenate(
        [day.loc[:n], np.full(v0 - n, day.loc[n - 1], day.loc.dtype)]
    )
    np_before = build_block_schedule(base_loc, n, block_size).num_pairs
    np_after = build_block_schedule(loc, extent, block_size).num_pairs
    if np_after > np_before:
        size_c = max(v0, pad_to or 0, block_size)
        size_c = int(np.ceil(size_c / block_size) * block_size)

        def pad_c(a, fill):
            out = np.full((size_c,), fill, a.dtype)
            out[:n] = a[:n]
            return out

        return PackedDayVisits(
            person=pad_c(day.person, np.int32(-1)),
            loc=pad_c(day.loc, day.loc[n - 1]),
            start=pad_c(day.start, np.float32(0.0)),
            end=pad_c(day.end, np.float32(0.0)),
            active=pad_c(day.active, False),
            extent=n,
            num_real=n,
            np_before=np_before,
            np_after=np_before,
        )
    return PackedDayVisits(
        person=person, loc=loc, start=start, end=end,
        active=person >= 0, extent=extent, num_real=n,
        np_before=np_before, np_after=np_after,
    )


def extend_packed(p: PackedDayVisits, size: int) -> PackedDayVisits:
    """Grow a packed day with trailing padding (uniform week sizing)."""
    if size == len(p):
        return p
    assert size > len(p), (size, len(p))
    pad = size - len(p)

    def ext(a, fill):
        return np.concatenate([a, np.full((pad,), fill, a.dtype)])

    return PackedDayVisits(
        person=ext(p.person, np.int32(-1)),
        loc=ext(p.loc, p.loc[-1] if len(p.loc) else np.int32(0)),
        start=ext(p.start, np.float32(0.0)),
        end=ext(p.end, np.float32(0.0)),
        active=ext(p.active, False),
        extent=p.extent,
        num_real=p.num_real,
        np_before=p.np_before,
        np_after=p.np_after,
    )


# ----------------------------------------------------------------------------
# Static load balancing (paper §V-B)
# ----------------------------------------------------------------------------


def balanced_location_partition(
    geo_key: np.ndarray,  # (L,) sort key
    visits_per_loc: np.ndarray,  # (L,) load proxy (weekly visit counts)
    num_partitions: int,
) -> np.ndarray:
    """Greedy prefix packing of geo-sorted locations by visit-count load.

    Returns part_of_loc (L,) int32. Mirrors the paper: sort by geography,
    accumulate until the partition exceeds the mean load, move on; the last
    partition takes the remainder. Heavy locations may own a partition alone.
    """
    L = len(geo_key)
    order = np.argsort(geo_key, kind="stable")
    loads = visits_per_loc[order].astype(np.float64)
    total = float(loads.sum())
    target = total / max(num_partitions, 1)
    part = np.zeros((L,), np.int32)
    cur, acc = 0, 0.0
    for i in range(L):
        part[order[i]] = cur
        acc += loads[i]
        if acc >= target * (cur + 1) and cur < num_partitions - 1:
            cur += 1
    return part


def naive_location_partition(num_locations: int, num_partitions: int) -> np.ndarray:
    """Uniform-count split (the paper's 'no load balancing' baseline)."""
    return (
        np.arange(num_locations, dtype=np.int64) * num_partitions // max(num_locations, 1)
    ).astype(np.int32)


def partition_people(num_people: int, num_partitions: int) -> np.ndarray:
    """People are uniformly partitioned (visit fan-out is what's balanced)."""
    return (
        np.arange(num_people, dtype=np.int64) * num_partitions // max(num_people, 1)
    ).astype(np.int32)


def partition_imbalance(part: np.ndarray, load: np.ndarray, num_partitions: int) -> float:
    """max/mean partition load — the metric Fig 2 is about."""
    per = np.zeros((num_partitions,), np.float64)
    np.add.at(per, part, load.astype(np.float64))
    mean = per.mean()
    return float(per.max() / mean) if mean > 0 else 1.0


# ----------------------------------------------------------------------------
# Block-pair schedule for the interaction pass
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class BlockSchedule:
    """Active (row_block, col_block) tile pairs for a location-sorted visit
    array: exactly the tiles that contain at least one same-location pair.
    This is the static block-sparsity structure that replaces the paper's
    per-location event queues. Ordered row-major so each row block's column
    tiles are consecutive (enables streaming accumulation in the kernel)."""

    block_size: int
    num_blocks: int  # V / block_size
    row_block: np.ndarray  # (NP,) int32
    col_block: np.ndarray  # (NP,) int32
    row_start: np.ndarray  # (NP,) bool — first pair of its row-block run
    pair_active: np.ndarray  # (NP,) bool — False on padding pairs
    num_pairs: int  # number of active pairs

    @property
    def dense_pairs(self) -> int:
        return self.num_blocks * self.num_blocks

    @property
    def sparsity(self) -> float:
        return 1.0 - self.num_pairs / max(self.dense_pairs, 1)


def build_block_schedule(
    loc_sorted: np.ndarray,  # (V,) visit loc ids, non-decreasing on real prefix
    num_real: int,
    block_size: int,
    pad_to: Optional[int] = None,
) -> BlockSchedule:
    V = len(loc_sorted)
    assert V % block_size == 0, (V, block_size)
    nb = V // block_size
    pairs: set[tuple[int, int]] = set()
    if num_real > 0:
        loc = loc_sorted[:num_real]
        # Run boundaries of each location segment.
        change = np.flatnonzero(np.diff(loc)) + 1
        starts = np.concatenate([[0], change])
        ends = np.concatenate([change, [num_real]])
        for s, e in zip(starts, ends):
            b0, b1 = s // block_size, (e - 1) // block_size
            for rb in range(b0, b1 + 1):
                for cb in range(b0, b1 + 1):
                    pairs.add((rb, cb))
    if not pairs:
        pairs.add((0, 0))
    arr = np.array(sorted(pairs), np.int32)
    num_pairs = len(arr)
    pair_active = np.ones((num_pairs,), np.bool_)
    if pad_to is not None and pad_to > num_pairs:
        # Pad by repeating the final pair with active=False. The repeat keeps
        # the kernel's output index_map constant over the padding (no output
        # block eviction/revisit with undefined contents) and the active flag
        # makes the body a no-op, so there is no double counting.
        reps = np.repeat(arr[-1:], pad_to - num_pairs, axis=0)
        arr = np.concatenate([arr, reps])
        pair_active = np.concatenate(
            [pair_active, np.zeros((pad_to - num_pairs,), np.bool_)]
        )
    row_block, col_block = arr[:, 0].copy(), arr[:, 1].copy()
    row_start = np.zeros((len(arr),), np.bool_)
    seen: set[int] = set()
    for k in range(len(arr)):
        if pair_active[k] and int(row_block[k]) not in seen:
            row_start[k] = True
            seen.add(int(row_block[k]))
    return BlockSchedule(
        block_size=block_size,
        num_blocks=nb,
        row_block=row_block,
        col_block=col_block,
        row_start=row_start,
        pair_active=pair_active,
        num_pairs=num_pairs,
    )


def week_packing_stats(pop: "Population", block_size: int) -> dict:
    """Schedule-size effect of occupancy-aware packing over a population's
    week: total block-pair tiles (NP) and padded visit-slot counts before
    and after :func:`pack_day_occupancy`, summed over the 7 days."""
    np_before = np_after = v_before = v_after = 0
    for d in pop.week:
        n = d.num_real
        base = pack_day(
            d.person[:n], d.loc[:n], d.start[:n], d.end[:n],
            pad_multiple=block_size,
        )
        packed = pack_day_occupancy(base, block_size)
        np_before += packed.np_before
        np_after += packed.np_after
        v_before += len(base)
        v_after += len(packed)
    return {
        "block_size": block_size,
        "np_before": int(np_before),
        "np_after": int(np_after),
        "np_reduction": float(np_before / max(np_after, 1)),
        "v_before": int(v_before),
        "v_after": int(v_after),
    }
