"""Version compatibility shims for the JAX API surface we depend on.

The repo targets the modern ``jax.shard_map`` entry point (with
``check_vma``), but CI and some dev boxes carry an older jax where
shard_map still lives in ``jax.experimental.shard_map`` (with
``check_rep`` and ``auto`` instead of ``axis_names``). Every shard_map
call site in the repo goes through :func:`shard_map` below so the whole
stack — the distributed simulator, the scenario-ensemble sharding, the
MoE dispatch, and the flash-attention wrapper — runs on either API.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Dispatch to ``jax.shard_map`` or the experimental fallback.

    ``axis_names`` (optional) is the set of mesh axes the body is manual
    over; ``None`` means all axes (the common case). Replication checking
    is disabled on both paths — call sites in this repo rely on that.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        try:
            return jax.shard_map(f, check_vma=False, **kwargs)
        except TypeError:
            pass
        try:  # intermediate signature: replication check named check_rep
            return jax.shard_map(f, check_rep=False, **kwargs)
        except TypeError:
            return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if axis_names is not None:
        manual = frozenset(axis_names)
        auto = frozenset(mesh.axis_names) - manual
        if auto:
            kwargs["auto"] = auto
    return _shard_map(f, check_rep=False, **kwargs)
