"""Capacity-bucketed dispatch/combine exchange (DESIGN.md §2, §4).

Loimos's visit-message exchange is a scatter over a bipartite graph: values
held by *people partitions* must reach the *location partitions* that own
each visit, and exposure results must flow back. On Charm++ this is
fine-grained messaging + aggregation + quiescence detection. The SPMD-native
equivalent is a **static-routed, capacity-bucketed all_to_all**:

  * routing is known from the (static) visit schedule: for each destination
    worker's visit slot we know the source worker and the source-local
    person index;
  * each (src, dst) worker pair exchanges a fixed-capacity buffer
    (capacity = max visits between any worker pair, the analog of MoE
    expert capacity — overflow cannot happen here because routing is
    *exact*, not load-balanced-on-the-fly);
  * dispatch: gather person channels into the send buffer, `all_to_all`,
    scatter into visit slots;
  * combine: the exact reverse, with a segment-sum at the source
    (propensities are additive).

This module is also used verbatim by the MoE layers (models/moe.py): expert
dispatch is the same primitive with tokens as "people" and experts as
"locations" — the paper's communication pattern applied beyond the paper.

All functions are shard_map-friendly: they take *per-worker local* arrays
and use `jax.lax.all_to_all` over a named mesh axis.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Static routing for one day-of-week on a W-worker mesh.

    Per-worker arrays (leading axis W = worker that owns them):
      send_idx[w]  (W, C): source-local person index to place in the buffer
                   slot (dst, c); -1 = padding.
      recv_slot[w] (W, C): destination-local *visit* index that buffer slot
                   (src, c) fills; -1 = padding.
    """

    num_workers: int
    capacity: int
    send_idx: np.ndarray  # (W, W, C) int32 [owner=src]
    recv_slot: np.ndarray  # (W, W, C) int32 [owner=dst]

    @property
    def bytes_per_channel(self) -> int:
        return self.num_workers * self.num_workers * self.capacity * 4


def build_exchange_plan(
    visit_person_local: np.ndarray,  # (W, Vw) global person id per local visit, -1 pad
    person_owner: np.ndarray,  # (P,) int32 worker owning each person
    person_local_index: np.ndarray,  # (P,) int32 index within owner's shard
    capacity_multiple: int = 8,
) -> ExchangePlan:
    """Host-side plan construction from the partitioned visit schedule.
    Fully vectorized (sort + prefix ranks) — O(R log R) for R routes, no
    python-per-visit loop, so full-state plans build in seconds."""
    W, Vw = visit_person_local.shape
    dst = np.repeat(np.arange(W, dtype=np.int64), Vw)
    v_local = np.tile(np.arange(Vw, dtype=np.int64), W)
    pids = visit_person_local.reshape(-1)
    valid = pids >= 0
    dst, v_local, pids = dst[valid], v_local[valid], pids[valid]
    src = person_owner[pids].astype(np.int64)
    p_local = person_local_index[pids].astype(np.int64)

    # Rank within each (src, dst) bucket via sorted prefix counting.
    key = src * W + dst
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    # position within run of equal keys
    change = np.flatnonzero(np.diff(key_s)) + 1
    starts = np.concatenate([[0], change])
    run_ids = np.searchsorted(change, np.arange(len(key_s)), side="right")
    run_starts = starts[run_ids]
    rank_s = np.arange(len(key_s)) - run_starts
    rank = np.empty_like(rank_s)
    rank[order] = rank_s

    counts = np.bincount(key, minlength=W * W)
    cap = int(counts.max()) if len(key) else 1
    cap = int(np.ceil(max(cap, 1) / capacity_multiple) * capacity_multiple)

    send_idx = np.full((W, W, cap), -1, np.int32)
    recv_slot = np.full((W, W, cap), -1, np.int32)
    send_idx[src, dst, rank] = p_local
    recv_slot[dst, src, rank] = v_local
    return ExchangePlan(W, cap, send_idx, recv_slot)


def dispatch(
    plan_send_idx,  # (W, C) this worker's slice of send_idx
    plan_recv_slot,  # (W, C) this worker's slice of recv_slot
    person_vals,  # (P_local, ch) values to route
    num_visits_local: int,
    axis_name: str,
):
    """Person-partition -> location-partition value routing (visit messages).

    Returns (V_local, ch) with zeros in unfilled slots."""
    ch = person_vals.shape[-1]
    safe = jnp.maximum(plan_send_idx, 0)
    buf = person_vals[safe] * (plan_send_idx >= 0)[..., None]  # (W, C, ch)
    buf = jax.lax.all_to_all(buf, axis_name, 0, 0, tiled=False)  # (W, C, ch)
    out = jnp.zeros((num_visits_local, ch), person_vals.dtype)
    safe_slot = jnp.maximum(plan_recv_slot, 0)
    vals = buf * (plan_recv_slot >= 0)[..., None]
    return out.at[safe_slot.reshape(-1)].add(vals.reshape(-1, ch))


def combine(
    plan_send_idx,  # (W, C)
    plan_recv_slot,  # (W, C)
    visit_vals,  # (V_local, ch) additive values (propensities)
    num_people_local: int,
    axis_name: str,
):
    """Location-partition -> person-partition additive return (exposure
    messages). Exact adjoint of :func:`dispatch`."""
    ch = visit_vals.shape[-1]
    safe_slot = jnp.maximum(plan_recv_slot, 0)
    buf = visit_vals[safe_slot] * (plan_recv_slot >= 0)[..., None]  # (W, C, ch)
    buf = jax.lax.all_to_all(buf, axis_name, 0, 0, tiled=False)
    out = jnp.zeros((num_people_local, ch), visit_vals.dtype)
    safe = jnp.maximum(plan_send_idx, 0)
    vals = buf * (plan_send_idx >= 0)[..., None]
    return out.at[safe.reshape(-1)].add(vals.reshape(-1, ch))
