"""Intervention framework (paper §III-A5, §IV-C5).

An intervention = trigger + selector + action:

  * **Trigger** — evaluated at the end of each simulation day from global
    statistics (the paper performs a reduction over person chares to count
    infectious people; here the reduction is a jnp sum — under shard_map it
    lowers to an all-reduce, the same collective).
  * **Selector** — a static or hash-random predicate over people/locations.
  * **Action** — either *ephemeral* (applies while the trigger holds:
    isolation visit masks, location closures, transmissibility scaling —
    "undo" is automatic because effects are recomputed functionally from
    base attributes each day) or *persistent* (vaccination: a one-shot flag
    with trivial undo, exactly the paper's vaccination semantics).

Everything is shape-static and jit/scan-compatible: triggers return scalar
bools, selectors return fixed (P,)/(L,) masks, and actions compose into
per-day effective multipliers/masks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core import rng

# --------------------------------------------------------------------------
# Triggers
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DayRange:
    """Active for day in [start, end)."""

    start: int
    end: int = 10**9

    def __call__(self, day, stats, was_active):
        return (day >= self.start) & (day < self.end)


@dataclasses.dataclass(frozen=True)
class CaseThreshold:
    """Activates when current infectious count crosses `on`; deactivates
    below `off` (hysteresis). Latches if `off` is None."""

    on: float
    off: Optional[float] = None
    metric: str = "infectious"  # or "cumulative"

    def __call__(self, day, stats, was_active):
        x = stats[self.metric]
        rising = x >= self.on
        if self.off is None:
            return was_active | rising
        return jnp.where(was_active, x >= self.off, rising)


# --------------------------------------------------------------------------
# Selectors — return a fixed mask at simulator-build time (host side).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Everyone:
    def people_mask(self, pop, seed):
        import numpy as np

        return np.ones((pop.num_people,), np.bool_)

    def locations_mask(self, pop, seed):
        import numpy as np

        return np.ones((pop.num_locations,), np.bool_)


@dataclasses.dataclass(frozen=True)
class AgeGroupIs:
    group: int

    def people_mask(self, pop, seed):
        return pop.age_group == self.group

    def locations_mask(self, pop, seed):
        import numpy as np

        return np.zeros((pop.num_locations,), np.bool_)


@dataclasses.dataclass(frozen=True)
class LocTypeIs:
    loc_type: int  # 0 home, 1 work, 2 school, 3 other

    def people_mask(self, pop, seed):
        import numpy as np

        return np.zeros((pop.num_people,), np.bool_)

    def locations_mask(self, pop, seed):
        return pop.loc_type == self.loc_type


@dataclasses.dataclass(frozen=True)
class RandomFraction:
    """Hash-selected stable random fraction (e.g. compliance sampling)."""

    fraction: float
    salt: int = 0

    def people_mask(self, pop, seed):
        import numpy as np

        u = rng.np_uniform(seed, rng.INIT_ATTR, self.salt, np.arange(pop.num_people))
        return u < self.fraction

    def locations_mask(self, pop, seed):
        import numpy as np

        u = rng.np_uniform(
            seed, rng.INIT_ATTR, self.salt + 1_000_003, np.arange(pop.num_locations)
        )
        return u < self.fraction


@dataclasses.dataclass(frozen=True)
class And:
    a: object
    b: object

    def people_mask(self, pop, seed):
        return self.a.people_mask(pop, seed) & self.b.people_mask(pop, seed)

    def locations_mask(self, pop, seed):
        return self.a.locations_mask(pop, seed) & self.b.locations_mask(pop, seed)


# --------------------------------------------------------------------------
# Actions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Isolate:
    """Selected people stop visiting while active (visit-schedule edit)."""

    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class CloseLocations:
    """Selected locations reject visits while active (school closures)."""

    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class ScaleSusceptibility:
    """Multiply beta_sigma of selected people while active (e.g. masking)."""

    factor: float
    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class ScaleInfectivity:
    """Multiply beta_iota of selected people while active."""

    factor: float
    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class Vaccinate:
    """One-shot persistent susceptibility reduction on first activation."""

    efficacy: float  # 0.9 => beta_sigma *= 0.1 forever after
    kind: str = dataclasses.field(default="persistent", init=False)


@dataclasses.dataclass(frozen=True)
class Intervention:
    name: str
    trigger: object
    selector: object
    action: object


@dataclasses.dataclass(frozen=True)
class CompiledIntervention:
    """Intervention with selector masks resolved to device arrays."""

    name: str
    trigger: object
    action: object
    people: jnp.ndarray  # (P,) bool
    locations: jnp.ndarray  # (L,) bool


def compile_interventions(
    interventions: Sequence[Intervention], pop, seed
) -> list[CompiledIntervention]:
    out = []
    for iv in interventions:
        out.append(
            CompiledIntervention(
                name=iv.name,
                trigger=iv.trigger,
                action=iv.action,
                people=jnp.asarray(iv.selector.people_mask(pop, seed)),
                locations=jnp.asarray(iv.selector.locations_mask(pop, seed)),
            )
        )
    return out


def apply_interventions(
    compiled: Sequence[CompiledIntervention],
    active,  # (K,) bool — trigger states from end of previous day
    vaccinated,  # (P,) bool persistent flag
    num_people: int,
    num_locations: int,
):
    """Fold active interventions into per-day effective masks/multipliers.

    Returns (visit_ok (P,), loc_open (L,), sus_mult (P,), inf_mult (P,),
    new_vaccinated (P,)). Pure function — "undo" is automatic.
    """
    visit_ok = jnp.ones((num_people,), bool)
    loc_open = jnp.ones((num_locations,), bool)
    sus_mult = jnp.ones((num_people,), jnp.float32)
    inf_mult = jnp.ones((num_people,), jnp.float32)
    for k, iv in enumerate(compiled):
        on = active[k]
        a = iv.action
        if isinstance(a, Isolate):
            visit_ok = visit_ok & ~(on & iv.people)
        elif isinstance(a, CloseLocations):
            loc_open = loc_open & ~(on & iv.locations)
        elif isinstance(a, ScaleSusceptibility):
            sus_mult = sus_mult * jnp.where(on & iv.people, a.factor, 1.0)
        elif isinstance(a, ScaleInfectivity):
            inf_mult = inf_mult * jnp.where(on & iv.people, a.factor, 1.0)
        elif isinstance(a, Vaccinate):
            vaccinated = vaccinated | (on & iv.people)
        else:
            raise TypeError(f"unknown action {a!r}")
    # Vaccination effect (persistent, applied regardless of current trigger).
    for iv in compiled:
        if isinstance(iv.action, Vaccinate):
            sus_mult = sus_mult * jnp.where(
                vaccinated & iv.people, 1.0 - iv.action.efficacy, 1.0
            )
            break  # one vaccinated flag — first Vaccinate defines efficacy
    return visit_ok, loc_open, sus_mult, inf_mult, vaccinated


def evaluate_triggers(compiled, day, stats, active):
    """End-of-day trigger evaluation (Algorithm 2, line 34)."""
    new = [
        iv.trigger(day, stats, active[k]) for k, iv in enumerate(compiled)
    ]
    if not new:
        return active
    return jnp.stack(new)
