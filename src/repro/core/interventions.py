"""Intervention framework (paper §III-A5, §IV-C5).

An intervention = trigger + selector + action:

  * **Trigger** — evaluated at the end of each simulation day from global
    statistics (the paper performs a reduction over person chares to count
    infectious people; here the reduction is a jnp sum — under shard_map it
    lowers to an all-reduce, the same collective).
  * **Selector** — a static or hash-random predicate over people/locations.
  * **Action** — either *ephemeral* (applies while the trigger holds:
    isolation visit masks, location closures, transmissibility scaling —
    "undo" is automatic because effects are recomputed functionally from
    base attributes each day) or *persistent* (vaccination: a one-shot flag
    with trivial undo, exactly the paper's vaccination semantics).

Everything is shape-static and jit/scan-compatible: triggers return scalar
bools, selectors return fixed (P,)/(L,) masks, and actions compose into
per-day effective multipliers/masks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import rng

# --------------------------------------------------------------------------
# Triggers
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DayRange:
    """Active for day in [start, end)."""

    start: int
    end: int = 10**9

    def __call__(self, day, stats, was_active):
        return (day >= self.start) & (day < self.end)


@dataclasses.dataclass(frozen=True)
class CaseThreshold:
    """Activates when current infectious count crosses `on`; deactivates
    below `off` (hysteresis). Latches if `off` is None."""

    on: float
    off: Optional[float] = None
    metric: str = "infectious"  # or "cumulative"

    def __call__(self, day, stats, was_active):
        x = stats[self.metric]
        rising = x >= self.on
        if self.off is None:
            return was_active | rising
        return jnp.where(was_active, x >= self.off, rising)


# --------------------------------------------------------------------------
# Selectors — return a fixed mask at simulator-build time (host side).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Everyone:
    def people_mask(self, pop, seed):
        import numpy as np

        return np.ones((pop.num_people,), np.bool_)

    def locations_mask(self, pop, seed):
        import numpy as np

        return np.ones((pop.num_locations,), np.bool_)


@dataclasses.dataclass(frozen=True)
class AgeGroupIs:
    group: int

    def people_mask(self, pop, seed):
        return pop.age_group == self.group

    def locations_mask(self, pop, seed):
        import numpy as np

        return np.zeros((pop.num_locations,), np.bool_)


@dataclasses.dataclass(frozen=True)
class LocTypeIs:
    loc_type: int  # 0 home, 1 work, 2 school, 3 other

    def people_mask(self, pop, seed):
        import numpy as np

        return np.zeros((pop.num_people,), np.bool_)

    def locations_mask(self, pop, seed):
        return pop.loc_type == self.loc_type


@dataclasses.dataclass(frozen=True)
class RandomFraction:
    """Hash-selected stable random fraction (e.g. compliance sampling)."""

    fraction: float
    salt: int = 0

    def people_mask(self, pop, seed):
        import numpy as np

        u = rng.np_uniform(seed, rng.INIT_ATTR, self.salt, np.arange(pop.num_people))
        return u < self.fraction

    def locations_mask(self, pop, seed):
        import numpy as np

        u = rng.np_uniform(
            seed, rng.INIT_ATTR, self.salt + 1_000_003, np.arange(pop.num_locations)
        )
        return u < self.fraction


@dataclasses.dataclass(frozen=True)
class And:
    a: object
    b: object

    def people_mask(self, pop, seed):
        return self.a.people_mask(pop, seed) & self.b.people_mask(pop, seed)

    def locations_mask(self, pop, seed):
        return self.a.locations_mask(pop, seed) & self.b.locations_mask(pop, seed)


# --------------------------------------------------------------------------
# Actions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Isolate:
    """Selected people stop visiting while active (visit-schedule edit)."""

    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class CloseLocations:
    """Selected locations reject visits while active (school closures)."""

    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class ScaleSusceptibility:
    """Multiply beta_sigma of selected people while active (e.g. masking)."""

    factor: float
    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class ScaleInfectivity:
    """Multiply beta_iota of selected people while active."""

    factor: float
    kind: str = dataclasses.field(default="ephemeral", init=False)


@dataclasses.dataclass(frozen=True)
class Vaccinate:
    """One-shot persistent susceptibility reduction on first activation."""

    efficacy: float  # 0.9 => beta_sigma *= 0.1 forever after
    kind: str = dataclasses.field(default="persistent", init=False)


@dataclasses.dataclass(frozen=True)
class Intervention:
    name: str
    trigger: object
    selector: object
    action: object


# --------------------------------------------------------------------------
# Per-agent intervention family
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TestTraceIsolate:
    """Per-agent test-trace-isolate policy (the second intervention family).

    Unlike :class:`Intervention` (population-level trigger/selector/effect,
    recomputed functionally each day), this family drives *persistent
    per-agent state* carried in ``SimState``: ``tested``, ``traced`` and
    ``isolated_until`` masks. Each day, up to ``tests_per_day`` eligible
    people (symptomatic first, then traced contacts) are tested — an exact,
    deterministic capacity-limited top-k under the counter RNG, so results
    are bitwise identical across mesh shapes. Positives isolate from the
    next day for ``isolation_days``; if ``trace`` is set, today's contacts
    of positives are traced via a second accumulator in the interaction
    kernels and isolate for ``trace_isolation_days``.
    """

    name: str
    tests_per_day: int
    selector: object = dataclasses.field(default_factory=Everyone)
    isolation_days: int = 10
    trace: bool = True
    trace_isolation_days: int = 14
    start_day: int = 0


@dataclasses.dataclass(frozen=True)
class PaSlotStatic:
    """Static structure of one per-agent intervention slot. Like
    :class:`IvSlotStatic`, the structure (is tracing compiled in?) must be
    identical across a scenario batch; numerics live in ``IvParams``."""

    name: str
    trace: bool


@dataclasses.dataclass(frozen=True)
class CompiledIntervention:
    """Intervention with selector masks resolved to device arrays."""

    name: str
    trigger: object
    action: object
    people: jnp.ndarray  # (P,) bool
    locations: jnp.ndarray  # (L,) bool


def check_unique_names(interventions) -> None:
    """Reject duplicate slot names early: the union/ensemble machinery keys
    slots by name, so a silent last-wins merge would drop interventions."""
    seen = set()
    for iv in interventions:
        if iv.name in seen:
            raise ValueError(
                f"duplicate intervention name '{iv.name}': slot names must "
                "be unique within a scenario (the batch union merges slots "
                "by name, so a duplicate would silently shadow the earlier "
                "one). Rename one of the interventions."
            )
        seen.add(iv.name)


def compile_interventions(
    interventions: Sequence[Intervention], pop, seed
) -> list[CompiledIntervention]:
    check_unique_names(interventions)
    out = []
    for iv in interventions:
        out.append(
            CompiledIntervention(
                name=iv.name,
                trigger=iv.trigger,
                action=iv.action,
                people=jnp.asarray(iv.selector.people_mask(pop, seed)),
                locations=jnp.asarray(iv.selector.locations_mask(pop, seed)),
            )
        )
    return out


def apply_interventions(
    compiled: Sequence[CompiledIntervention],
    active,  # (K,) bool — trigger states from end of previous day
    vaccinated,  # (P,) bool persistent flag
    num_people: int,
    num_locations: int,
):
    """Fold active interventions into per-day effective masks/multipliers.

    Returns (visit_ok (P,), loc_open (L,), sus_mult (P,), inf_mult (P,),
    new_vaccinated (P,)). Pure function — "undo" is automatic.
    """
    visit_ok = jnp.ones((num_people,), bool)
    loc_open = jnp.ones((num_locations,), bool)
    sus_mult = jnp.ones((num_people,), jnp.float32)
    inf_mult = jnp.ones((num_people,), jnp.float32)
    for k, iv in enumerate(compiled):
        on = active[k]
        a = iv.action
        if isinstance(a, Isolate):
            visit_ok = visit_ok & ~(on & iv.people)
        elif isinstance(a, CloseLocations):
            loc_open = loc_open & ~(on & iv.locations)
        elif isinstance(a, ScaleSusceptibility):
            sus_mult = sus_mult * jnp.where(on & iv.people, a.factor, 1.0)
        elif isinstance(a, ScaleInfectivity):
            inf_mult = inf_mult * jnp.where(on & iv.people, a.factor, 1.0)
        elif isinstance(a, Vaccinate):
            vaccinated = vaccinated | (on & iv.people)
        else:
            raise TypeError(f"unknown action {a!r}")
    # Vaccination effect (persistent, applied regardless of current trigger).
    for iv in compiled:
        if isinstance(iv.action, Vaccinate):
            sus_mult = sus_mult * jnp.where(
                vaccinated & iv.people, 1.0 - iv.action.efficacy, 1.0
            )
            break  # one vaccinated flag — first Vaccinate defines efficacy
    return visit_ok, loc_open, sus_mult, inf_mult, vaccinated


def evaluate_triggers(compiled, day, stats, active):
    """End-of-day trigger evaluation (Algorithm 2, line 34)."""
    new = [
        iv.trigger(day, stats, active[k]) for k, iv in enumerate(compiled)
    ]
    if not new:
        return active
    return jnp.stack(new)


# --------------------------------------------------------------------------
# Stacked (structure-of-arrays) formulation — the scenario-ensemble path.
#
# The object formulation above keeps Python branching (isinstance on the
# action, Optional trigger fields) inside the day step, which pins every
# numeric to trace-time constants. For vmap-over-scenarios all *values*
# must instead be device arrays with a leading batch axis, while the
# *structure* (which action/trigger each slot is, which metric it reads)
# stays static and identical across the batch. ``IvSlotStatic`` carries
# the structure; ``IvParams`` carries the stacked numerics.
# --------------------------------------------------------------------------

NEVER_OFF = -3.0e38  # thresh_off encoding of "latched" (off=None)


@dataclasses.dataclass(frozen=True)
class IvSlotStatic:
    """Static per-slot structure. Must be identical across a scenario
    batch; ensembles may *disable* a slot per scenario via IvParams.enabled
    but may not change what the slot is."""

    name: str
    action: str  # isolate | close | scale_sus | scale_inf | vaccinate
    trigger: str  # day_range | case_threshold
    metric: str = "infectious"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IvParams:
    """Scenario-varying intervention numerics; every leaf stacks over a
    leading batch axis (slot axis K is the trailing structure axis)."""

    enabled: jnp.ndarray  # (K,) bool — per-scenario slot on/off
    day_start: jnp.ndarray  # (K,) int32 (day_range)
    day_end: jnp.ndarray  # (K,) int32
    thresh_on: jnp.ndarray  # (K,) f32 (case_threshold)
    thresh_off: jnp.ndarray  # (K,) f32; NEVER_OFF => latching
    factor: jnp.ndarray  # (K,) f32 — scale factor, or 1-efficacy
    people: jnp.ndarray  # (K, P) bool selector masks
    locations: jnp.ndarray  # (K, L) bool
    # --- per-agent (test-trace-isolate) slots, K2 axis ------------------
    pa_enabled: jnp.ndarray  # (K2,) bool — per-scenario slot on/off
    pa_start: jnp.ndarray  # (K2,) int32 — first active day
    pa_tests: jnp.ndarray  # (K2,) int32 — daily testing-capacity budget
    pa_iso: jnp.ndarray  # (K2,) int32 — isolation days for positives
    pa_trace_iso: jnp.ndarray  # (K2,) int32 — isolation days for traced
    pa_people: jnp.ndarray  # (K2, P) bool — selector (who the policy covers)

    @property
    def num_slots(self) -> int:
        return self.enabled.shape[-1]

    @property
    def num_pa_slots(self) -> int:
        return self.pa_enabled.shape[-1]


_ACTION_KINDS = {
    Isolate: "isolate",
    CloseLocations: "close",
    ScaleSusceptibility: "scale_sus",
    ScaleInfectivity: "scale_inf",
    Vaccinate: "vaccinate",
}


def compile_iv_params(
    interventions: Sequence, pop, seed
) -> tuple[tuple[IvSlotStatic, ...], tuple[PaSlotStatic, ...], IvParams]:
    """Resolve a mixed intervention list into
    (classic static slots, per-agent static slots, stacked params).

    ``interventions`` may mix :class:`Intervention` (classic family, K axis)
    and :class:`TestTraceIsolate` (per-agent family, K2 axis); each family
    keeps its own slot order (the original list order within the family).
    Selector masks are resolved host-side with the scenario seed (the same
    semantics as :func:`compile_interventions`), so per-scenario seeds give
    per-scenario compliance samples in an ensemble.
    """
    import numpy as np

    check_unique_names(interventions)
    pa_ivs = [iv for iv in interventions if isinstance(iv, TestTraceIsolate)]
    interventions = [
        iv for iv in interventions if not isinstance(iv, TestTraceIsolate)
    ]

    n_vax = sum(1 for iv in interventions if isinstance(iv.action, Vaccinate))
    if n_vax > 1:
        raise ValueError(
            f"{n_vax} Vaccinate slots in one scenario/union: the single "
            "vaccinated flag carries exactly one efficacy, so a second slot "
            "would silently apply the wrong multiplier. Compare vaccine "
            "efficacies as a disease/param axis (perturb the factor of one "
            "slot per scenario), not as separate slots."
        )

    K = len(interventions)
    statics = []
    enabled = np.ones((K,), np.bool_)
    day_start = np.zeros((K,), np.int32)
    day_end = np.full((K,), 2**31 - 1, np.int32)
    thresh_on = np.zeros((K,), np.float32)
    thresh_off = np.full((K,), NEVER_OFF, np.float32)
    factor = np.ones((K,), np.float32)
    people = np.zeros((K, pop.num_people), np.bool_)
    locations = np.zeros((K, pop.num_locations), np.bool_)

    for k, iv in enumerate(interventions):
        a, t = iv.action, iv.trigger
        kind = _ACTION_KINDS.get(type(a))
        if kind is None:
            raise TypeError(f"unknown action {a!r}")
        if isinstance(t, DayRange):
            tkind, metric = "day_range", "infectious"
            day_start[k] = t.start
            day_end[k] = min(t.end, 2**31 - 1)
        elif isinstance(t, CaseThreshold):
            tkind, metric = "case_threshold", t.metric
            thresh_on[k] = t.on
            thresh_off[k] = NEVER_OFF if t.off is None else t.off
        else:
            raise TypeError(f"unknown trigger {t!r}")
        statics.append(IvSlotStatic(iv.name, kind, tkind, metric))
        if isinstance(a, (ScaleSusceptibility, ScaleInfectivity)):
            factor[k] = a.factor
        elif isinstance(a, Vaccinate):
            factor[k] = 1.0 - a.efficacy
        people[k] = np.asarray(iv.selector.people_mask(pop, seed))
        locations[k] = np.asarray(iv.selector.locations_mask(pop, seed))

    K2 = len(pa_ivs)
    pa_statics = []
    pa_enabled = np.ones((K2,), np.bool_)
    pa_start = np.zeros((K2,), np.int32)
    pa_tests = np.zeros((K2,), np.int32)
    pa_iso = np.zeros((K2,), np.int32)
    pa_trace_iso = np.zeros((K2,), np.int32)
    pa_people = np.zeros((K2, pop.num_people), np.bool_)
    for k, iv in enumerate(pa_ivs):
        pa_statics.append(PaSlotStatic(iv.name, bool(iv.trace)))
        pa_start[k] = iv.start_day
        pa_tests[k] = iv.tests_per_day
        pa_iso[k] = iv.isolation_days
        pa_trace_iso[k] = iv.trace_isolation_days
        pa_people[k] = np.asarray(iv.selector.people_mask(pop, seed))

    params = IvParams(
        enabled=jnp.asarray(enabled),
        day_start=jnp.asarray(day_start),
        day_end=jnp.asarray(day_end),
        thresh_on=jnp.asarray(thresh_on),
        thresh_off=jnp.asarray(thresh_off),
        factor=jnp.asarray(factor),
        people=jnp.asarray(people),
        locations=jnp.asarray(locations),
        pa_enabled=jnp.asarray(pa_enabled),
        pa_start=jnp.asarray(pa_start),
        pa_tests=jnp.asarray(pa_tests),
        pa_iso=jnp.asarray(pa_iso),
        pa_trace_iso=jnp.asarray(pa_trace_iso),
        pa_people=jnp.asarray(pa_people),
    )
    return tuple(statics), tuple(pa_statics), params


def apply_iv_params(
    slots: Sequence[IvSlotStatic],
    p: IvParams,
    active,  # (K,) bool — trigger states from end of previous day
    vaccinated,  # (P,) bool persistent flag
    num_people: int,
    num_locations: int,
):
    """Stacked-params twin of :func:`apply_interventions`; same op order,
    so results are bitwise identical. Fully traceable/vmappable."""
    visit_ok = jnp.ones((num_people,), bool)
    loc_open = jnp.ones((num_locations,), bool)
    sus_mult = jnp.ones((num_people,), jnp.float32)
    inf_mult = jnp.ones((num_people,), jnp.float32)
    for k, s in enumerate(slots):
        on = active[k]
        if s.action == "isolate":
            visit_ok = visit_ok & ~(on & p.people[k])
        elif s.action == "close":
            loc_open = loc_open & ~(on & p.locations[k])
        elif s.action == "scale_sus":
            sus_mult = sus_mult * jnp.where(on & p.people[k], p.factor[k], 1.0)
        elif s.action == "scale_inf":
            inf_mult = inf_mult * jnp.where(on & p.people[k], p.factor[k], 1.0)
        elif s.action == "vaccinate":
            vaccinated = vaccinated | (on & p.people[k])
    for k, s in enumerate(slots):
        if s.action == "vaccinate":
            sus_mult = sus_mult * jnp.where(
                vaccinated & p.people[k], p.factor[k], 1.0
            )
            break  # one vaccinated flag — first Vaccinate defines efficacy
    return visit_ok, loc_open, sus_mult, inf_mult, vaccinated


def evaluate_iv_triggers(slots, p: IvParams, day, stats, active):
    """Stacked-params twin of :func:`evaluate_triggers`. Disabled slots
    (p.enabled[k] == False) never activate, which is how an ensemble turns
    an intervention off in some scenarios without changing structure."""
    if not slots:
        return active
    new = []
    for k, s in enumerate(slots):
        if s.trigger == "day_range":
            t = (day >= p.day_start[k]) & (day < p.day_end[k])
        else:  # case_threshold (hysteresis; thresh_off == NEVER_OFF latches)
            x = stats[s.metric]
            rising = x >= p.thresh_on[k]
            t = jnp.where(active[k], x >= p.thresh_off[k], rising)
        new.append(t & p.enabled[k])
    return jnp.stack(new)
