"""Counter-based hash RNG for partition-invariant stochastic draws.

The paper (§VI) fixes one global seed so that all scaling runs have identical
epidemiological results — but in the Charm++ implementation that only holds
per partitioning, because draws are consumed from per-chare streams. Here
every random draw is a *pure function* of ``(seed, day, entity ids, stream)``
via a 32-bit mixing hash, so results are bitwise identical across any mesh
shape, worker count, or replay after restart. This is strictly stronger
reproducibility than the paper's and is what makes elastic restart exact.

The same integer arithmetic is used inside Pallas kernels (it is plain
uint32 ops, so it lowers to TPU VPU instructions and runs unchanged in
interpret mode) and in the pure-jnp reference oracles, so kernel-vs-ref
comparisons are exact.

Streams (documented constants, one per random decision in the simulator):
  CONTACT      per (pid_i, pid_j, day): did a co-occupant pair make contact?
  INFECT       per (pid, day): infection draw against total propensity
  TRANSITION   per (pid, day): FSA next-state categorical draw
  DWELL        per (pid, day): dwell-time draw for the state entered
  SEED_CHOICE  per (pid, day): outbreak seeding
  TEST         per (slot, pid, day): testing-priority draw for the
               capacity-limited daily test budget
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Stream ids — keep stable; they are part of the reproducibility contract.
CONTACT = np.uint32(0x01)
INFECT = np.uint32(0x02)
TRANSITION = np.uint32(0x03)
DWELL = np.uint32(0x04)
SEED_CHOICE = np.uint32(0x05)
VISIT_SAMPLE = np.uint32(0x06)
INIT_ATTR = np.uint32(0x07)
TEST = np.uint32(0x08)

_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def _u32(x):
    """Cast to uint32 with wrapping semantics (jnp arrays or python ints)."""
    if isinstance(x, (int, np.integer)):
        return np.uint32(x & 0xFFFFFFFF)
    return x.astype(jnp.uint32)


def fmix32(h):
    """Murmur3 finalizer: full-avalanche 32-bit mix. Works on jnp uint32."""
    with np.errstate(over="ignore"):  # uint32 wrap is the point
        h = _u32(h)
        h = h ^ (h >> 16)
        h = h * _C1
        h = h ^ (h >> 13)
        h = h * _C2
        h = h ^ (h >> 16)
    return h


def hash_u32(seed, *words):
    """Combine an arbitrary number of uint32 words into one mixed uint32.

    Broadcasting: any of the words may be arrays; standard jnp broadcasting
    applies. Order-sensitive (h is folded left-to-right), so (i, j) and
    (j, i) produce independent draws.
    """
    with np.errstate(over="ignore"):  # uint32 wrap is the point
        h = fmix32(_u32(seed) ^ _GOLDEN)
        for i, w in enumerate(words):
            h = fmix32(h ^ fmix32(_u32(w) + _GOLDEN * np.uint32(i + 1)))
    return h


def uniform(seed, *words):
    """U(0,1) float32 from the hash; never exactly 0 (safe for log)."""
    h = hash_u32(seed, *words)
    # Top 24 bits -> [0, 1) with 2^-24 resolution, then offset by 2^-25.
    u = (h >> np.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    return u + jnp.float32(2.0**-25)


def exponential(mean, seed, *words):
    """Exponential(mean) draw."""
    return -mean * jnp.log(uniform(seed, *words))


def categorical(cum_probs, seed, *words):
    """Inverse-CDF categorical draw.

    cum_probs: (..., K) cumulative probabilities along the last axis (rows
    end at ~1.0). Returns int32 index with the same batch shape as the
    broadcast of the hash words.
    """
    u = uniform(seed, *words)
    # count of cum < u  ==  sampled index
    return jnp.sum(cum_probs < u[..., None], axis=-1).astype(jnp.int32)


def np_uniform(seed, *words):
    """NumPy mirror of :func:`uniform` for host-side generators/tests."""

    def mix(h):
        h = np.uint32(h)
        with np.errstate(over="ignore"):
            h ^= h >> np.uint32(16)
            h *= _C1
            h ^= h >> np.uint32(13)
            h *= _C2
            h ^= h >> np.uint32(16)
        return h

    with np.errstate(over="ignore"):
        h = mix(np.uint32(seed & 0xFFFFFFFF) ^ _GOLDEN)
        for i, w in enumerate(words):
            w = np.asarray(w, dtype=np.uint64) & np.uint64(0xFFFFFFFF)
            h = mix(h ^ mix(w.astype(np.uint32) + _GOLDEN * np.uint32(i + 1)))
    u = (h >> np.uint32(8)).astype(np.float64) * 2.0**-24
    return u + 2.0**-25
