"""Distributed epidemic day step (Algorithm 2, SPMD over a device mesh).

People and locations are partitioned exactly as in the paper: people in
uniform blocks, locations by the geo-sorted visit-weighted static scheme
(§V-B). Each simulated day runs three phases inside one `shard_map`:

  1. **visit dispatch** — per-person epidemiological channels (sus value,
     inf value, visit-ok flag) routed person-partition → location-partition
     through the capacity-bucketed all_to_all (core/exchange.py). This is
     the paper's visit-message exchange with aggregation built in.
  2. **interactions** — each worker runs the block-scheduled interaction
     kernel on its local, location-sorted visit arrays.
  3. **exposure combine + update** — per-visit propensities return to the
     person owners through the adjoint all_to_all (exposure messages);
     infection sampling, FSA update, and trigger reductions (psum) follow.

Because all stochastic draws are counter-based on *global* ids, the
distributed simulation is bitwise identical to the single-device
reference for any worker count — tested in tests/test_dist.py by spawning
a multi-device host-platform subprocess.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat
from repro.core import disease as disease_lib
from repro.core import exchange as ex_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import transmission as tx_lib
from repro.kernels.interactions import ops as iops

AXIS = "workers"


@dataclasses.dataclass
class DistPlan:
    """Host-built static partition + routing data (all numpy)."""

    num_workers: int
    people_per_worker: int  # Pw (padded)
    num_people: int  # P real
    locs_per_worker: int  # Lw (padded)
    visits_per_worker: int  # Vw (padded, uniform across workers & days)
    pairs_per_worker: int  # NPw
    block_size: int
    # (7, W, Vw)
    week_pid: np.ndarray  # global person ids, -1 pad
    week_loc: np.ndarray  # *global* loc id (for the contact hash), pad ok
    week_start: np.ndarray
    week_end: np.ndarray
    week_p: np.ndarray  # per-visit contact probability (gathered at build)
    # (7, W, NPw) block schedules
    row_idx: np.ndarray
    col_idx: np.ndarray
    row_start: np.ndarray
    pair_active: np.ndarray
    # (7, W, W, C) exchange routing
    send_idx: np.ndarray
    recv_slot: np.ndarray
    capacity: int
    # location partition (for elastic re-partitioning / stats)
    loc_partition: np.ndarray  # (L,)


def build_dist_plan(
    pop: pop_lib.Population,
    num_workers: int,
    block_size: int = 128,
    balanced: bool = True,
) -> DistPlan:
    W = num_workers
    P_real = pop.num_people
    Pw = int(np.ceil(P_real / W))

    # Location partition: the paper's static load balancing (or naive).
    visits_per_loc = np.zeros((pop.num_locations,), np.int64)
    for d in pop.week:
        np.add.at(visits_per_loc, d.loc[: d.num_real], 1)
    if balanced:
        loc_part = pop_lib.balanced_location_partition(
            pop.geo_key, visits_per_loc, W
        )
    else:
        loc_part = pop_lib.naive_location_partition(pop.num_locations, W)

    person_owner = (np.arange(P_real) // Pw).astype(np.int32)
    person_local = (np.arange(P_real) % Pw).astype(np.int32)

    # Per-worker, per-day location-sorted visit arrays.
    days = []
    for d in pop.week:
        n = d.num_real
        v_part = loc_part[d.loc[:n]]
        per_worker = []
        for w in range(W):
            sel = np.flatnonzero(v_part == w)
            per_worker.append(
                pop_lib.pack_day(
                    d.person[:n][sel], d.loc[:n][sel],
                    d.start[:n][sel], d.end[:n][sel],
                    pad_multiple=block_size,
                )
            )
        days.append(per_worker)
    Vw = max(len(pw) for day in days for pw in day)
    Vw = int(np.ceil(Vw / block_size) * block_size)
    days = [
        [
            pop_lib.pack_day(
                pw.person[: pw.num_real], pw.loc[: pw.num_real],
                pw.start[: pw.num_real], pw.end[: pw.num_real],
                pad_to=Vw, pad_multiple=block_size,
            )
            for pw in day
        ]
        for day in days
    ]

    # Block schedules, padded to a uniform pair count.
    scheds = [
        [pop_lib.build_block_schedule(pw.loc, pw.num_real, block_size) for pw in day]
        for day in days
    ]
    NPw = max(s.row_block.shape[0] for day in scheds for s in day)
    scheds = [
        [
            pop_lib.build_block_schedule(pw.loc, pw.num_real, block_size, pad_to=NPw)
            for pw in day
        ]
        for day in days
    ]

    # Exchange plans (same routing structure every day; capacity = max).
    plans = []
    for day in days:
        vp = np.stack([pw.person for pw in day])  # (W, Vw)
        plans.append(
            ex_lib.build_exchange_plan(vp, person_owner, person_local)
        )
    C = max(p.capacity for p in plans)
    send_idx = np.full((7, W, W, C), -1, np.int32)
    recv_slot = np.full((7, W, W, C), -1, np.int32)
    for d, p in enumerate(plans):
        send_idx[d, :, :, : p.capacity] = p.send_idx
        recv_slot[d, :, :, : p.capacity] = p.recv_slot

    stack = lambda f: np.stack([np.stack([f(x) for x in day]) for day in days])
    sstack = lambda f: np.stack([np.stack([f(s) for s in day]) for day in scheds])

    # Per-visit contact probability, gathered on host (location attrs are
    # static; this is the paper's "store p as a location attribute").
    week_p = np.stack(
        [
            np.stack([pop.contact_prob[np.minimum(pw.loc, pop.num_locations - 1)]
                      for pw in day])
            for day in days
        ]
    ).astype(np.float32)

    # Padded locations per worker (only used for closure masks / stats).
    Lw = int(np.max(np.bincount(loc_part, minlength=W)))

    return DistPlan(
        num_workers=W,
        people_per_worker=Pw,
        num_people=P_real,
        locs_per_worker=Lw,
        visits_per_worker=Vw,
        pairs_per_worker=NPw,
        block_size=block_size,
        week_pid=stack(lambda x: x.person),
        week_loc=stack(lambda x: x.loc),
        week_start=stack(lambda x: x.start),
        week_end=stack(lambda x: x.end),
        week_p=week_p,
        row_idx=sstack(lambda s: s.row_block),
        col_idx=sstack(lambda s: s.col_block),
        row_start=sstack(lambda s: s.row_start.astype(np.int32)),
        pair_active=sstack(lambda s: s.pair_active.astype(np.int32)),
        send_idx=send_idx,
        recv_slot=recv_slot,
        capacity=C,
        loc_partition=loc_part,
    )


@dataclasses.dataclass
class DistSimulator:
    """shard_map-distributed simulator; mirrors EpidemicSimulator's results
    bitwise (same counter-based draws on global ids)."""

    pop: pop_lib.Population
    disease: disease_lib.DiseaseModel
    mesh: Mesh
    tm: tx_lib.TransmissionModel = dataclasses.field(
        default_factory=tx_lib.TransmissionModel
    )
    interventions: Sequence[iv_lib.Intervention] = ()
    seed: int = 0
    block_size: int = 128
    balanced: bool = True
    backend: str = "jnp"
    static_network: bool = False
    seed_per_day: int = 10
    seed_days: int = 7

    def __post_init__(self):
        assert self.mesh.axis_names == (AXIS,), (
            "DistSimulator expects a 1-D mesh with axis 'workers' — flatten "
            "(pod, data, model) into it; see launch/mesh.py:make_worker_mesh"
        )
        self.axis_size = int(self.mesh.shape[AXIS])
        self.plan = build_dist_plan(
            self.pop, self.axis_size, self.block_size, self.balanced
        )
        W, Pw = self.plan.num_workers, self.plan.people_per_worker
        self.compiled_ivs = iv_lib.compile_interventions(
            self.interventions, self.pop, self.seed
        )
        # Reshape per-person intervention masks to (W, Pw).
        self._iv_people = [
            self._pad_people(np.asarray(iv.people)) for iv in self.compiled_ivs
        ]
        # Per-visit location-open requires per-visit loc->intervention mask;
        # gather at build: (K, 7, W, Vw) bool — visits at closed-type locs.
        self._iv_visit_loc = [
            np.asarray(iv.locations)[np.minimum(self.plan.week_loc, self.pop.num_locations - 1)]
            for iv in self.compiled_ivs
        ]
        self.sus_table = jnp.asarray(self.disease.susceptibility)
        self.inf_table = jnp.asarray(self.disease.infectivity)
        base_bs = self._pad_people(self.pop.beta_sus.astype(np.float32))
        base_bi = self._pad_people(self.pop.beta_inf.astype(np.float32))
        self.base_beta_sus = jnp.asarray(base_bs)
        self.base_beta_inf = jnp.asarray(base_bi)
        self._specs_built = False
        self._build_step()

    # -- helpers -----------------------------------------------------------
    def _pad_people(self, arr: np.ndarray):
        W, Pw = self.plan.num_workers, self.plan.people_per_worker
        out = np.zeros((W * Pw,) + arr.shape[1:], arr.dtype)
        out[: self.plan.num_people] = arr
        return out.reshape((W, Pw) + arr.shape[1:])

    def init_state(self):
        W, Pw = self.plan.num_workers, self.plan.people_per_worker
        # Pad people enter an absorbing, non-susceptible state.
        absorbing = int(np.argmax(self.disease.susceptibility == 0.0))
        health = np.full((W * Pw,), absorbing, np.int32)
        health[: self.plan.num_people] = self.disease.initial_state
        return {
            "day": jnp.asarray(0, jnp.int32),
            "health": jnp.asarray(health.reshape(W, Pw)),
            "dwell": jnp.full((W, Pw), disease_lib.ABSORBING_DWELL, jnp.float32),
            "cumulative": jnp.asarray(0, jnp.int32),
            "iv_active": jnp.zeros((max(len(self.compiled_ivs), 1),), bool),
            "vaccinated": jnp.zeros((W, Pw), bool),
        }

    # -- the shard_map day step --------------------------------------------
    def _build_step(self):
        plan = self.plan
        W, Pw, Vw = plan.num_workers, plan.people_per_worker, plan.visits_per_worker
        mesh = self.mesh
        axis = AXIS

        wk = {
            "pid": jnp.asarray(plan.week_pid),
            "loc": jnp.asarray(plan.week_loc),
            "start": jnp.asarray(plan.week_start),
            "end": jnp.asarray(plan.week_end),
            "p": jnp.asarray(plan.week_p),
            "row": jnp.asarray(plan.row_idx),
            "col": jnp.asarray(plan.col_idx),
            "rs": jnp.asarray(plan.row_start),
            "pa": jnp.asarray(plan.pair_active),
            "send": jnp.asarray(plan.send_idx),
            "recv": jnp.asarray(plan.recv_slot),
        }
        iv_people = [jnp.asarray(m) for m in self._iv_people]
        iv_visit_loc = [jnp.asarray(m) for m in self._iv_visit_loc]
        nb = Vw // plan.block_size

        def worker_step(state, wk_local, base_bs, base_bi, iv_ppl, iv_vloc):
            """Runs on one worker; leading (1, ...) local shards squeezed."""
            w = jax.lax.axis_index(axis)
            day = state["day"]
            dow = day % 7
            # week arrays are (7, W, ...) sharded on axis 1 -> local (7, 1, ...)
            take = lambda a: jax.lax.dynamic_index_in_dim(
                a.squeeze(1), dow, 0, keepdims=False
            )
            pid = take(wk_local["pid"])  # (Vw,) global ids
            loc = take(wk_local["loc"])
            vstart, vend = take(wk_local["start"]), take(wk_local["end"])
            p_v = take(wk_local["p"])
            row_i, col_i = take(wk_local["row"]), take(wk_local["col"])
            row_s, pair_a = take(wk_local["rs"]), take(wk_local["pa"])
            send = take(wk_local["send"])  # (W, C)
            recv = take(wk_local["recv"])  # (W, C)

            health = state["health"].squeeze(0)  # (Pw,)
            dwell = state["dwell"].squeeze(0)
            vacc = state["vaccinated"].squeeze(0)
            base_bs = base_bs.squeeze(0)
            base_bi = base_bi.squeeze(0)

            # ---- interventions (person side) ----
            visit_ok = jnp.ones((Pw,), jnp.float32)
            sus_m = jnp.ones((Pw,), jnp.float32)
            inf_m = jnp.ones((Pw,), jnp.float32)
            for k, civ in enumerate(self.compiled_ivs):
                on = state["iv_active"][k]
                sel = iv_ppl[k].squeeze(0)
                a = civ.action
                if isinstance(a, iv_lib.Isolate):
                    visit_ok = visit_ok * jnp.where(on & sel, 0.0, 1.0)
                elif isinstance(a, iv_lib.ScaleSusceptibility):
                    sus_m = sus_m * jnp.where(on & sel, a.factor, 1.0)
                elif isinstance(a, iv_lib.ScaleInfectivity):
                    inf_m = inf_m * jnp.where(on & sel, a.factor, 1.0)
                elif isinstance(a, iv_lib.Vaccinate):
                    vacc = vacc | (on & sel)
                    sus_m = sus_m * jnp.where(vacc & sel, 1.0 - a.efficacy, 1.0)
            person_sus = self.sus_table[health] * base_bs * sus_m
            person_inf = self.inf_table[health] * base_bi * inf_m

            # ---- phase 1: visit dispatch (all_to_all) ----
            chans = jnp.stack([person_sus, person_inf, visit_ok], axis=-1)
            visit_vals = ex_lib.dispatch(send, recv, chans, Vw, axis)
            sus_v, inf_v, ok_v = (visit_vals[:, 0], visit_vals[:, 1], visit_vals[:, 2])

            # ---- location-side interventions (closures) ----
            open_v = jnp.ones((Vw,), jnp.float32)
            for k, civ in enumerate(self.compiled_ivs):
                if isinstance(civ.action, iv_lib.CloseLocations):
                    on = state["iv_active"][k]
                    closed = take(iv_vloc[k])  # (Vw,) bool
                    open_v = open_v * jnp.where(on & closed, 0.0, 1.0)

            active = (pid >= 0) & (ok_v > 0.0) & (open_v > 0.0)
            eff_pid = jnp.where(active, pid, -1)
            sus_v = sus_v * active
            inf_v = inf_v * active

            # ---- phase 2: interactions ----
            contact_day = jnp.where(self.static_network, dow, day)
            col_inf = iops.col_has_infectious(inf_v, eff_pid, nb, plan.block_size)
            meta = jnp.stack(
                [jnp.asarray(self.seed, jnp.uint32), contact_day.astype(jnp.uint32)]
            )
            acc, cnt = iops.interactions_auto(
                eff_pid, loc, vstart, vend, p_v, sus_v, inf_v,
                row_i, col_i, row_s, pair_a, col_inf, meta,
                block_size=plan.block_size, backend=self.backend,
            )

            # ---- phase 3: exposure combine (adjoint all_to_all) ----
            A = ex_lib.combine(send, recv, acc[:, None] * active[:, None], Pw, axis)
            A = A[:, 0] * jnp.float32(self.tm.tau * self.tm.time_unit)

            # infection sampling on global pids
            gpid = (w * Pw + jnp.arange(Pw)).astype(jnp.uint32)
            u = rng.uniform(self.seed, rng.INFECT, day, gpid)
            infected = (A > 0.0) & (u > jnp.exp(-A))

            # seeding via global order statistic (top-k over workers)
            def seeding(_):
                us = rng.uniform(self.seed, rng.SEED_CHOICE, day, gpid)
                sus_ok = self.sus_table[health] > 0.0
                us = jnp.where(sus_ok, us, 2.0)
                k = self.seed_per_day
                local_small = -jax.lax.top_k(-us, k)[0]  # k smallest local
                all_small = jax.lax.all_gather(local_small, axis).reshape(-1)
                thresh = -jax.lax.top_k(-all_small, k)[0][-1]
                return (us <= thresh) & sus_ok

            seeded = jax.lax.cond(
                day < self.seed_days,
                seeding,
                lambda _: jnp.zeros((Pw,), bool),
                None,
            )

            can = self.sus_table[health] > 0.0
            new_mask = (infected | seeded) & can
            # FSA update with *global* pid draws (same as single-device).
            cum_tab = jnp.asarray(self.disease.cum_trans)
            dwell_mean = jnp.asarray(self.disease.dwell_mean_days)
            nxt = rng.categorical(cum_tab[health], self.seed, rng.TRANSITION, day, gpid)
            dwell_after = dwell - 1.0
            timed = dwell_after <= 0.0
            h_t = jnp.where(timed, nxt, health)
            h_new = jnp.where(new_mask, self.disease.entry_state, h_t)
            changed = new_mask | (timed & (h_new != health))
            nd = rng.exponential(dwell_mean[h_new], self.seed, rng.DWELL, day, gpid)
            nd = jnp.maximum(nd, 1.0)
            nd = jnp.where(
                dwell_mean[h_new] >= disease_lib.ABSORBING_DWELL,
                disease_lib.ABSORBING_DWELL, nd,
            )
            d_new = jnp.where(changed, nd, dwell_after)

            # ---- global reductions (Algorithm 2 line 34's reduction) ----
            new_count = jax.lax.psum(new_mask.sum().astype(jnp.int32), axis)
            infectious = jax.lax.psum(
                (self.inf_table[h_new] > 0.0).sum().astype(jnp.int32), axis
            )
            susceptible = jax.lax.psum(
                (self.sus_table[h_new] > 0.0).sum().astype(jnp.int32), axis
            )
            contacts = jax.lax.psum(cnt.sum().astype(jnp.int32), axis)
            cumulative = state["cumulative"] + new_count
            stats = {
                "day": day,
                "new_infections": new_count,
                "cumulative": cumulative,
                "infectious": infectious,
                "susceptible": susceptible,
                "contacts": contacts,
            }
            iv_active = iv_lib.evaluate_triggers(
                self.compiled_ivs, day, stats, state["iv_active"]
            )
            if len(self.compiled_ivs) == 0:
                iv_active = state["iv_active"]
            new_state = {
                "day": day + 1,
                "health": h_new[None],
                "dwell": d_new[None],
                "cumulative": cumulative,
                "iv_active": iv_active,
                "vaccinated": vacc[None],
            }
            return new_state, stats

        shard_axes = P(AXIS)
        pspec = {
            "day": P(),
            "health": shard_axes,
            "dwell": shard_axes,
            "cumulative": P(),
            "iv_active": P(),
            "vaccinated": shard_axes,
        }
        week_spec = P(None, AXIS)  # (7, W, ...) arrays shard the worker axis
        wspec = jax.tree.map(lambda _: week_spec, wk)
        stat_spec = {k: P() for k in
                     ("day", "new_infections", "cumulative", "infectious",
                      "susceptible", "contacts")}

        step = compat.shard_map(
            worker_step,
            mesh=mesh,
            in_specs=(pspec, wspec, shard_axes, shard_axes,
                      [shard_axes] * len(iv_people),
                      [week_spec] * len(iv_visit_loc)),
            out_specs=(pspec, stat_spec),
        )
        self._wk = wk
        self._iv_people_dev = iv_people
        self._iv_visit_loc_dev = iv_visit_loc
        self._step = jax.jit(
            lambda st: step(
                st, self._wk, self.base_beta_sus, self.base_beta_inf,
                self._iv_people_dev, self._iv_visit_loc_dev,
            )
        )

    # ------------------------------------------------------------------
    def day_step(self, state):
        return self._step(state)

    def run(self, days: int, state=None):
        state = state if state is not None else self.init_state()
        hist: dict[str, list] = {}
        for _ in range(days):
            state, stats = self.day_step(state)
            for k, v in jax.device_get(stats).items():
                hist.setdefault(k, []).append(v)
        return state, {k: np.asarray(v) for k, v in hist.items()}
