"""Distributed epidemic day step (Algorithm 2, SPMD over a device mesh).

People and locations are partitioned exactly as in the paper: people in
uniform blocks, locations by the geo-sorted visit-weighted static scheme
(§V-B). Each simulated day runs three phases inside one `shard_map`:

  1. **visit dispatch** — per-person epidemiological channels (sus value,
     inf value, visit-ok flag) routed person-partition → location-partition
     through the capacity-bucketed all_to_all (core/exchange.py). This is
     the paper's visit-message exchange with aggregation built in.
  2. **interactions** — each worker runs the block-scheduled interaction
     kernel on its local, location-sorted visit arrays.
  3. **exposure combine + update** — per-visit propensities return to the
     person owners through the adjoint all_to_all (exposure messages);
     infection sampling, FSA update, and trigger reductions (psum) follow.

The day step is the pure function :func:`dist_day_step` of
``(static, plan, week, params, state)`` — the distributed twin of
``core/simulator.py:day_step``:

  * ``DistStatic`` — trace-time structure (partition geometry, intervention
    slot layout, kernel backend). Identical across a scenario ensemble.
  * ``plan``/``week`` — per-worker local shards of the static exchange
    routing and weekly visit schedule (device arrays; host construction in
    :func:`build_dist_plan` / :func:`week_device_arrays`).
  * ``params`` — the *same* ``SimParams`` pytree the single-device engine
    uses, with per-person leaves padded to the worker layout
    (:func:`pad_params`). Because every scenario-varying numeric is a leaf
    of this pytree, the step is vmappable over a leading scenario axis —
    the engine core's ``layout="hybrid"`` runs B scenarios × W workers on a
    2-D (workers × scenarios) mesh this way.

A whole run is a single jitted ``lax.scan`` over :func:`dist_day_step`
inside one ``shard_map`` — no host-side per-day dispatch, matching the
single-device and ensemble engines.

Because all stochastic draws are counter-based on *global* ids, the
distributed simulation is bitwise identical to the single-device
reference for any worker count — tested in tests/test_dist.py by spawning
a multi-device host-platform subprocess.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import disease as disease_lib
from repro.core import exchange as ex_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import simulator as sim_lib
from repro.core import transmission as tx_lib
from repro.kernels.interactions import ops as iops

AXIS = "workers"

STAT_KEYS = sim_lib.STAT_KEYS


@dataclasses.dataclass
class DistPlan:
    """Host-built static partition + routing data (all numpy)."""

    num_workers: int
    people_per_worker: int  # Pw (padded)
    num_people: int  # P real
    locs_per_worker: int  # Lw (padded)
    visits_per_worker: int  # Vw (padded, uniform across workers & days)
    pairs_per_worker: int  # NPw
    block_size: int
    # (7, W, Vw)
    week_pid: np.ndarray  # global person ids, -1 pad
    week_loc: np.ndarray  # *global* loc id (for the contact hash), pad ok
    week_start: np.ndarray
    week_end: np.ndarray
    week_p: np.ndarray  # per-visit contact probability (gathered at build)
    # (7, W, NPw) block schedules
    row_idx: np.ndarray
    col_idx: np.ndarray
    row_start: np.ndarray
    pair_active: np.ndarray
    # (7, W, W, C) exchange routing
    send_idx: np.ndarray
    recv_slot: np.ndarray
    capacity: int
    # location partition (for elastic re-partitioning / stats)
    loc_partition: np.ndarray  # (L,)


def build_dist_plan(
    pop: pop_lib.Population,
    num_workers: int,
    block_size: int = 128,
    balanced: bool = True,
    pack: bool = True,
) -> DistPlan:
    W = num_workers
    P_real = pop.num_people
    Pw = int(np.ceil(P_real / W))

    # Location partition: the paper's static load balancing (or naive).
    visits_per_loc = np.zeros((pop.num_locations,), np.int64)
    for d in pop.week:
        np.add.at(visits_per_loc, d.loc[: d.num_real], 1)
    if balanced:
        loc_part = pop_lib.balanced_location_partition(
            pop.geo_key, visits_per_loc, W
        )
    else:
        loc_part = pop_lib.naive_location_partition(pop.num_locations, W)

    person_owner = (np.arange(P_real) // Pw).astype(np.int32)
    person_local = (np.arange(P_real) % Pw).astype(np.int32)

    # Per-worker, per-day location-sorted visit arrays.
    days = []
    for d in pop.week:
        n = d.num_real
        v_part = loc_part[d.loc[:n]]
        per_worker = []
        for w in range(W):
            sel = np.flatnonzero(v_part == w)
            per_worker.append(
                pop_lib.pack_day(
                    d.person[:n][sel], d.loc[:n][sel],
                    d.start[:n][sel], d.end[:n][sel],
                    pad_multiple=block_size,
                )
            )
        days.append(per_worker)
    if pack:
        # Occupancy-aware run packing per worker shard (smaller block-pair
        # schedules; layout is epidemiologically free — global-id draws).
        days = [
            [pop_lib.pack_day_occupancy(pw, block_size) for pw in day]
            for day in days
        ]
        Vw = max(len(pw) for day in days for pw in day)
        Vw = int(np.ceil(Vw / block_size) * block_size)
        days = [[pop_lib.extend_packed(pw, Vw) for pw in day] for day in days]
        extents = [[pw.extent for pw in day] for day in days]
    else:
        Vw = max(len(pw) for day in days for pw in day)
        Vw = int(np.ceil(Vw / block_size) * block_size)
        days = [
            [
                pop_lib.pack_day(
                    pw.person[: pw.num_real], pw.loc[: pw.num_real],
                    pw.start[: pw.num_real], pw.end[: pw.num_real],
                    pad_to=Vw, pad_multiple=block_size,
                )
                for pw in day
            ]
            for day in days
        ]
        extents = [[pw.num_real for pw in day] for day in days]

    # Block schedules, padded to a uniform pair count.
    scheds = [
        [
            pop_lib.build_block_schedule(pw.loc, e, block_size)
            for pw, e in zip(day, ext)
        ]
        for day, ext in zip(days, extents)
    ]
    NPw = max(s.row_block.shape[0] for day in scheds for s in day)
    scheds = [
        [
            pop_lib.build_block_schedule(pw.loc, e, block_size, pad_to=NPw)
            for pw, e in zip(day, ext)
        ]
        for day, ext in zip(days, extents)
    ]

    # Exchange plans (same routing structure every day; capacity = max).
    plans = []
    for day in days:
        vp = np.stack([pw.person for pw in day])  # (W, Vw)
        plans.append(
            ex_lib.build_exchange_plan(vp, person_owner, person_local)
        )
    C = max(p.capacity for p in plans)
    send_idx = np.full((7, W, W, C), -1, np.int32)
    recv_slot = np.full((7, W, W, C), -1, np.int32)
    for d, p in enumerate(plans):
        send_idx[d, :, :, : p.capacity] = p.send_idx
        recv_slot[d, :, :, : p.capacity] = p.recv_slot

    stack = lambda f: np.stack([np.stack([f(x) for x in day]) for day in days])
    sstack = lambda f: np.stack([np.stack([f(s) for s in day]) for day in scheds])

    # Per-visit contact probability, gathered on host (location attrs are
    # static; this is the paper's "store p as a location attribute").
    week_p = np.stack(
        [
            np.stack([pop.contact_prob[np.minimum(pw.loc, pop.num_locations - 1)]
                      for pw in day])
            for day in days
        ]
    ).astype(np.float32)

    # Padded locations per worker (only used for closure masks / stats).
    Lw = int(np.max(np.bincount(loc_part, minlength=W)))

    return DistPlan(
        num_workers=W,
        people_per_worker=Pw,
        num_people=P_real,
        locs_per_worker=Lw,
        visits_per_worker=Vw,
        pairs_per_worker=NPw,
        block_size=block_size,
        week_pid=stack(lambda x: x.person),
        week_loc=stack(lambda x: x.loc),
        week_start=stack(lambda x: x.start),
        week_end=stack(lambda x: x.end),
        week_p=week_p,
        row_idx=sstack(lambda s: s.row_block),
        col_idx=sstack(lambda s: s.col_block),
        row_start=sstack(lambda s: s.row_start.astype(np.int32)),
        pair_active=sstack(lambda s: s.pair_active.astype(np.int32)),
        send_idx=send_idx,
        recv_slot=recv_slot,
        capacity=C,
        loc_partition=loc_part,
    )


# --------------------------------------------------------------------------
# Trace-time structure + device-array builders for the pure day step
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DistStatic:
    """Trace-time structure of the distributed step: partition geometry plus
    the same intervention slot layout / backend as ``SimStatic``. Identical
    across every scenario of a hybrid ensemble."""

    num_people: int  # real P (pre-padding)
    num_locations: int
    num_workers: int
    people_per_worker: int  # Pw
    visits_per_worker: int  # Vw
    block_size: int
    seed_topk: int  # static per-worker top-k width for outbreak seeding
    iv_slots: tuple  # tuple[iv_lib.IvSlotStatic, ...]
    backend: str = "jnp"


def make_dist_static(
    plan: DistPlan,
    num_locations: int,
    iv_slots: tuple,
    backend: str = "jnp",
    max_seed_per_day: int = 10,
) -> DistStatic:
    """``seed_topk`` must cover the largest ``seed_per_day`` any scenario
    will run with (clamped to the shard size) so the global order statistic
    in :func:`dist_day_step` is exact — see the seeding phase there."""
    return DistStatic(
        num_people=plan.num_people,
        num_locations=num_locations,
        num_workers=plan.num_workers,
        people_per_worker=plan.people_per_worker,
        visits_per_worker=plan.visits_per_worker,
        block_size=plan.block_size,
        seed_topk=max(1, min(int(max_seed_per_day), plan.people_per_worker)),
        iv_slots=iv_slots,
        backend=backend,
    )


def week_device_arrays(plan: DistPlan):
    """Device copies of the weekly schedule + exchange routing, split into
    the ``week`` (visit schedule) and ``plan`` (routing) arguments of
    :func:`dist_day_step`. All arrays are (7, W, ...) — sharded on axis 1.
    """
    week = {
        "pid": jnp.asarray(plan.week_pid),
        "loc": jnp.asarray(plan.week_loc),
        "start": jnp.asarray(plan.week_start),
        "end": jnp.asarray(plan.week_end),
        "p": jnp.asarray(plan.week_p),
        "row": jnp.asarray(plan.row_idx),
        "col": jnp.asarray(plan.col_idx),
        "rs": jnp.asarray(plan.row_start),
        "pa": jnp.asarray(plan.pair_active),
    }
    route = {
        "send": jnp.asarray(plan.send_idx),
        "recv": jnp.asarray(plan.recv_slot),
    }
    return week, route


def pad_params(params: sim_lib.SimParams, plan: DistPlan) -> sim_lib.SimParams:
    """Pad the per-person leaves of a single-device ``SimParams`` to the
    plan's W*Pw person axis. Pad people have zero betas and sit outside
    every selector mask, so they are epidemiologically inert."""
    pad = plan.num_workers * plan.people_per_worker - plan.num_people
    padp = lambda a: jnp.pad(a, ((0, pad),))
    return dataclasses.replace(
        params,
        beta_sus=padp(params.beta_sus),
        beta_inf=padp(params.beta_inf),
        iv=dataclasses.replace(
            params.iv,
            people=jnp.pad(params.iv.people, ((0, 0), (0, pad))),
            pa_people=jnp.pad(params.iv.pa_people, ((0, 0), (0, pad))),
        ),
    )


def _spec(batch_axis, *axes):
    return P(batch_axis, *axes) if batch_axis is not None else P(*axes)


def dist_param_specs(batch_axis: Optional[str] = None) -> sim_lib.SimParams:
    """SimParams-shaped PartitionSpec tree for the worker-padded layout.
    ``batch_axis`` prepends a scenario axis to every leaf (hybrid mesh)."""
    s = lambda *axes: _spec(batch_axis, *axes)
    iv = iv_lib.IvParams(
        enabled=s(), day_start=s(), day_end=s(), thresh_on=s(),
        thresh_off=s(), factor=s(), people=s(None, AXIS), locations=s(),
        pa_enabled=s(), pa_start=s(), pa_tests=s(), pa_iso=s(),
        pa_trace_iso=s(), pa_people=s(None, AXIS),
    )
    return sim_lib.SimParams(
        seed=s(), tau_eff=s(), sus_table=s(), inf_table=s(), sym_table=s(),
        cum_trans=s(),
        dwell_mean=s(), entry_state=s(), beta_sus=s(AXIS), beta_inf=s(AXIS),
        seed_per_day=s(), seed_days=s(), static_network=s(), iv=iv,
    )


def dist_state_specs(batch_axis: Optional[str] = None) -> sim_lib.SimState:
    s = lambda *axes: _spec(batch_axis, *axes)
    return sim_lib.SimState(
        day=s(), health=s(AXIS), dwell=s(AXIS), cumulative=s(),
        iv_active=s(), vaccinated=s(AXIS),
        tested=s(AXIS), traced=s(AXIS), isolated_until=s(AXIS),
    )


def dist_init_state(
    disease: disease_lib.DiseaseModel, plan: DistPlan, num_iv_slots: int
) -> sim_lib.SimState:
    """Worker-padded initial state; pad people enter an absorbing,
    non-susceptible state so they never participate."""
    Ppad = plan.num_workers * plan.people_per_worker
    non_sus = np.flatnonzero(np.asarray(disease.susceptibility) == 0.0)
    if Ppad > plan.num_people and len(non_sus) == 0:
        raise ValueError(
            f"disease model '{disease.name}' has no zero-susceptibility "
            "state to park the padded people in — they would be seedable "
            "and break dist<->single parity"
        )
    absorbing = int(non_sus[0]) if len(non_sus) else disease.initial_state
    health = np.full((Ppad,), absorbing, np.int32)
    health[: plan.num_people] = disease.initial_state
    return sim_lib.SimState(
        day=jnp.asarray(0, jnp.int32),
        health=jnp.asarray(health),
        dwell=jnp.full((Ppad,), disease_lib.ABSORBING_DWELL, jnp.float32),
        cumulative=jnp.asarray(0, jnp.int32),
        iv_active=jnp.zeros((num_iv_slots,), bool),
        vaccinated=jnp.zeros((Ppad,), bool),
        tested=jnp.zeros((Ppad,), bool),
        traced=jnp.zeros((Ppad,), bool),
        isolated_until=jnp.zeros((Ppad,), jnp.int32),
    )


# --------------------------------------------------------------------------
# The pure distributed day step (call inside shard_map over axis AXIS)
# --------------------------------------------------------------------------


def dist_day_step(
    static: DistStatic,
    plan,  # dict: local (7, W, C) exchange routing ("send", "recv")
    week,  # dict: local (7, ...) weekly visit schedule + block schedules
    params: sim_lib.SimParams,  # per-person leaves are local (Pw,) shards
    state: sim_lib.SimState,  # health/dwell/vaccinated local (Pw,) shards
):
    """One distributed day on one worker's local shard; pure in
    (params, state). The SPMD twin of ``simulator.day_step`` — same
    counter-based draws on global person ids, so results are bitwise equal
    to the single-device reference. vmappable over a leading scenario axis
    of (params, state) for hybrid (workers × scenarios) ensembles.
    """
    axis = AXIS
    Pw, Vw = static.people_per_worker, static.visits_per_worker
    w = jax.lax.axis_index(axis)
    day = state.day
    dow = day % pop_lib.DAYS_PER_WEEK
    take = lambda a: jax.lax.dynamic_index_in_dim(a, dow, 0, keepdims=False)
    pid = take(week["pid"])  # (Vw,) global person ids, -1 pad
    loc = take(week["loc"])
    vstart, vend = take(week["start"]), take(week["end"])
    p_v = take(week["p"])
    row_i, col_i = take(week["row"]), take(week["col"])
    row_s, pair_a = take(week["rs"]), take(week["pa"])
    send, recv = take(plan["send"]), take(plan["recv"])  # (W, C)

    # ---- phase 1: interventions + per-person channels (shared iv lib) ----
    visit_ok, loc_open, sus_mult, inf_mult, vaccinated = iv_lib.apply_iv_params(
        static.iv_slots,
        params.iv,
        state.iv_active,
        state.vaccinated,
        Pw,
        static.num_locations,
    )
    person_sus = params.sus_table[state.health] * params.beta_sus * sus_mult
    person_inf = params.inf_table[state.health] * params.beta_inf * inf_mult

    # ---- visit dispatch (all_to_all): route person channels to visits ----
    chans = jnp.stack(
        [person_sus, person_inf, visit_ok.astype(jnp.float32)], axis=-1
    )
    visit_vals = ex_lib.dispatch(send, recv, chans, Vw, axis)
    sus_v, inf_v, ok_v = visit_vals[:, 0], visit_vals[:, 1], visit_vals[:, 2]

    # Location-side closures: loc_open is (L,) replicated; gather per visit.
    open_v = loc_open[jnp.minimum(loc, static.num_locations - 1)]
    active = (pid >= 0) & (ok_v > 0.0) & open_v
    eff_pid = jnp.where(active, pid, -1)
    sus_v = sus_v * active
    inf_v = inf_v * active

    # ---- phase 2: interactions ----
    contact_day = jnp.where(params.static_network, dow, day)
    col_inf = iops.col_has_infectious(
        inf_v, eff_pid, Vw // static.block_size, static.block_size
    )
    row_sus = iops.row_has_susceptible(
        sus_v, eff_pid, Vw // static.block_size, static.block_size
    )
    meta = jnp.stack(
        [params.seed.astype(jnp.uint32), contact_day.astype(jnp.uint32)]
    )
    acc, cnt = iops.interactions_auto(
        eff_pid, loc, vstart, vend, p_v, sus_v, inf_v,
        row_i, col_i, row_s, pair_a, col_inf, row_sus, meta,
        block_size=static.block_size, backend=static.backend,
    )

    # ---- phase 3: exposure combine (adjoint all_to_all) + update ----
    A = ex_lib.combine(send, recv, acc[:, None] * active[:, None], Pw, axis)
    A = A[:, 0] * params.tau_eff

    gpid = (w * Pw + jnp.arange(Pw, dtype=jnp.int32)).astype(jnp.uint32)
    infected = tx_lib.sample_infections(A, params.seed, day, pid=gpid)

    def with_seeding(_):
        # Global order statistic: union of per-worker top-k smallest draws.
        # static.seed_topk >= min(seed_per_day, Pw) guarantees the global
        # k-th smallest is inside the gathered union, so the threshold is
        # bitwise identical to the single-device full sort.
        us = rng.uniform(params.seed, rng.SEED_CHOICE, day, gpid)
        sus_ok = params.sus_table[state.health] > 0.0
        us = jnp.where(sus_ok, us, 2.0)
        local_small = -jax.lax.top_k(-us, static.seed_topk)[0]
        all_small = jnp.sort(
            jax.lax.all_gather(local_small, axis).reshape(-1)
        )
        k = jnp.minimum(params.seed_per_day, static.num_people) - 1
        thresh = all_small[jnp.clip(k, 0, all_small.shape[0] - 1)]
        return (us <= thresh) & sus_ok & (params.seed_per_day > 0)

    seeded = jax.lax.cond(
        day < params.seed_days,
        with_seeding,
        lambda _: jnp.zeros((Pw,), bool),
        None,
    )

    can_infect = params.sus_table[state.health] > 0.0
    new_mask = (infected | seeded) & can_infect
    health, dwell = disease_lib.update_health_tables(
        params.cum_trans,
        params.dwell_mean,
        params.sus_table,
        params.entry_state,
        state.health,
        state.dwell,
        new_mask,
        params.seed,
        day,
        pid=gpid,
    )

    # ---- global reductions (Algorithm 2 line 34's reduction) ----
    new_count = jax.lax.psum(new_mask.sum().astype(jnp.int32), axis)
    cumulative = state.cumulative + new_count
    infectious = jax.lax.psum(
        (params.inf_table[health] > 0.0).sum().astype(jnp.int32), axis
    )
    susceptible = jax.lax.psum(
        (params.sus_table[health] > 0.0).sum().astype(jnp.int32), axis
    )
    # Widen before the cross-worker accumulation: at paper scale (~4.6B
    # traversed edges/s) an int32 psum wraps within one day. Mirrors the
    # single-device widening in simulator.py:phase_update.
    cdtype = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32
    contacts = jax.lax.psum(cnt.sum().astype(cdtype), axis)
    stats = {
        "day": day,
        "new_infections": new_count,
        "cumulative": cumulative,
        "infectious": infectious,
        "susceptible": susceptible,
        "contacts": contacts,
        # Host-side traversed edges (== contacts by construction); see
        # simulator.STAT_KEYS for why it is a separate key.
        "edges": contacts,
        # Legacy reference path: no per-agent interventions (zeros, like
        # simulator.phase_update — the unified engine computes these).
        "tests_used": jnp.zeros((), jnp.int32),
        "isolated": jnp.zeros((), jnp.int32),
        "traced": jnp.zeros((), jnp.int32),
    }
    iv_active = iv_lib.evaluate_iv_triggers(
        static.iv_slots, params.iv, day, stats, state.iv_active
    )
    new_state = sim_lib.SimState(
        day=day + 1,
        health=health,
        dwell=dwell,
        cumulative=cumulative,
        iv_active=iv_active,
        vaccinated=vaccinated,
        tested=state.tested,
        traced=state.traced,
        isolated_until=state.isolated_until,
    )
    return new_state, stats


def dist_run_scan(static, plan, week, params, state, days: int):
    """A whole distributed run as one lax.scan over :func:`dist_day_step`."""

    def body(s, _):
        return dist_day_step(static, plan, week, params, s)

    return jax.lax.scan(body, state, None, length=days)
