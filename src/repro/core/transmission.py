"""Transmission model (paper §III-A4).

Propensity of a contact between susceptible i and infectious j overlapping
for T seconds:

    rho(i, j, T) = T * tau * beta_sigma(p_i) * sigma(X_i)
                         * beta_iota(p_j)  * iota(X_j)        (Eq. 2)

Per-person accumulated propensity over the day's m infectious contacts:

    A(p_i) = sum_j rho(X_i, X_j, T_j)                          (Eq. 3)

and p_i is infected iff  a = -log(u)/A < 1  for u ~ U(0,1), i.e. with
probability 1 - exp(-A).

All draws are counter-based (see core/rng.py): the contact Bernoulli for the
pair (i, j) on a given day and the infection draw for person i are pure
functions of ids + day, which makes the simulation partition-invariant.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import rng


@dataclasses.dataclass(frozen=True)
class TransmissionModel:
    tau: float = 0.05  # global tuning value (paper validation uses 0.05)
    time_unit: float = 1.0  # multiplier converting visit time units -> seconds


def pair_propensity(
    tm: TransmissionModel,
    overlap: jnp.ndarray,  # (..., ) seconds of co-occupancy T
    sus_sigma: jnp.ndarray,  # sigma(X_i) * beta_sigma(p_i), susceptible side
    inf_iota: jnp.ndarray,  # iota(X_j) * beta_iota(p_j), infectious side
) -> jnp.ndarray:
    return overlap * jnp.float32(tm.tau * tm.time_unit) * sus_sigma * inf_iota


def sample_infections(
    total_propensity: jnp.ndarray,  # (P,) A(p_i)
    seed,
    day,
    pid=None,  # (P,) uint32 ids keying the draws; default = arange
) -> jnp.ndarray:
    """Bernoulli(1 - exp(-A)) per person, via the paper's -log(u)/A < 1 form.

    ``pid`` lets a sharded caller pass *global* person ids so the per-worker
    draws match the single-device reference bitwise."""
    if pid is None:
        pid = jnp.arange(total_propensity.shape[0], dtype=jnp.uint32)
    u = rng.uniform(seed, rng.INFECT, day, pid)
    # -log(u)/A < 1  <=>  u > exp(-A); guard A == 0 (no exposure).
    return (total_propensity > 0.0) & (u > jnp.exp(-total_propensity))


def infection_probability(total_propensity: jnp.ndarray) -> jnp.ndarray:
    return 1.0 - jnp.exp(-total_propensity)
