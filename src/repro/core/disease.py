"""Disease models as finite state automata (paper §III-A1).

Each state carries a susceptibility sigma and infectivity iota. Transitions
are stochastic both in the next state (categorical) and in dwell time
(exponential around a per-state mean, matching "non-deterministic both in
terms of the state transitioned to and how long a person remains").

The FSA is represented with small dense tables so the per-day update is a
handful of vectorized gathers over the (P,) person-state arrays — no
per-agent control flow, which is the TPU-native replacement for the paper's
per-person FSA objects stored on Charm++ node groups (here the tables live
replicated on every device, the moral equivalent of a node group).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core import rng

# Dwell value treated as "never times out" (absorbing states).
ABSORBING_DWELL = 1.0e9


@dataclasses.dataclass(frozen=True)
class DiseaseModel:
    """Immutable FSA description. All tables are small numpy arrays; they are
    closed over by the jitted day step (replicated constants on device)."""

    name: str
    states: tuple[str, ...]
    susceptibility: np.ndarray  # (S,) f32, sigma(X)
    infectivity: np.ndarray  # (S,) f32, iota(X)
    trans_probs: np.ndarray  # (S, S) f32, rows sum to 1 (absorbing: self=1)
    dwell_mean_days: np.ndarray  # (S,) f32; ABSORBING_DWELL for absorbing
    entry_state: int  # state entered on infection (e.g. E)
    initial_state: int  # state people start in (e.g. S)
    # Optional (S,) f32 mask of *symptomatic* states: the testing-priority
    # tier for per-agent interventions. None = "any infectious state".
    symptomatic: Optional[np.ndarray] = None

    @property
    def num_states(self) -> int:
        return len(self.states)

    @property
    def sym_table(self) -> np.ndarray:
        """(S,) f32: 1.0 for states that present symptoms (test priority)."""
        if self.symptomatic is not None:
            return np.asarray(self.symptomatic, np.float32)
        return (self.infectivity > 0).astype(np.float32)

    def state_index(self, name: str) -> int:
        return self.states.index(name)

    @property
    def cum_trans(self) -> np.ndarray:
        return np.cumsum(self.trans_probs, axis=-1).astype(np.float32)

    @property
    def infectious_mask(self) -> np.ndarray:
        return (self.infectivity > 0).astype(np.bool_)

    @property
    def susceptible_mask(self) -> np.ndarray:
        return (self.susceptibility > 0).astype(np.bool_)

    def validate(self) -> None:
        S = self.num_states
        assert self.trans_probs.shape == (S, S)
        np.testing.assert_allclose(self.trans_probs.sum(-1), 1.0, atol=1e-5)
        assert 0 <= self.entry_state < S and 0 <= self.initial_state < S


def make_disease(
    name: str,
    states: Sequence[str],
    susceptibility: Sequence[float],
    infectivity: Sequence[float],
    transitions: dict[str, dict[str, float]],
    dwell_mean_days: dict[str, float],
    entry_state: str,
    initial_state: str,
    symptomatic: Optional[Sequence[str]] = None,
) -> DiseaseModel:
    """Friendly constructor from dicts (the moral equivalent of the paper's
    Protobuf disease-model input format; see configs/ for concrete models)."""
    states = tuple(states)
    S = len(states)
    idx = {s: i for i, s in enumerate(states)}
    tp = np.zeros((S, S), np.float32)
    for s, outs in transitions.items():
        for t, p in outs.items():
            tp[idx[s], idx[t]] = p
    for i in range(S):
        if tp[i].sum() == 0.0:  # absorbing
            tp[i, i] = 1.0
    dwell = np.full((S,), ABSORBING_DWELL, np.float32)
    for s, d in dwell_mean_days.items():
        dwell[idx[s]] = d
    sym = None
    if symptomatic is not None:
        sym = np.zeros((S,), np.float32)
        for s in symptomatic:
            sym[idx[s]] = 1.0
    m = DiseaseModel(
        name=name,
        states=states,
        susceptibility=np.asarray(susceptibility, np.float32),
        infectivity=np.asarray(infectivity, np.float32),
        trans_probs=tp,
        dwell_mean_days=dwell,
        entry_state=idx[entry_state],
        initial_state=idx[initial_state],
        symptomatic=sym,
    )
    m.validate()
    return m


def covid_model() -> DiseaseModel:
    """Expanded SEIR tuned to represent COVID-19 (paper §III-A1): exposed,
    presymptomatic, symptomatic/asymptomatic branch, recovered."""
    return make_disease(
        name="covid-seir+",
        states=("S", "E", "Ipre", "Isym", "Iasym", "R"),
        susceptibility=[1.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        infectivity=[0.0, 0.0, 0.8, 1.0, 0.5, 0.0],
        transitions={
            "E": {"Ipre": 1.0},
            "Ipre": {"Isym": 0.65, "Iasym": 0.35},
            "Isym": {"R": 1.0},
            "Iasym": {"R": 1.0},
        },
        dwell_mean_days={"E": 3.0, "Ipre": 2.0, "Isym": 5.0, "Iasym": 4.0},
        entry_state="E",
        initial_state="S",
        symptomatic=["Isym"],
    )


def sir_model(recovery_days: float = 7.0) -> DiseaseModel:
    """Simple SIR used for the EpiHiper validation study (paper §VI/§VIII)."""
    return make_disease(
        name="sir",
        states=("S", "I", "R"),
        susceptibility=[1.0, 0.0, 0.0],
        infectivity=[0.0, 1.0, 0.0],
        transitions={"I": {"R": 1.0}},
        dwell_mean_days={"I": recovery_days},
        entry_state="I",
        initial_state="S",
    )


def seir_model() -> DiseaseModel:
    """Classic SEIR (FRED-style fixed pipeline) — used in ablations."""
    return make_disease(
        name="seir",
        states=("S", "E", "I", "R"),
        susceptibility=[1.0, 0.0, 0.0, 0.0],
        infectivity=[0.0, 0.0, 1.0, 0.0],
        transitions={"E": {"I": 1.0}, "I": {"R": 1.0}},
        dwell_mean_days={"E": 3.0, "I": 6.0},
        entry_state="E",
        initial_state="S",
    )


# ----------------------------------------------------------------------------
# Vectorized per-day FSA update
# ----------------------------------------------------------------------------


def initial_health(model: DiseaseModel, num_people: int):
    """(state, dwell_left) arrays for a fresh population."""
    state = jnp.full((num_people,), model.initial_state, jnp.int32)
    dwell = jnp.full((num_people,), ABSORBING_DWELL, jnp.float32)
    return state, dwell


def update_health_tables(
    cum_trans: jnp.ndarray,  # (S, S) cumulative transition rows
    dwell_mean: jnp.ndarray,  # (S,)
    susceptibility: jnp.ndarray,  # (S,)
    entry_state,  # scalar int32 (may be traced — scenario-ensemble path)
    state: jnp.ndarray,  # (P,) int32
    dwell_left: jnp.ndarray,  # (P,) f32 days remaining in current state
    newly_infected: jnp.ndarray,  # (P,) bool
    seed,
    day,
    pid=None,  # (P,) uint32 ids for the draws; default = arange (global ids)
):
    """End-of-day health update (Algorithm 2 line 30), table-driven.

    Order matters and matches the serial algorithm: infections landed this
    day take precedence (a susceptible cannot also make a timed transition),
    then timed transitions fire for anyone whose dwell expired.

    Every disease-model input is a (traceable) array, which makes this the
    FSA update used under vmap-over-scenarios where each scenario carries
    perturbed tables (:mod:`repro.engine`). Draws are keyed on ``pid`` —
    the distributed engine passes each worker's *global* person ids so a
    sharded update is bitwise identical to the single-device one.
    """
    if pid is None:
        pid = jnp.arange(state.shape[0], dtype=jnp.uint32)

    # Timed transition draws (only applied where dwell expires).
    next_state = rng.categorical(cum_trans[state], seed, rng.TRANSITION, day, pid)
    dwell_after = dwell_left - 1.0
    timed = dwell_after <= 0.0

    state_t = jnp.where(timed, next_state, state)
    # Infection overrides: susceptible -> entry state.
    can_infect = susceptibility[state] > 0.0
    infected = newly_infected & can_infect
    state_new = jnp.where(infected, entry_state, state_t)

    changed = infected | (timed & (state_new != state))
    new_dwell = rng.exponential(
        dwell_mean[state_new], seed, rng.DWELL, day, pid
    )
    # Keep at least one day in any transient state (paper's day granularity).
    new_dwell = jnp.maximum(new_dwell, 1.0)
    new_dwell = jnp.where(
        dwell_mean[state_new] >= ABSORBING_DWELL, ABSORBING_DWELL, new_dwell
    )
    dwell_out = jnp.where(changed, new_dwell, dwell_after)
    return state_new, dwell_out


def update_health(
    model: DiseaseModel,
    state: jnp.ndarray,  # (P,) int32
    dwell_left: jnp.ndarray,  # (P,) f32 days remaining in current state
    newly_infected: jnp.ndarray,  # (P,) bool
    seed,
    day,
):
    """Model-object convenience wrapper over :func:`update_health_tables`."""
    return update_health_tables(
        jnp.asarray(model.cum_trans),
        jnp.asarray(model.dwell_mean_days),
        jnp.asarray(model.susceptibility),
        model.entry_state,
        state,
        dwell_left,
        newly_infected,
        seed,
        day,
    )


def seed_infections(
    model: DiseaseModel,
    state: jnp.ndarray,
    dwell_left: jnp.ndarray,
    num_to_seed: int,
    seed,
    day,
):
    """Infect ~num_to_seed random susceptible people (paper: 10/day for the
    first week). Partition-invariant: the chosen people are the ones with the
    smallest hash draw, a global order-statistic independent of sharding."""
    P = state.shape[0]
    pid = jnp.arange(P, dtype=jnp.uint32)
    u = rng.uniform(seed, rng.SEED_CHOICE, day, pid)
    sus = jnp.asarray(model.susceptibility)[state] > 0.0
    u = jnp.where(sus, u, 2.0)  # non-susceptible sort last
    # threshold = (num_to_seed)-th smallest draw
    thresh = jnp.sort(u)[jnp.minimum(num_to_seed, P) - 1]
    chosen = (u <= thresh) & sus
    return update_health(model, state, dwell_left, chosen, seed, day)
