"""Contact models (paper §III-A3).

The min/max/alpha model computes, per location, the probability p that any
given pair of simultaneously-present people actually come into contact, as a
function of the location's maximum occupancy N (a proxy for its size):

    p = min(1, [A + (B - A) * (1 - exp(-N / alpha))] / (N - 1))     (Eq. 1)

so that a person visiting at peak occupancy expects between A and B contacts.
The paper uses A=5, B=40, alpha=1000 (calibrated against POLYMOD).

As in the implementation described in §IV-C3, max occupancy is a
*pre-processing* product of the visit schedule (computed here with a
vectorized sweep instead of the paper's script), and the per-location p is
computed once at initialization and stored as a location attribute.

The second model (fixed probability everywhere) is used for purely synthetic
populations where max occupancy is not known in advance.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MinMaxAlpha:
    min_contacts: float = 5.0  # A
    max_contacts: float = 40.0  # B
    alpha: float = 1000.0

    def probability(self, max_occupancy):
        """Vectorized Eq. 1. Works on numpy or jnp arrays."""
        xp = jnp if isinstance(max_occupancy, jnp.ndarray) else np
        N = xp.asarray(max_occupancy, dtype=xp.float32)
        A, B, a = self.min_contacts, self.max_contacts, self.alpha
        expected = A + (B - A) * (1.0 - xp.exp(-N / a))
        p = expected / xp.maximum(N - 1.0, 1.0)
        # N <= 2: everyone present makes contact (Eq. 1 is defined for N > 2).
        p = xp.where(N <= 2.0, 1.0, xp.minimum(p, 1.0))
        return p.astype(xp.float32)


@dataclasses.dataclass(frozen=True)
class FixedProbability:
    p: float = 0.5

    def probability(self, max_occupancy):
        xp = jnp if isinstance(max_occupancy, jnp.ndarray) else np
        N = xp.asarray(max_occupancy, dtype=xp.float32)
        return xp.full_like(N, xp.float32(self.p))


def max_occupancy_from_visits(
    num_locations: int,
    visit_loc: np.ndarray,
    visit_start: np.ndarray,
    visit_end: np.ndarray,
) -> np.ndarray:
    """Peak simultaneous occupancy per location from one day's visits.

    **Test oracle only** — the literal O(E) event loop (+1 at each arrival,
    -1 at each departure, running max per location), kept as the readable
    specification of the tie-breaking semantics (departures before arrivals
    at equal times, so touching visits never overlap). Production code uses
    the vectorized :func:`max_occupancy_fast`; the two are property-tested
    equal on tied-time schedules in tests/test_property.py.
    """
    occ = np.zeros((num_locations,), np.int32)
    if len(visit_loc) == 0:
        return occ
    # Event stream: (time, +1/-1, loc); departures before arrivals at ties
    # (a visit ending exactly when another starts does not overlap).
    times = np.concatenate([visit_start, visit_end])
    deltas = np.concatenate(
        [np.ones_like(visit_start, np.int32), -np.ones_like(visit_end, np.int32)]
    )
    locs = np.concatenate([visit_loc, visit_loc])
    order = np.lexsort((deltas, times))  # deltas=-1 (departure) sorts first
    cur = np.zeros((num_locations,), np.int32)
    for t, d, l in zip(times[order], deltas[order], locs[order]):
        cur[l] += d
        if cur[l] > occ[l]:
            occ[l] = cur[l]
    return occ


def max_occupancy_fast(
    num_locations: int,
    visit_loc: np.ndarray,
    visit_start: np.ndarray,
    visit_end: np.ndarray,
) -> np.ndarray:
    """Vectorized variant of :func:`max_occupancy_from_visits` (numpy only,
    O(E log E)): per-location running max via sorted cumulative deltas."""
    E = len(visit_loc)
    occ = np.zeros((num_locations,), np.int32)
    if E == 0:
        return occ
    times = np.concatenate([visit_start, visit_end])
    deltas = np.concatenate([np.ones(E, np.int64), -np.ones(E, np.int64)])
    locs = np.concatenate([visit_loc, visit_loc]).astype(np.int64)
    # Sort by (loc, time, delta) with departures first at equal times.
    order = np.lexsort((deltas, times, locs))
    locs_s, deltas_s = locs[order], deltas[order]
    run = np.cumsum(deltas_s)
    # Subtract the cumulative total up to the start of each location segment.
    seg_start = np.searchsorted(locs_s, np.arange(num_locations), side="left")
    seg_end = np.searchsorted(locs_s, np.arange(num_locations), side="right")
    base = np.concatenate([[0], run])[seg_start]
    # Per-location running max of (run - base) over its segment.
    np.maximum.at(occ, locs_s, (run - np.repeat(base, seg_end - seg_start)).astype(np.int32))
    return occ
