"""EpiHiper-style static-contact-network baseline (paper §VI, §VIII).

EpiHiper pre-processes the visit schedule into a FIXED contact network
(per run), then diffuses the disease over it. Two implementations here:

1. The production path: ``EngineCore.single(static_network=True)`` keys
   the contact hash by day-of-week instead of absolute day — the same
   weekly contact network every week, per replicate seed. This is what
   benchmarks/bench_validation.py (Fig 9) compares against the dynamic
   mode.

2. This module: an *independent* edge-list implementation — precompute
   the weekly contact edges explicitly (numpy, from the same contact
   draws), then run SIR diffusion over the edge list with the same
   transmission model. Serves as a second oracle for the static mode and
   mirrors EpiHiper's architecture literally.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import disease as disease_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import transmission as tx_lib


@dataclasses.dataclass
class ContactNetwork:
    """Weekly static contact network: directed contact edges per day-of-week."""

    src: list  # 7 arrays of person ids (susceptible side)
    dst: list  # 7 arrays of person ids (infectious side)
    duration: list  # 7 arrays of overlap seconds


def precompute_contact_network(pop: pop_lib.Population, seed: int) -> ContactNetwork:
    """Enumerate contacts for each day-of-week (the EpiHiper preprocessing
    script). O(sum of per-location pair counts) with numpy blocking."""
    src_all, dst_all, dur_all = [], [], []
    for dow, day in enumerate(pop.week):
        n = day.num_real
        loc, person = day.loc[:n], day.person[:n]
        start, end = day.start[:n], day.end[:n]
        srcs, dsts, durs = [], [], []
        # iterate location runs (visits are location-sorted)
        change = np.flatnonzero(np.diff(loc)) + 1
        starts_idx = np.concatenate([[0], change])
        ends_idx = np.concatenate([change, [n]])
        for s, e in zip(starts_idx, ends_idx):
            m = e - s
            if m < 2:
                continue
            p = person[s:e]
            st, en = start[s:e], end[s:e]
            ov = np.minimum(en[:, None], en[None, :]) - np.maximum(
                st[:, None], st[None, :]
            )
            ii, jj = np.nonzero((ov > 0) & (p[:, None] != p[None, :]))
            if len(ii) == 0:
                continue
            pmin = np.minimum(p[ii], p[jj])
            pmax = np.maximum(p[ii], p[jj])
            u = rng.np_uniform(seed, int(rng.CONTACT), dow, pmin, pmax,
                               np.full(len(ii), loc[s]))
            keep = u < pop.contact_prob[loc[s]]
            srcs.append(p[ii][keep])
            dsts.append(p[jj][keep])
            durs.append(ov[ii, jj][keep])
        src_all.append(np.concatenate(srcs) if srcs else np.zeros(0, np.int64))
        dst_all.append(np.concatenate(dsts) if dsts else np.zeros(0, np.int64))
        dur_all.append(np.concatenate(durs) if durs else np.zeros(0, np.float64))
    return ContactNetwork(src_all, dst_all, dur_all)


def run_sir_on_network(
    pop: pop_lib.Population,
    net: ContactNetwork,
    tm: tx_lib.TransmissionModel,
    days: int,
    seed: int,
    seed_per_day: int = 2,
    seed_days: int = 5,
    recovery_days: float = 7.0,
):
    """SIR diffusion over the static network, same draws as the simulator
    (INFECT/SEED_CHOICE/DWELL streams on global pids)."""
    model = disease_lib.sir_model(recovery_days)
    P = pop.num_people
    S, I, R = 0, 1, 2
    state = np.zeros(P, np.int32)
    dwell = np.full(P, disease_lib.ABSORBING_DWELL)
    cum = 0
    hist = {"cumulative": [], "infectious": []}
    pid = np.arange(P)
    for day in range(days):
        dow = day % 7
        src, dst, dur = net.src[dow], net.dst[dow], net.duration[dow]
        inf_val = (state == I).astype(np.float64) * pop.beta_inf
        sus_val = (state == S).astype(np.float64) * pop.beta_sus
        A = np.zeros(P)
        # edges are ordered pairs (both (i,j) and (j,i) enumerated), so a
        # single directed contribution per edge covers both roles
        np.add.at(A, src, dur * sus_val[src] * inf_val[dst])
        A *= tm.tau * tm.time_unit
        u = rng.np_uniform(seed, int(rng.INFECT), day, pid)
        infected = (A > 0) & (u > np.exp(-A))
        if day < seed_days and seed_per_day:
            us = rng.np_uniform(seed, int(rng.SEED_CHOICE), day, pid)
            us = np.where(state == S, us, 2.0)
            k = min(seed_per_day, P)
            thresh = np.partition(us, k - 1)[k - 1]
            infected |= (us <= thresh) & (state == S)
        newly = infected & (state == S)
        # timed recovery
        dwell -= 1.0
        recovered = (state == I) & (dwell <= 0)
        state[recovered] = R
        state[newly] = I
        d = rng.np_uniform(seed, int(rng.DWELL), day, pid)
        dwell[newly] = np.maximum(-recovery_days * np.log(d[newly]), 1.0)
        cum += int(newly.sum())
        hist["cumulative"].append(cum)
        hist["infectious"].append(int((state == I).sum()))
    return {k: np.asarray(v) for k, v in hist.items()}
