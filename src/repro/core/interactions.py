"""Day-level interaction pass orchestration (Algorithm 2, middle loop).

Bridges the Population's static week structure and the interaction kernels:
stacks the 7 day-of-week visit arrays + block schedules into fixed-shape
device arrays (so one jitted day step serves the whole run, selected by
``day % 7``), gathers per-visit person values, runs a kernel backend, and
segment-sums exposure back to people — the single-device equivalent of the
visit-message / exposure-message exchanges (the distributed version routes
the same values through core/exchange.py instead of gathers).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import population as pop_lib
from repro.kernels.interactions import ops as iops


@dataclasses.dataclass(frozen=True)
class WeekData:
    """Stacked (7, ...) device arrays for the weekly schedule."""

    pid: jnp.ndarray  # (7, V) int32, -1 padding
    loc: jnp.ndarray  # (7, V) int32
    start: jnp.ndarray  # (7, V) f32
    end: jnp.ndarray  # (7, V) f32
    row_idx: jnp.ndarray  # (7, NP) int32
    col_idx: jnp.ndarray  # (7, NP) int32
    row_start: jnp.ndarray  # (7, NP) int32
    pair_active: jnp.ndarray  # (7, NP) int32
    block_size: int
    num_blocks: int

    @property
    def visits_per_day(self) -> int:
        return self.pid.shape[1]


# Registered as a pytree (arrays as leaves, block geometry as aux data) so
# WeekData can cross jit/shard_map boundaries as an explicit argument — the
# scenario-ensemble sharding passes it with replicated specs instead of
# relying on closed-over constants.
jax.tree_util.register_pytree_node(
    WeekData,
    lambda w: (
        (w.pid, w.loc, w.start, w.end, w.row_idx, w.col_idx, w.row_start,
         w.pair_active),
        (w.block_size, w.num_blocks),
    ),
    lambda aux, ch: WeekData(*ch, block_size=aux[0], num_blocks=aux[1]),
)


def build_week_data(
    pop: pop_lib.Population, block_size: int, pack: bool = True
) -> WeekData:
    """Stack the weekly schedule for the kernels. ``pack`` applies the
    occupancy-aware run packing (population.py:pack_day_occupancy), which
    shrinks the block-pair schedule NP; layout is epidemiologically free
    (counter-based draws key on ids, not slots)."""
    if pack:
        week = [pop_lib.pack_day_occupancy(d, block_size) for d in pop.week]
        size = max(len(d) for d in week)
        week = [pop_lib.extend_packed(d, size) for d in week]
        extents = [d.extent for d in week]
    else:
        week = pop_lib.pad_week_uniform(pop.week, pad_multiple=block_size)
        extents = [d.num_real for d in week]
    scheds = [
        pop_lib.build_block_schedule(d.loc, e, block_size)
        for d, e in zip(week, extents)
    ]
    np_max = max(s.row_block.shape[0] for s in scheds)
    scheds = [
        pop_lib.build_block_schedule(d.loc, e, block_size, pad_to=np_max)
        for d, e in zip(week, extents)
    ]

    def stack(getter, dtype):
        return jnp.asarray(np.stack([getter(x) for x in zip(week, scheds)]), dtype)

    return WeekData(
        pid=stack(lambda x: x[0].person, jnp.int32),
        loc=stack(lambda x: x[0].loc, jnp.int32),
        start=stack(lambda x: x[0].start, jnp.float32),
        end=stack(lambda x: x[0].end, jnp.float32),
        row_idx=stack(lambda x: x[1].row_block, jnp.int32),
        col_idx=stack(lambda x: x[1].col_block, jnp.int32),
        row_start=stack(lambda x: x[1].row_start.astype(np.int32), jnp.int32),
        pair_active=stack(lambda x: x[1].pair_active.astype(np.int32), jnp.int32),
        block_size=block_size,
        num_blocks=len(week[0]) // block_size,
    )


def day_exposure(
    week: WeekData,
    dow,  # scalar int day-of-week
    num_people: int,
    person_sus_val,  # (P,) sigma(X)*beta_sigma, already intervention-scaled
    person_inf_val,  # (P,) iota(X)*beta_iota
    contact_prob,  # (L,) per-location p
    visit_ok,  # (P,) bool — person-level intervention visit mask
    loc_open,  # (L,) bool — location-level intervention mask
    tau,  # scalar transmissibility
    seed,
    contact_day,  # day index for the contact hash (absolute day, or day%7
    #               for the EpiHiper-style static-network baseline)
    backend: str = "jnp",
):
    """Returns (per-person propensity A (P,), total sus-inf contacts)."""
    take = lambda a: jax.lax.dynamic_index_in_dim(a, dow, 0, keepdims=False)
    pid, loc = take(week.pid), take(week.loc)
    start, end = take(week.start), take(week.end)
    row_idx, col_idx = take(week.row_idx), take(week.col_idx)
    row_start, pair_active = take(week.row_start), take(week.pair_active)

    safe_pid = jnp.maximum(pid, 0)
    active = (pid >= 0) & visit_ok[safe_pid] & loc_open[loc]
    eff_pid = jnp.where(active, pid, -1)
    sus_v = person_sus_val[safe_pid] * active
    inf_v = person_inf_val[safe_pid] * active
    p_v = contact_prob[loc]

    col_inf = iops.col_has_infectious(inf_v, eff_pid, week.num_blocks, week.block_size)
    row_sus = iops.row_has_susceptible(sus_v, eff_pid, week.num_blocks, week.block_size)
    meta = jnp.stack(
        [jnp.asarray(seed, jnp.uint32), jnp.asarray(contact_day, jnp.uint32)]
    )
    acc, cnt = iops.interactions_auto(
        eff_pid, loc, start, end, p_v, sus_v, inf_v,
        row_idx, col_idx, row_start, pair_active, col_inf, row_sus, meta,
        block_size=week.block_size, backend=backend,
    )
    # Exposure combine: per-person total propensity (Eq. 3), times tau.
    A = jax.ops.segment_sum(
        jnp.where(active, acc, 0.0), safe_pid, num_segments=num_people
    ) * jnp.asarray(tau, jnp.float32)  # asarray: tau may be a traced scalar
    return A, cnt.sum()
