"""The single-device day loop (Algorithm 2): reference semantics.

Single-program, fixed-shape formulation of the paper's parallel control
flow: one jitted ``day_step`` handles any day (the weekly schedule is
stacked on a leading day-of-week axis), and a whole run is a ``lax.scan``
over days. Distribution over a device mesh is in
:mod:`repro.core.simulator_dist`; this module is the single-device
reference (bitwise identical by construction — all stochastic draws are
counter-based, see core/rng.py).

Execution lives in :mod:`repro.engine` — one topology-parameterized scan
serving every layout (``EngineCore.single(...).run1(...)`` is the
single-scenario front door; ``repro.api.run()`` the spec-driven one). The
pure functions here (``day_step``, ``run_scan``, ``phase_*``) remain the
*reference semantics* the engine core is pinned against bitwise
(tests/test_engine.py), plus :func:`run_eager`, the per-phase-timed
day-at-a-time driver benchmarks use.

The day step is factored into pure functions of ``(static, week,
contact_prob, params, state)``:

  * ``SimStatic`` — trace-time structure (shapes, kernel backend, the
    intervention slot layout). Identical across a scenario ensemble.
  * ``SimParams`` — every scenario-varying numeric (seed, transmissibility,
    disease tables, per-person betas, intervention thresholds/masks,
    outbreak-seeding knobs) as device arrays. Because *values* live in this
    pytree rather than in closed-over Python attributes, ``day_step`` is
    vmappable over a leading batch axis — the engine core runs B scenarios
    in one ``lax.scan`` by stacking ``SimParams``/``SimState`` and
    vmapping, exactly the way the weekly schedule is stacked on a
    day-of-week axis here.

Phases per day (matching the paper's phase breakdown, Fig 7):
  1. *visits*    — intervention masks + per-visit person-value gather
                   (distributed: the visit-message all_to_all),
  2. *interact*  — block-scheduled interaction kernel + exposure combine
                   (distributed: exposure all_to_all),
  3. *update*    — infection sampling + FSA update + trigger evaluation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import disease as disease_lib
from repro.core import interactions as inter_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import transmission as tx_lib


# History keys every engine's day step emits, in emission order. The
# distributed engine and the api facade key their stat pytrees on this.
# "edges" is the traversed-edge count (the TEPS numerator): numerically
# equal to "contacts", but measured *inside* the Pallas kernel on the
# pallas-compact backend and derived host-side everywhere else — keeping
# both makes the kernel counter a cross-checked quantity.
STAT_KEYS = ("day", "new_infections", "cumulative", "infectious",
             "susceptible", "contacts", "edges",
             # Per-agent intervention telemetry (PR 7): constant zero when
             # no TestTraceIsolate slot exists (the reference path below
             # emits the zeros; the unified engine computes them).
             "tests_used", "isolated", "traced")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    day: jnp.ndarray  # scalar int32
    health: jnp.ndarray  # (P,) int32 FSA state
    dwell: jnp.ndarray  # (P,) f32 days left in state
    cumulative: jnp.ndarray  # scalar int32 — infections so far (incl. seeds)
    iv_active: jnp.ndarray  # (K,) bool
    vaccinated: jnp.ndarray  # (P,) bool
    # --- persistent per-agent intervention state (PR 7) -----------------
    tested: jnp.ndarray  # (P,) bool — ever consumed a test
    traced: jnp.ndarray  # (P,) bool — ever traced as a contact of a positive
    isolated_until: jnp.ndarray  # (P,) int32 — isolation active while day <


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimParams:
    """All scenario-varying numerics of a run, as device arrays.

    One scenario is a pytree of scalars/tables; a B-scenario ensemble is
    the same pytree with every leaf stacked on a leading batch axis
    (see :func:`repro.engine.core.stack_params`).
    """

    seed: jnp.ndarray  # () uint32 — Monte Carlo replicate stream
    tau_eff: jnp.ndarray  # () f32 — tau * time_unit (Eq. 2 prefactor)
    sus_table: jnp.ndarray  # (S,) f32 sigma(X)
    inf_table: jnp.ndarray  # (S,) f32 iota(X)
    sym_table: jnp.ndarray  # (S,) f32 — symptomatic states (test priority)
    cum_trans: jnp.ndarray  # (S, S) f32 cumulative transition rows
    dwell_mean: jnp.ndarray  # (S,) f32
    entry_state: jnp.ndarray  # () int32 — state entered on infection
    beta_sus: jnp.ndarray  # (P,) f32 person beta_sigma
    beta_inf: jnp.ndarray  # (P,) f32 person beta_iota
    seed_per_day: jnp.ndarray  # () int32 outbreak seeding intensity
    seed_days: jnp.ndarray  # () int32 outbreak seeding duration
    static_network: jnp.ndarray  # () bool — EpiHiper-style fixed weekly net
    iv: iv_lib.IvParams  # stacked intervention numerics


@dataclasses.dataclass(frozen=True)
class SimStatic:
    """Trace-time structure shared by every scenario in a batch."""

    num_people: int
    num_locations: int
    iv_slots: tuple  # tuple[iv_lib.IvSlotStatic, ...]
    backend: str = "jnp"


def build_params(
    pop: pop_lib.Population,
    disease: disease_lib.DiseaseModel,
    tm: tx_lib.TransmissionModel,
    interventions: Sequence[iv_lib.Intervention],
    seed: int,
    *,
    seed_per_day: int = 10,
    seed_days: int = 7,
    static_network: bool = False,
    iv_enabled: Sequence[bool] = (),
) -> tuple[tuple, tuple, SimParams]:
    """Compile one scenario's configs into
    (classic iv slot structure, per-agent slot structure, SimParams).

    ``iv_enabled`` (empty = all on) disables intervention slots without
    changing the slot structure — the mechanism scenario ensembles use to
    share one trace-time layout across design cells. It is positional over
    the *original* mixed intervention list; entries are routed to the
    matching family here.
    """
    iv_slots, pa_slots, iv_params = iv_lib.compile_iv_params(
        interventions, pop, seed
    )
    if len(iv_enabled):
        assert len(iv_enabled) == len(iv_slots) + len(pa_slots), \
            "iv_enabled/slot mismatch"
        en = np.asarray(iv_enabled, np.bool_)
        is_pa = np.asarray(
            [isinstance(iv, iv_lib.TestTraceIsolate) for iv in interventions],
            np.bool_,
        )
        iv_params = dataclasses.replace(
            iv_params,
            enabled=jnp.asarray(en[~is_pa]),
            pa_enabled=jnp.asarray(en[is_pa]),
        )
    params = SimParams(
        seed=jnp.asarray(np.uint32(seed & 0xFFFFFFFF)),
        tau_eff=jnp.asarray(np.float32(tm.tau * tm.time_unit)),
        sus_table=jnp.asarray(disease.susceptibility),
        inf_table=jnp.asarray(disease.infectivity),
        sym_table=jnp.asarray(disease.sym_table),
        cum_trans=jnp.asarray(disease.cum_trans),
        dwell_mean=jnp.asarray(disease.dwell_mean_days),
        entry_state=jnp.asarray(disease.entry_state, jnp.int32),
        beta_sus=jnp.asarray(pop.beta_sus, jnp.float32),
        beta_inf=jnp.asarray(pop.beta_inf, jnp.float32),
        seed_per_day=jnp.asarray(seed_per_day, jnp.int32),
        seed_days=jnp.asarray(seed_days, jnp.int32),
        static_network=jnp.asarray(static_network, bool),
        iv=iv_params,
    )
    return iv_slots, pa_slots, params


# --------------------------------------------------------------------------
# Pure per-day phases (vmappable over a leading batch axis of params/state)
# --------------------------------------------------------------------------


def phase_visits(static: SimStatic, params: SimParams, state: SimState):
    """Phase 1: intervention masks + per-person epidemiological values."""
    visit_ok, loc_open, sus_mult, inf_mult, vaccinated = iv_lib.apply_iv_params(
        static.iv_slots,
        params.iv,
        state.iv_active,
        state.vaccinated,
        static.num_people,
        static.num_locations,
    )
    person_sus = params.sus_table[state.health] * params.beta_sus * sus_mult
    person_inf = params.inf_table[state.health] * params.beta_inf * inf_mult
    return visit_ok, loc_open, person_sus, person_inf, vaccinated


def phase_interact(
    static, week, contact_prob, params, state, visit_ok, loc_open,
    person_sus, person_inf,
):
    """Phase 2: block-scheduled interactions + exposure combine."""
    dow = state.day % pop_lib.DAYS_PER_WEEK
    contact_day = jnp.where(
        params.static_network, dow, state.day
    )  # static net: draws keyed by day-of-week => identical every week
    return inter_lib.day_exposure(
        week,
        dow,
        static.num_people,
        person_sus,
        person_inf,
        contact_prob,
        visit_ok,
        loc_open,
        params.tau_eff,
        params.seed,
        contact_day,
        backend=static.backend,
    )


def phase_update(static, params, state, A, contacts, vaccinated):
    """Phase 3: infection sampling, seeding, FSA update, triggers."""
    infected = tx_lib.sample_infections(A, params.seed, state.day)

    def with_seeding(h_d):
        h, d = h_d
        pid = jnp.arange(static.num_people, dtype=jnp.uint32)
        u = rng.uniform(params.seed, rng.SEED_CHOICE, state.day, pid)
        sus = params.sus_table[h] > 0.0
        u = jnp.where(sus, u, 2.0)
        k = jnp.minimum(params.seed_per_day, static.num_people) - 1
        thresh = jnp.sort(u)[jnp.maximum(k, 0)]
        return (u <= thresh) & sus & (params.seed_per_day > 0)

    seeded = jax.lax.cond(
        state.day < params.seed_days,
        with_seeding,
        lambda _: jnp.zeros((static.num_people,), bool),
        (state.health, state.dwell),
    )
    can_infect = params.sus_table[state.health] > 0.0
    new_mask = (infected | seeded) & can_infect
    health, dwell = disease_lib.update_health_tables(
        params.cum_trans,
        params.dwell_mean,
        params.sus_table,
        params.entry_state,
        state.health,
        state.dwell,
        new_mask,
        params.seed,
        state.day,
    )
    new_count = new_mask.sum().astype(jnp.int32)
    cumulative = state.cumulative + new_count
    infectious = (params.inf_table[health] > 0.0).sum().astype(jnp.int32)
    cdtype = (
        jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32
    )
    stats = {
        "day": state.day,
        "new_infections": new_count,
        "cumulative": cumulative,
        "infectious": infectious,
        "susceptible": (params.sus_table[health] > 0.0).sum().astype(jnp.int32),
        "contacts": contacts.astype(cdtype),
        # Host-side traversed edges; the unified engine substitutes the
        # in-kernel counter on the pallas-compact backend.
        "edges": contacts.astype(cdtype),
        # The reference path carries no per-agent interventions; the stats
        # are constant zeros (the engine must match them bitwise whenever
        # no TestTraceIsolate slot is configured).
        "tests_used": jnp.zeros((), jnp.int32),
        "isolated": jnp.zeros((), jnp.int32),
        "traced": jnp.zeros((), jnp.int32),
    }
    iv_active = iv_lib.evaluate_iv_triggers(
        static.iv_slots, params.iv, state.day, stats, state.iv_active
    )
    new_state = SimState(
        day=state.day + 1,
        health=health,
        dwell=dwell,
        cumulative=cumulative,
        iv_active=iv_active,
        vaccinated=vaccinated,
        tested=state.tested,
        traced=state.traced,
        isolated_until=state.isolated_until,
    )
    return new_state, stats


def day_step(static, week, contact_prob, params: SimParams, state: SimState):
    """One simulated day; pure in (params, state) given static structure."""
    visit_ok, loc_open, person_sus, person_inf, vaccinated = phase_visits(
        static, params, state
    )
    A, contacts = phase_interact(
        static, week, contact_prob, params, state,
        visit_ok, loc_open, person_sus, person_inf,
    )
    return phase_update(static, params, state, A, contacts, vaccinated)


def run_scan(static, week, contact_prob, params, state, days: int):
    """A whole run as one lax.scan over :func:`day_step`."""

    def body(s, _):
        return day_step(static, week, contact_prob, params, s)

    return jax.lax.scan(body, state, None, length=days)


def init_state(
    disease: disease_lib.DiseaseModel, num_people: int, num_iv_slots: int
) -> SimState:
    health, dwell = disease_lib.initial_health(disease, num_people)
    return SimState(
        day=jnp.asarray(0, jnp.int32),
        health=health,
        dwell=dwell,
        cumulative=jnp.asarray(0, jnp.int32),
        iv_active=jnp.zeros((num_iv_slots,), bool),
        vaccinated=jnp.zeros((num_people,), bool),
        tested=jnp.zeros((num_people,), bool),
        traced=jnp.zeros((num_people,), bool),
        isolated_until=jnp.zeros((num_people,), jnp.int32),
    )


def legacy_parts(core):
    """(static, week, contact_prob, params) for the legacy pure functions,
    extracted from a B=1 ``layout="local"`` EngineCore.

    This is the bridge between the unified engine (which owns population
    compilation) and the reference semantics in this module: parity tests
    and :func:`run_eager` drive ``day_step``/``phase_*`` with exactly the
    arrays the engine scans over."""
    from repro.engine.core import index_params  # cycle-free at call time

    assert core.layout == "local" and core.num_real == 1, \
        "legacy_parts() needs a B=1 local EngineCore"
    params = index_params(core.params, 0)
    static = SimStatic(
        num_people=core.pop.num_people,
        num_locations=core.pop.num_locations,
        iv_slots=core.iv_slots,
        backend=core.backend,
    )
    return static, core.week_data, jnp.asarray(core.pop.contact_prob), params


def run_eager(core, days: int, state: Optional[SimState] = None):
    """Day-at-a-time loop with per-phase wall times (benchmarks Fig 4/7).

    ``core`` is a B=1 ``layout="local"`` EngineCore. Phases are timed by
    running each phase's jitted sub-program to completion; numbers include
    dispatch overhead, which is the honest CPU-side analog of the paper's
    per-phase projections. Trajectories are bitwise-identical to
    ``core.run1`` (same per-day arithmetic, scan vs Python loop)."""
    static, week, contact_prob, params = legacy_parts(core)
    state = state if state is not None else core.init_state1()
    p1 = jax.jit(lambda st: phase_visits(static, params, st))
    p2 = jax.jit(
        lambda st, ok, op, ps, pi: phase_interact(
            static, week, contact_prob, params, st, ok, op, ps, pi,
        )
    )
    p3 = jax.jit(
        lambda st, A, c, v: phase_update(static, params, st, A, c, v)
    )
    hist: dict[str, list] = {}
    times = {"visits": [], "interact": [], "update": []}
    for _ in range(days):
        t0 = time.perf_counter()
        visit_ok, loc_open, ps, pi, vacc = jax.block_until_ready(p1(state))
        t1 = time.perf_counter()
        A, contacts = jax.block_until_ready(p2(state, visit_ok, loc_open, ps, pi))
        t2 = time.perf_counter()
        state, stats = jax.block_until_ready(p3(state, A, contacts, vacc))
        t3 = time.perf_counter()
        times["visits"].append(t1 - t0)
        times["interact"].append(t2 - t1)
        times["update"].append(t3 - t2)
        for k, v in jax.device_get(stats).items():
            hist.setdefault(k, []).append(v)
    return state, {k: np.asarray(v) for k, v in hist.items()}, {
        k: np.asarray(v) for k, v in times.items()
    }


def attack_rate(hist) -> float:
    return float(hist["cumulative"][-1])
