"""EpidemicSimulator — the top-level day loop (Algorithm 2).

Single-program, fixed-shape formulation of the paper's parallel control
flow: one jitted ``day_step`` handles any day (the weekly schedule is
stacked on a leading day-of-week axis), and a whole run is a ``lax.scan``
over days. Distribution over a device mesh is in
:mod:`repro.core.simulator_dist`; this module is the single-device
reference (bitwise identical by construction — all stochastic draws are
counter-based, see core/rng.py).

Execution now lives in :mod:`repro.engine` — one topology-parameterized
scan serving every layout — and ``EpidemicSimulator`` is a deprecated
facade over it. The pure functions here (``day_step``, ``run_scan``,
``phase_*``) remain the *reference semantics* the engine core is pinned
against bitwise (tests/test_engine.py).

The day step is factored into pure functions of ``(static, week,
contact_prob, params, state)``:

  * ``SimStatic`` — trace-time structure (shapes, kernel backend, the
    intervention slot layout). Identical across a scenario ensemble.
  * ``SimParams`` — every scenario-varying numeric (seed, transmissibility,
    disease tables, per-person betas, intervention thresholds/masks,
    outbreak-seeding knobs) as device arrays. Because *values* live in this
    pytree rather than in closed-over Python attributes, ``day_step`` is
    vmappable over a leading batch axis — the scenario-ensemble engine
    (:mod:`repro.sweep`) runs B scenarios in one ``lax.scan`` by stacking
    ``SimParams``/``SimState`` and vmapping, exactly the way the weekly
    schedule is stacked on a day-of-week axis here.

Phases per day (matching the paper's phase breakdown, Fig 7):
  1. *visits*    — intervention masks + per-visit person-value gather
                   (distributed: the visit-message all_to_all),
  2. *interact*  — block-scheduled interaction kernel + exposure combine
                   (distributed: exposure all_to_all),
  3. *update*    — infection sampling + FSA update + trigger evaluation.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import disease as disease_lib
from repro.core import interactions as inter_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import transmission as tx_lib


# History keys every engine's day step emits, in emission order. The
# distributed engine and the api facade key their stat pytrees on this.
STAT_KEYS = ("day", "new_infections", "cumulative", "infectious",
             "susceptible", "contacts")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    day: jnp.ndarray  # scalar int32
    health: jnp.ndarray  # (P,) int32 FSA state
    dwell: jnp.ndarray  # (P,) f32 days left in state
    cumulative: jnp.ndarray  # scalar int32 — infections so far (incl. seeds)
    iv_active: jnp.ndarray  # (K,) bool
    vaccinated: jnp.ndarray  # (P,) bool


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimParams:
    """All scenario-varying numerics of a run, as device arrays.

    One scenario is a pytree of scalars/tables; a B-scenario ensemble is
    the same pytree with every leaf stacked on a leading batch axis
    (see :func:`repro.sweep.engine.stack_params`).
    """

    seed: jnp.ndarray  # () uint32 — Monte Carlo replicate stream
    tau_eff: jnp.ndarray  # () f32 — tau * time_unit (Eq. 2 prefactor)
    sus_table: jnp.ndarray  # (S,) f32 sigma(X)
    inf_table: jnp.ndarray  # (S,) f32 iota(X)
    cum_trans: jnp.ndarray  # (S, S) f32 cumulative transition rows
    dwell_mean: jnp.ndarray  # (S,) f32
    entry_state: jnp.ndarray  # () int32 — state entered on infection
    beta_sus: jnp.ndarray  # (P,) f32 person beta_sigma
    beta_inf: jnp.ndarray  # (P,) f32 person beta_iota
    seed_per_day: jnp.ndarray  # () int32 outbreak seeding intensity
    seed_days: jnp.ndarray  # () int32 outbreak seeding duration
    static_network: jnp.ndarray  # () bool — EpiHiper-style fixed weekly net
    iv: iv_lib.IvParams  # stacked intervention numerics


@dataclasses.dataclass(frozen=True)
class SimStatic:
    """Trace-time structure shared by every scenario in a batch."""

    num_people: int
    num_locations: int
    iv_slots: tuple  # tuple[iv_lib.IvSlotStatic, ...]
    backend: str = "jnp"


def build_params(
    pop: pop_lib.Population,
    disease: disease_lib.DiseaseModel,
    tm: tx_lib.TransmissionModel,
    interventions: Sequence[iv_lib.Intervention],
    seed: int,
    *,
    seed_per_day: int = 10,
    seed_days: int = 7,
    static_network: bool = False,
    iv_enabled: Sequence[bool] = (),
) -> tuple[tuple, SimParams]:
    """Compile one scenario's configs into (iv slot structure, SimParams).

    ``iv_enabled`` (empty = all on) disables intervention slots without
    changing the slot structure — the mechanism scenario ensembles use to
    share one trace-time layout across design cells.
    """
    iv_slots, iv_params = iv_lib.compile_iv_params(interventions, pop, seed)
    if len(iv_enabled):
        assert len(iv_enabled) == len(iv_slots), "iv_enabled/slot mismatch"
        iv_params = dataclasses.replace(
            iv_params, enabled=jnp.asarray(np.asarray(iv_enabled, np.bool_))
        )
    params = SimParams(
        seed=jnp.asarray(np.uint32(seed & 0xFFFFFFFF)),
        tau_eff=jnp.asarray(np.float32(tm.tau * tm.time_unit)),
        sus_table=jnp.asarray(disease.susceptibility),
        inf_table=jnp.asarray(disease.infectivity),
        cum_trans=jnp.asarray(disease.cum_trans),
        dwell_mean=jnp.asarray(disease.dwell_mean_days),
        entry_state=jnp.asarray(disease.entry_state, jnp.int32),
        beta_sus=jnp.asarray(pop.beta_sus, jnp.float32),
        beta_inf=jnp.asarray(pop.beta_inf, jnp.float32),
        seed_per_day=jnp.asarray(seed_per_day, jnp.int32),
        seed_days=jnp.asarray(seed_days, jnp.int32),
        static_network=jnp.asarray(static_network, bool),
        iv=iv_params,
    )
    return iv_slots, params


# --------------------------------------------------------------------------
# Pure per-day phases (vmappable over a leading batch axis of params/state)
# --------------------------------------------------------------------------


def phase_visits(static: SimStatic, params: SimParams, state: SimState):
    """Phase 1: intervention masks + per-person epidemiological values."""
    visit_ok, loc_open, sus_mult, inf_mult, vaccinated = iv_lib.apply_iv_params(
        static.iv_slots,
        params.iv,
        state.iv_active,
        state.vaccinated,
        static.num_people,
        static.num_locations,
    )
    person_sus = params.sus_table[state.health] * params.beta_sus * sus_mult
    person_inf = params.inf_table[state.health] * params.beta_inf * inf_mult
    return visit_ok, loc_open, person_sus, person_inf, vaccinated


def phase_interact(
    static, week, contact_prob, params, state, visit_ok, loc_open,
    person_sus, person_inf,
):
    """Phase 2: block-scheduled interactions + exposure combine."""
    dow = state.day % pop_lib.DAYS_PER_WEEK
    contact_day = jnp.where(
        params.static_network, dow, state.day
    )  # static net: draws keyed by day-of-week => identical every week
    return inter_lib.day_exposure(
        week,
        dow,
        static.num_people,
        person_sus,
        person_inf,
        contact_prob,
        visit_ok,
        loc_open,
        params.tau_eff,
        params.seed,
        contact_day,
        backend=static.backend,
    )


def phase_update(static, params, state, A, contacts, vaccinated):
    """Phase 3: infection sampling, seeding, FSA update, triggers."""
    infected = tx_lib.sample_infections(A, params.seed, state.day)

    def with_seeding(h_d):
        h, d = h_d
        pid = jnp.arange(static.num_people, dtype=jnp.uint32)
        u = rng.uniform(params.seed, rng.SEED_CHOICE, state.day, pid)
        sus = params.sus_table[h] > 0.0
        u = jnp.where(sus, u, 2.0)
        k = jnp.minimum(params.seed_per_day, static.num_people) - 1
        thresh = jnp.sort(u)[jnp.maximum(k, 0)]
        return (u <= thresh) & sus & (params.seed_per_day > 0)

    seeded = jax.lax.cond(
        state.day < params.seed_days,
        with_seeding,
        lambda _: jnp.zeros((static.num_people,), bool),
        (state.health, state.dwell),
    )
    can_infect = params.sus_table[state.health] > 0.0
    new_mask = (infected | seeded) & can_infect
    health, dwell = disease_lib.update_health_tables(
        params.cum_trans,
        params.dwell_mean,
        params.sus_table,
        params.entry_state,
        state.health,
        state.dwell,
        new_mask,
        params.seed,
        state.day,
    )
    new_count = new_mask.sum().astype(jnp.int32)
    cumulative = state.cumulative + new_count
    infectious = (params.inf_table[health] > 0.0).sum().astype(jnp.int32)
    stats = {
        "day": state.day,
        "new_infections": new_count,
        "cumulative": cumulative,
        "infectious": infectious,
        "susceptible": (params.sus_table[health] > 0.0).sum().astype(jnp.int32),
        "contacts": contacts.astype(jnp.int64)
        if jax.config.read("jax_enable_x64")
        else contacts.astype(jnp.int32),
    }
    iv_active = iv_lib.evaluate_iv_triggers(
        static.iv_slots, params.iv, state.day, stats, state.iv_active
    )
    new_state = SimState(
        day=state.day + 1,
        health=health,
        dwell=dwell,
        cumulative=cumulative,
        iv_active=iv_active,
        vaccinated=vaccinated,
    )
    return new_state, stats


def day_step(static, week, contact_prob, params: SimParams, state: SimState):
    """One simulated day; pure in (params, state) given static structure."""
    visit_ok, loc_open, person_sus, person_inf, vaccinated = phase_visits(
        static, params, state
    )
    A, contacts = phase_interact(
        static, week, contact_prob, params, state,
        visit_ok, loc_open, person_sus, person_inf,
    )
    return phase_update(static, params, state, A, contacts, vaccinated)


def run_scan(static, week, contact_prob, params, state, days: int):
    """A whole run as one lax.scan over :func:`day_step`."""

    def body(s, _):
        return day_step(static, week, contact_prob, params, s)

    return jax.lax.scan(body, state, None, length=days)


def init_state(
    disease: disease_lib.DiseaseModel, num_people: int, num_iv_slots: int
) -> SimState:
    health, dwell = disease_lib.initial_health(disease, num_people)
    return SimState(
        day=jnp.asarray(0, jnp.int32),
        health=health,
        dwell=dwell,
        cumulative=jnp.asarray(0, jnp.int32),
        iv_active=jnp.zeros((num_iv_slots,), bool),
        vaccinated=jnp.zeros((num_people,), bool),
    )


@dataclasses.dataclass
class EpidemicSimulator:
    """Deprecated facade: ``repro.engine.EngineCore(layout="local")`` with
    a batch of one. The pure functions above (``day_step``, ``run_scan``)
    remain the single-device *reference semantics* — the engine core is
    tested bitwise against them (tests/test_engine.py) — but execution
    dispatches through the unified topology-parameterized scan."""

    pop: pop_lib.Population
    disease: disease_lib.DiseaseModel
    tm: tx_lib.TransmissionModel = dataclasses.field(
        default_factory=tx_lib.TransmissionModel
    )
    interventions: Sequence[iv_lib.Intervention] = ()
    seed: int = 0
    backend: str = "jnp"  # interaction backend: jnp | scan | compact | pallas
    block_size: int = 128
    pack_visits: bool = True  # occupancy-aware schedule packing (smaller NP)
    static_network: bool = False  # EpiHiper-style fixed weekly contact net
    seed_per_day: int = 10
    seed_days: int = 7
    iv_enabled: Sequence[bool] = ()  # per-slot enable mask; () = all on

    def __post_init__(self):
        warnings.warn(
            "EpidemicSimulator is a deprecated facade; use "
            "repro.engine.EngineCore(layout='local') or repro.api.run()",
            DeprecationWarning, stacklevel=2,
        )
        from repro.configs.sweep import Scenario
        from repro.engine import EngineCore, index_params

        self._core = EngineCore(
            self.pop,
            [Scenario(
                name="single", disease=self.disease, tm=self.tm,
                interventions=tuple(self.interventions),
                iv_enabled=tuple(self.iv_enabled), seed=self.seed,
                seed_per_day=self.seed_per_day, seed_days=self.seed_days,
                static_network=self.static_network,
            )],
            layout="local", backend=self.backend,
            block_size=self.block_size, pack_visits=self.pack_visits,
        )
        self.week = self._core.week_data
        self.iv_slots = self._core.iv_slots
        self.params = index_params(self._core.params, 0)
        self.static = SimStatic(
            num_people=self.pop.num_people,
            num_locations=self.pop.num_locations,
            iv_slots=self.iv_slots,
            backend=self.backend,
        )
        self.contact_prob = jnp.asarray(self.pop.contact_prob)
        self.sus_table = self.params.sus_table
        self.inf_table = self.params.inf_table
        # Reference single-day step over the legacy pure functions (used by
        # run_eager timing and external day-at-a-time callers).
        self._day_step = jax.jit(
            lambda st: day_step(
                self.static, self.week, self.contact_prob, self.params, st
            )
        )

    # ------------------------------------------------------------------
    def init_state(self) -> SimState:
        return init_state(self.disease, self.pop.num_people, len(self.iv_slots))

    # ------------------------------------------------------------------
    def run(self, days: int, state: Optional[SimState] = None,
            params: Optional[SimParams] = None):
        """Whole run as one jitted scan (through the engine core). Returns
        (final state, history dict of (days,) numpy arrays).

        ``params`` substitutes another scenario's :class:`SimParams` (same
        trace-time structure) without recompiling — params is a traced
        argument of the compiled scan, so one program serves a scenario
        batch run sequentially."""
        state = state if state is not None else self.init_state()
        params = params if params is not None else self.params
        add_b = lambda t: jax.tree.map(lambda x: x[None], t)
        final, _, hist, _ = self._core.run_days(
            days, params=add_b(params), state=add_b(state)
        )
        final = jax.tree.map(lambda x: x[0], final)
        return final, {k: v[:, 0] for k, v in hist.items()}

    def run_eager(self, days: int, state: Optional[SimState] = None):
        """Day-at-a-time loop with per-phase wall times (benchmarks Fig 4/7).

        Phases are timed by running each phase's jitted sub-program to
        completion; numbers include dispatch overhead, which is the honest
        CPU-side analog of the paper's per-phase projections."""
        state = state if state is not None else self.init_state()
        p1 = jax.jit(lambda st: phase_visits(self.static, self.params, st))
        p2 = jax.jit(
            lambda st, ok, op, ps, pi: phase_interact(
                self.static, self.week, self.contact_prob, self.params, st,
                ok, op, ps, pi,
            )
        )
        p3 = jax.jit(
            lambda st, A, c, v: phase_update(self.static, self.params, st, A, c, v)
        )
        hist: dict[str, list] = {}
        times = {"visits": [], "interact": [], "update": []}
        for _ in range(days):
            t0 = time.perf_counter()
            visit_ok, loc_open, ps, pi, vacc = jax.block_until_ready(p1(state))
            t1 = time.perf_counter()
            A, contacts = jax.block_until_ready(p2(state, visit_ok, loc_open, ps, pi))
            t2 = time.perf_counter()
            state, stats = jax.block_until_ready(p3(state, A, contacts, vacc))
            t3 = time.perf_counter()
            times["visits"].append(t1 - t0)
            times["interact"].append(t2 - t1)
            times["update"].append(t3 - t2)
            for k, v in jax.device_get(stats).items():
                hist.setdefault(k, []).append(v)
        return state, {k: np.asarray(v) for k, v in hist.items()}, {
            k: np.asarray(v) for k, v in times.items()
        }

    # ------------------------------------------------------------------
    def checkpoint_payload(self, state: SimState) -> dict[str, Any]:
        """Everything needed for exact restart (day-granular)."""
        return {
            "day": state.day,
            "health": state.health,
            "dwell": state.dwell,
            "cumulative": state.cumulative,
            "iv_active": state.iv_active,
            "vaccinated": state.vaccinated,
            "seed": np.asarray(self.seed),
        }

    def restore_state(self, payload: dict[str, Any]) -> SimState:
        assert int(payload["seed"]) == self.seed, "seed mismatch on restore"
        return SimState(
            day=jnp.asarray(payload["day"], jnp.int32),
            health=jnp.asarray(payload["health"], jnp.int32),
            dwell=jnp.asarray(payload["dwell"], jnp.float32),
            cumulative=jnp.asarray(payload["cumulative"], jnp.int32),
            iv_active=jnp.asarray(payload["iv_active"], bool),
            vaccinated=jnp.asarray(payload["vaccinated"], bool),
        )


def attack_rate(hist) -> float:
    return float(hist["cumulative"][-1])
