"""EpidemicSimulator — the top-level day loop (Algorithm 2).

Single-program, fixed-shape formulation of the paper's parallel control
flow: one jitted ``day_step`` handles any day (the weekly schedule is
stacked on a leading day-of-week axis), and a whole run is a ``lax.scan``
over days. Distribution over a device mesh is in
:mod:`repro.core.simulator_dist`; this module is the single-device
reference (bitwise identical by construction — all stochastic draws are
counter-based, see core/rng.py).

Phases per day (matching the paper's phase breakdown, Fig 7):
  1. *visits*    — intervention masks + per-visit person-value gather
                   (distributed: the visit-message all_to_all),
  2. *interact*  — block-scheduled interaction kernel + exposure combine
                   (distributed: exposure all_to_all),
  3. *update*    — infection sampling + FSA update + trigger evaluation.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import disease as disease_lib
from repro.core import interactions as inter_lib
from repro.core import interventions as iv_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.core import transmission as tx_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    day: jnp.ndarray  # scalar int32
    health: jnp.ndarray  # (P,) int32 FSA state
    dwell: jnp.ndarray  # (P,) f32 days left in state
    cumulative: jnp.ndarray  # scalar int32 — infections so far (incl. seeds)
    iv_active: jnp.ndarray  # (K,) bool
    vaccinated: jnp.ndarray  # (P,) bool


@dataclasses.dataclass
class EpidemicSimulator:
    pop: pop_lib.Population
    disease: disease_lib.DiseaseModel
    tm: tx_lib.TransmissionModel = dataclasses.field(
        default_factory=tx_lib.TransmissionModel
    )
    interventions: Sequence[iv_lib.Intervention] = ()
    seed: int = 0
    backend: str = "jnp"  # interaction kernel backend: jnp | scan | pallas
    block_size: int = 128
    static_network: bool = False  # EpiHiper-style fixed weekly contact net
    seed_per_day: int = 10
    seed_days: int = 7

    def __post_init__(self):
        self.week = inter_lib.build_week_data(self.pop, self.block_size)
        self.compiled_ivs = iv_lib.compile_interventions(
            self.interventions, self.pop, self.seed
        )
        self.contact_prob = jnp.asarray(self.pop.contact_prob)
        self.base_beta_sus = jnp.asarray(self.pop.beta_sus)
        self.base_beta_inf = jnp.asarray(self.pop.beta_inf)
        self.sus_table = jnp.asarray(self.disease.susceptibility)
        self.inf_table = jnp.asarray(self.disease.infectivity)
        self._day_step = jax.jit(self._day_step_impl)
        self._run_scan = jax.jit(self._run_scan_impl, static_argnames=("days",))

    # ------------------------------------------------------------------
    def init_state(self) -> SimState:
        health, dwell = disease_lib.initial_health(self.disease, self.pop.num_people)
        return SimState(
            day=jnp.asarray(0, jnp.int32),
            health=health,
            dwell=dwell,
            cumulative=jnp.asarray(0, jnp.int32),
            iv_active=jnp.zeros((len(self.compiled_ivs),), bool),
            vaccinated=jnp.zeros((self.pop.num_people,), bool),
        )

    # ------------------------------------------------------------------
    def _phase_visits(self, state: SimState):
        """Phase 1: intervention masks + per-person epidemiological values."""
        visit_ok, loc_open, sus_mult, inf_mult, vaccinated = (
            iv_lib.apply_interventions(
                self.compiled_ivs,
                state.iv_active,
                state.vaccinated,
                self.pop.num_people,
                self.pop.num_locations,
            )
        )
        person_sus = self.sus_table[state.health] * self.base_beta_sus * sus_mult
        person_inf = self.inf_table[state.health] * self.base_beta_inf * inf_mult
        return visit_ok, loc_open, person_sus, person_inf, vaccinated

    def _phase_interact(self, state, visit_ok, loc_open, person_sus, person_inf):
        """Phase 2: block-scheduled interactions + exposure combine."""
        dow = state.day % pop_lib.DAYS_PER_WEEK
        contact_day = jnp.where(
            self.static_network, dow, state.day
        )  # static net: draws keyed by day-of-week => identical every week
        return inter_lib.day_exposure(
            self.week,
            dow,
            self.pop.num_people,
            person_sus,
            person_inf,
            self.contact_prob,
            visit_ok,
            loc_open,
            self.tm.tau * self.tm.time_unit,
            self.seed,
            contact_day,
            backend=self.backend,
        )

    def _phase_update(self, state: SimState, A, contacts, vaccinated):
        """Phase 3: infection sampling, seeding, FSA update, triggers."""
        infected = tx_lib.sample_infections(A, self.seed, state.day)

        def with_seeding(h_d):
            h, d = h_d
            pid = jnp.arange(self.pop.num_people, dtype=jnp.uint32)
            u = rng.uniform(self.seed, rng.SEED_CHOICE, state.day, pid)
            sus = self.sus_table[h] > 0.0
            u = jnp.where(sus, u, 2.0)
            k = jnp.minimum(self.seed_per_day, self.pop.num_people) - 1
            thresh = jnp.sort(u)[k]
            return (u <= thresh) & sus

        seeded = jax.lax.cond(
            state.day < self.seed_days,
            with_seeding,
            lambda _: jnp.zeros((self.pop.num_people,), bool),
            (state.health, state.dwell),
        )
        can_infect = self.sus_table[state.health] > 0.0
        new_mask = (infected | seeded) & can_infect
        health, dwell = disease_lib.update_health(
            self.disease, state.health, state.dwell, new_mask, self.seed, state.day
        )
        new_count = new_mask.sum().astype(jnp.int32)
        cumulative = state.cumulative + new_count
        infectious = (self.inf_table[health] > 0.0).sum().astype(jnp.int32)
        stats = {
            "day": state.day,
            "new_infections": new_count,
            "cumulative": cumulative,
            "infectious": infectious,
            "susceptible": (self.sus_table[health] > 0.0).sum().astype(jnp.int32),
            "contacts": contacts.astype(jnp.int64)
            if jax.config.read("jax_enable_x64")
            else contacts.astype(jnp.int32),
        }
        iv_active = iv_lib.evaluate_triggers(
            self.compiled_ivs, state.day, stats, state.iv_active
        )
        new_state = SimState(
            day=state.day + 1,
            health=health,
            dwell=dwell,
            cumulative=cumulative,
            iv_active=iv_active,
            vaccinated=vaccinated,
        )
        return new_state, stats

    def _day_step_impl(self, state: SimState):
        visit_ok, loc_open, person_sus, person_inf, vaccinated = self._phase_visits(
            state
        )
        A, contacts = self._phase_interact(
            state, visit_ok, loc_open, person_sus, person_inf
        )
        return self._phase_update(state, A, contacts, vaccinated)

    # ------------------------------------------------------------------
    def _run_scan_impl(self, state: SimState, *, days: int):
        def body(s, _):
            s2, stats = self._day_step_impl(s)
            return s2, stats

        return jax.lax.scan(body, state, None, length=days)

    def run(self, days: int, state: Optional[SimState] = None):
        """Whole run as one jitted scan. Returns (final state, history dict
        of (days,) numpy arrays)."""
        state = state if state is not None else self.init_state()
        final, hist = self._run_scan(state, days=days)
        return final, jax.device_get(hist)

    def run_eager(self, days: int, state: Optional[SimState] = None):
        """Day-at-a-time loop with per-phase wall times (benchmarks Fig 4/7).

        Phases are timed by running each phase's jitted sub-program to
        completion; numbers include dispatch overhead, which is the honest
        CPU-side analog of the paper's per-phase projections."""
        state = state if state is not None else self.init_state()
        p1 = jax.jit(self._phase_visits)
        p2 = jax.jit(self._phase_interact)
        p3 = jax.jit(self._phase_update)
        hist: dict[str, list] = {}
        times = {"visits": [], "interact": [], "update": []}
        for _ in range(days):
            t0 = time.perf_counter()
            visit_ok, loc_open, ps, pi, vacc = jax.block_until_ready(p1(state))
            t1 = time.perf_counter()
            A, contacts = jax.block_until_ready(p2(state, visit_ok, loc_open, ps, pi))
            t2 = time.perf_counter()
            state, stats = jax.block_until_ready(p3(state, A, contacts, vacc))
            t3 = time.perf_counter()
            times["visits"].append(t1 - t0)
            times["interact"].append(t2 - t1)
            times["update"].append(t3 - t2)
            for k, v in jax.device_get(stats).items():
                hist.setdefault(k, []).append(v)
        return state, {k: np.asarray(v) for k, v in hist.items()}, {
            k: np.asarray(v) for k, v in times.items()
        }

    # ------------------------------------------------------------------
    def checkpoint_payload(self, state: SimState) -> dict[str, Any]:
        """Everything needed for exact restart (day-granular)."""
        return {
            "day": state.day,
            "health": state.health,
            "dwell": state.dwell,
            "cumulative": state.cumulative,
            "iv_active": state.iv_active,
            "vaccinated": state.vaccinated,
            "seed": np.asarray(self.seed),
        }

    def restore_state(self, payload: dict[str, Any]) -> SimState:
        assert int(payload["seed"]) == self.seed, "seed mismatch on restore"
        return SimState(
            day=jnp.asarray(payload["day"], jnp.int32),
            health=jnp.asarray(payload["health"], jnp.int32),
            dwell=jnp.asarray(payload["dwell"], jnp.float32),
            cumulative=jnp.asarray(payload["cumulative"], jnp.int32),
            iv_active=jnp.asarray(payload["iv_active"], bool),
            vaccinated=jnp.asarray(payload["vaccinated"], bool),
        )


def attack_rate(hist) -> float:
    return float(hist["cumulative"][-1])
