"""Named disease + intervention presets — the vocabulary of
:class:`repro.api.ExperimentSpec`.

An experiment spec is *serializable* (JSON/TOML), so it references diseases
and intervention bundles by name rather than by Python object; this module
is the registry those names resolve against. The CLI drivers
(``launch/simulate.py`` / ``launch/sweep.py``) expose the same names, so a
flag-built run and a spec-built run mean the same thing by construction.

Historically these lived in ``launch/simulate.py``; they moved here so the
core API never imports argparse-bearing driver modules. The old import
path still works (re-exported there).
"""

from __future__ import annotations

from repro.core import disease as disease_lib
from repro.core import interventions as iv

DISEASES = {
    "covid": disease_lib.covid_model,
    "sir": disease_lib.sir_model,
    "seir": disease_lib.seir_model,
}

INTERVENTION_PRESETS = {
    "none": [],
    "school-closure": [iv.Intervention(
        "close-schools", iv.CaseThreshold(on=100), iv.LocTypeIs(2),
        iv.CloseLocations(),
    )],
    "vax-seniors": [iv.Intervention(
        "vaccinate-seniors", iv.DayRange(14), iv.AgeGroupIs(2),
        iv.Vaccinate(0.85),
    )],
    "lockdown": [iv.Intervention(
        "lockdown", iv.CaseThreshold(on=500, off=100),
        iv.RandomFraction(0.8, salt=3), iv.Isolate(),
    )],
    # Per-agent family (PR 7): capacity-limited daily testing with
    # symptomatic priority; positives isolate and (optionally) their
    # contacts are traced into the queue. Budgets are per-day absolute
    # counts — scale them to the population under study via sweeps.
    "tti": [iv.TestTraceIsolate(
        "tti", tests_per_day=100, isolation_days=10,
        trace=True, trace_isolation_days=14,
    )],
    "tti-no-trace": [iv.TestTraceIsolate(
        "test-isolate", tests_per_day=100, isolation_days=10, trace=False,
    )],
}
