"""Scenario-ensemble configs (the paper's *use case*: intervention studies).

A :class:`Scenario` names one fully-specified run — disease model,
transmission model, interventions, Monte Carlo seed, seeding schedule. A
:class:`ScenarioBatch` is an ordered collection of scenarios that the
engine core (:mod:`repro.engine`) executes in a *single* jitted
``lax.scan`` by stacking every scenario's ``SimParams`` on a leading batch
axis and vmapping the day step.

Structural constraint: every scenario in a batch must share trace-time
structure — the same disease FSA *shape* (number of states; the table
*values* may be perturbed freely) and the same intervention slot layout
(same ordered list of action/trigger kinds; per-scenario thresholds,
factors, selector draws, and enabled flags may differ). ``from_product``
guarantees this by building each factorial cell from the same template
axes; for hand-rolled batches the engine validates it at build time.

``from_product`` broadcasts: any axis given as a single value applies to
every cell; sequences become factorial axes. The factorial order is
``interventions x tau x disease x seeds`` with seeds innermost, so
consecutive scenarios are Monte Carlo replicates of the same design cell.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple, Union

from repro.core import disease as disease_lib
from repro.core import transmission as tx_lib
from repro.core.interventions import Intervention


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-specified simulation run."""

    name: str
    disease: disease_lib.DiseaseModel
    tm: tx_lib.TransmissionModel = dataclasses.field(
        default_factory=tx_lib.TransmissionModel
    )
    interventions: Tuple[Intervention, ...] = ()
    # Per-slot enable mask; () means all enabled. This is how a factorial
    # design shares one union slot layout across cells while each cell
    # activates only its own interventions (slot *values* stack, slot
    # *structure* stays identical across the batch).
    iv_enabled: Tuple[bool, ...] = ()
    seed: int = 0
    seed_per_day: int = 10
    seed_days: int = 7
    static_network: bool = False


def _axis(x, default) -> tuple:
    """Broadcast a scalar-or-sequence factorial axis to a tuple."""
    if x is None:
        return (default,)
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)


@dataclasses.dataclass(frozen=True)
class ScenarioBatch:
    """An ordered batch of scenarios run as one vmapped ensemble."""

    scenarios: Tuple[Scenario, ...]

    def __len__(self) -> int:
        return len(self.scenarios)

    def __iter__(self) -> Iterator[Scenario]:
        return iter(self.scenarios)

    def __getitem__(self, i) -> Scenario:
        return self.scenarios[i]

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.scenarios)

    def validate(self) -> None:
        assert len(self.scenarios) > 0, "empty scenario batch"
        S = self.scenarios[0].disease.num_states
        K = len(self.scenarios[0].interventions)
        for s in self.scenarios:
            if s.disease.num_states != S:
                raise ValueError(
                    f"scenario '{s.name}': disease has {s.disease.num_states} "
                    f"states, batch requires {S} (FSA structure must match; "
                    "perturb table values, not the state set)"
                )
            if len(s.interventions) != K:
                raise ValueError(
                    f"scenario '{s.name}': {len(s.interventions)} intervention "
                    f"slots, batch requires {K} (disable a slot with an "
                    "always-off trigger instead of dropping it)"
                )

    # ------------------------------------------------------------------
    @classmethod
    def from_scenarios(cls, scenarios: Sequence[Scenario]) -> "ScenarioBatch":
        batch = cls(scenarios=tuple(scenarios))
        batch.validate()
        return batch

    @classmethod
    def from_product(
        cls,
        *,
        interventions: Optional[
            Dict[str, Sequence[Intervention]]
        ] = None,  # design axis: name -> intervention list
        tau: Union[float, Sequence[float], None] = None,
        disease: Union[
            disease_lib.DiseaseModel,
            Dict[str, disease_lib.DiseaseModel],
            None,
        ] = None,
        seeds: Union[int, Sequence[int]] = 0,
        time_unit: float = 1.0,
        seed_per_day: int = 10,
        seed_days: int = 7,
        static_network: bool = False,
    ) -> "ScenarioBatch":
        """Factorial study builder: ``interventions x tau x disease x seeds``.

        Every axis broadcasts when given a single value. The intervention
        axis is compiled to a *union* slot layout: each scenario carries
        every intervention that appears in any design cell, with an
        ``iv_enabled`` mask activating only its own cell's slots — so all
        scenarios share one trace-time structure. (Limitation inherited
        from the single-run semantics: at most one Vaccinate slot per
        union, since one ``vaccinated`` flag carries one efficacy.) Monte
        Carlo ``seeds`` are the innermost axis, so replicates of one
        design cell are adjacent in the batch.
        """
        iv_axis = tuple(
            (interventions or {"baseline": ()}).items()
        )  # ((name, ivs), ...)
        union: tuple = sum((tuple(ivs) for _, ivs in iv_axis), ())
        masks = []
        off = 0
        for _, ivs in iv_axis:
            n = len(ivs)
            masks.append(
                tuple(off <= j < off + n for j in range(len(union)))
            )
            off += n
        tau_axis = _axis(tau, tx_lib.TransmissionModel().tau)
        if disease is None:
            dz_axis = (("covid", disease_lib.covid_model()),)
        elif isinstance(disease, dict):
            dz_axis = tuple(disease.items())
        else:
            dz_axis = ((disease.name, disease),)
        seed_axis = _axis(seeds, 0)

        scenarios = []
        for (iv_name, ivs), mask in zip(iv_axis, masks):
            for t in tau_axis:
                for dz_name, dz in dz_axis:
                    for seed in seed_axis:
                        parts = [iv_name]
                        if len(tau_axis) > 1:
                            parts.append(f"tau={t:g}")
                        if len(dz_axis) > 1:
                            parts.append(dz_name)
                        if len(seed_axis) > 1:
                            parts.append(f"s{seed}")
                        scenarios.append(
                            Scenario(
                                name="/".join(parts),
                                disease=dz,
                                tm=tx_lib.TransmissionModel(
                                    tau=float(t), time_unit=time_unit
                                ),
                                interventions=union,
                                iv_enabled=mask,
                                seed=int(seed),
                                seed_per_day=seed_per_day,
                                seed_days=seed_days,
                                static_network=static_network,
                            )
                        )
        return cls.from_scenarios(scenarios)
