"""The 10 assigned architectures (exact configs from the assignment brief),
plus ``reduced_config`` for CPU smoke tests.

Each entry cites its source tier from the assignment. Frontends for [vlm]
and [audio] archs are stubs: ``input_specs`` provides precomputed patch /
frame embeddings (the transformer backbone is what is specified).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

SMOLLM_360M = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, head_dim=64,
    tie_embeddings=True,
    source="[hf:HuggingFaceTB/SmolLM-135M; hf] llama-arch small, GQA kv=5",
)

GRANITE_3_2B = ModelConfig(
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=49155, head_dim=64,
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-2b-base; hf] GQA",
)

QWEN3_14B = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=17408, vocab_size=151936, head_dim=128,
    qk_norm=True,
    source="[hf:Qwen/Qwen3-8B; hf] qk_norm, GQA",
)

QWEN2_1_5B = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    qkv_bias=True, tie_embeddings=True,
    source="[arXiv:2407.10671; hf] GQA, QKV bias",
)

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_experts=8, experts_per_token=2, attn_window=4096,
    source="[arXiv:2401.04088; hf] 8 experts top-2, SWA",
)

MOONSHOT_16B_A3B = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    num_experts=64, experts_per_token=6,
    source="[hf:moonshotai/Moonlight-16B-A3B; hf] kimi/moonlight, 64e top-6",
)

RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), local_window=2048,
    lru_width=4096, tie_embeddings=True,
    source="[arXiv:2402.19427; unverified] RG-LRU + local attn, 1:2",
)

LLAVA_NEXT_MISTRAL_7B = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    attn_window=4096, num_patches=576,
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] anyres tiling "
    "(frontend stubbed: precomputed patch embeddings); mistral SWA backbone",
)

MAMBA2_130M = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_groups=1, d_conv=4, expand=2, ssd_chunk=256,
    tie_embeddings=True, rope_theta=None,
    source="[arXiv:2405.21060; unverified] SSD (state-space duality)",
)

WHISPER_BASE = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    enc_layers=6, enc_frames=1500, rope_theta=None, norm_eps=1e-5,
    source="[arXiv:2212.04356; unverified] enc-dec, conv frontend (stubbed: "
    "precomputed frame embeddings)",
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        SMOLLM_360M, GRANITE_3_2B, QWEN3_14B, QWEN2_1_5B,
        MIXTRAL_8X7B, MOONSHOT_16B_A3B, RECURRENTGEMMA_9B,
        LLAVA_NEXT_MISTRAL_7B, MAMBA2_130M, WHISPER_BASE,
    )
}


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers, narrow
    width, few experts, small vocab. Structure (GQA ratios, pattern,
    flags) is preserved."""
    kv = max(cfg.num_kv_heads, 1)
    heads = max(cfg.num_heads, 1)
    g = max(heads // kv, 1)
    small_kv = min(kv, 2)
    small_heads = small_kv * min(g, 3)
    repl = {
        "num_layers": min(cfg.num_layers, 4 if not cfg.block_pattern else 4),
        "d_model": 64,
        "num_heads": small_heads if cfg.family != "ssm" else 0,
        "num_kv_heads": small_kv if cfg.family != "ssm" else 0,
        "head_dim": 16 if cfg.family != "ssm" else 0,
        "d_ff": 128 if cfg.d_ff else 0,
        "vocab_size": 512,
        "num_experts": min(cfg.num_experts, 4),
        "experts_per_token": min(cfg.experts_per_token, 2),
        "attn_window": 32 if cfg.attn_window else None,
        "local_window": 32,
        "lru_width": 64 if cfg.lru_width else 0,
        "ssm_state": 16 if cfg.ssm_state else 0,
        "ssd_chunk": 16,
        "enc_layers": min(cfg.enc_layers, 2),
        "enc_frames": 24 if cfg.enc_frames and cfg.family == "audio" else cfg.enc_frames,
        "num_patches": 8 if cfg.num_patches else 0,
        "name": cfg.name + "-smoke",
    }
    return dataclasses.replace(cfg, **repl)
