"""Epidemic dataset configs (paper Table II/III).

Full-scale entity counts are kept for the dry-run (shapes only); ``*-mini``
variants are generated and *run* on CPU for the benchmark suite. The scale
notes record the reduction factor so Table-II comparisons are explicit.
"""

from __future__ import annotations

from repro.configs.base import EpidemicConfig

EPIDEMICS = {
    # --- digital twins (paper Table II; §IV-A1) -------------------------
    "md": EpidemicConfig(
        name="md", generator="twin", num_people=5_513_000,
        scale_note="paper MD: 5.513M people / 2.896M locs / 25.97M visits/wk",
    ),
    "va": EpidemicConfig(
        name="va", generator="twin", num_people=7_685_000, seed=1,
        scale_note="paper VA: 7.685M people / 4.092M locs / 36.20M visits/wk",
    ),
    "md-mini": EpidemicConfig(
        name="md-mini", generator="twin", num_people=55_130,
        scale_note="MD at 1/100 scale (CPU-runnable)",
    ),
    "va-mini": EpidemicConfig(
        name="va-mini", generator="twin", num_people=76_850, seed=1,
        scale_note="VA at 1/100 scale (CPU-runnable)",
    ),
    "twin-2k": EpidemicConfig(
        name="twin-2k", generator="twin", num_people=2_000,
        scale_note="test-size twin",
    ),
    # --- Watts-Strogatz synthetics (paper Table II; §IV-A2) -------------
    "ws-us": EpidemicConfig(
        name="ws-us", generator="ws", num_people=280_400_000,
        num_locations=71_710_000,
        scale_note="paper WS-US: 280.4M people / 71.71M locs",
    ),
    "ws-100m": EpidemicConfig(
        name="ws-100m", generator="ws", num_people=100_000_000,
        num_locations=25_000_000,
    ),
    "ws-20m": EpidemicConfig(
        name="ws-20m", generator="ws", num_people=20_000_000,
        num_locations=5_000_000,
    ),
    "ws-200k": EpidemicConfig(
        name="ws-200k", generator="ws", num_people=200_000,
        num_locations=50_000, scale_note="WS-20M at 1/100 scale",
    ),
    "ws-50k": EpidemicConfig(
        name="ws-50k", generator="ws", num_people=50_000,
        num_locations=12_500, scale_note="bench-size WS",
    ),
    # --- grid weak-scaling loads (paper Table III) -----------------------
    # per-worker loads: 144k/36k, 288k/72k, 576k/144k (people/locs per core)
    "grid-1x": EpidemicConfig(
        name="grid-1x", generator="grid", num_people=144_000, grid=(190, 190),
        scale_note="Table III 1x per-core load (36.1k locs)",
    ),
    "grid-2x": EpidemicConfig(
        name="grid-2x", generator="grid", num_people=288_000, grid=(269, 268),
        scale_note="Table III 2x per-core load (72.1k locs)",
    ),
    "grid-4x": EpidemicConfig(
        name="grid-4x", generator="grid", num_people=576_000, grid=(380, 379),
        scale_note="Table III 4x per-core load (144k locs)",
    ),
    "grid-tiny": EpidemicConfig(
        name="grid-tiny", generator="grid", num_people=14_400, grid=(60, 60),
        scale_note="1x load at 1/10 (CPU tests)",
    ),
}
