"""Config dataclasses: model architectures, input shapes, epidemic datasets."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: Optional[int] = None  # sliding-window attention
    rope_theta: Optional[float] = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # hybrid (RecurrentGemma / Griffin)
    block_pattern: tuple = ()  # e.g. ("rec", "rec", "attn"), cycled
    local_window: int = 2048
    lru_width: int = 0  # 0 => d_model
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    ssd_chunk: int = 256
    # encoder-decoder (Whisper)
    enc_layers: int = 0
    enc_frames: int = 1500
    # VLM (LLaVA-Next)
    num_patches: int = 0  # patch tokens prepended (anyres stub)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # performance knobs (§Perf hillclimbing; defaults = naive baseline)
    attn_impl: str = "naive"  # naive | chunked (online-softmax KV blocks)
    attn_chunk: int = 1024  # KV chunk for attn_impl=chunked
    remat_policy: str = "nothing"  # nothing | dots | none
    moe_dispatch: str = "pjit"  # pjit (global scatter) | shard_map (local)
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // 64  # mamba2 head dim is 64

    @property
    def sub_quadratic(self) -> bool:
        """Supports decoding with O(1)/O(window) state (long_500k rule)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # RG-LRU state + local-window attention
        return self.attn_window is not None  # SWA

    def param_count(self) -> int:
        from repro.models import model as model_lib

        return model_lib.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import model as model_lib

        return model_lib.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped per assignment"
    return True, ""


@dataclasses.dataclass(frozen=True)
class EpidemicConfig:
    name: str
    generator: str  # twin | ws | grid
    num_people: int
    num_locations: int = 0  # ws only
    grid: tuple = ()  # grid only
    scale_note: str = ""
    seed: int = 0
    tau: float = 2.0e-5
    days: int = 200

    def build(self, pad_multiple: int = 128):
        from repro.data import (
            digital_twin_population,
            grid_population,
            watts_strogatz_population,
        )

        if self.generator == "twin":
            return digital_twin_population(
                self.num_people, seed=self.seed, name=self.name,
                pad_multiple=pad_multiple,
            )
        if self.generator == "ws":
            return watts_strogatz_population(
                self.num_people, self.num_locations, seed=self.seed,
                name=self.name, pad_multiple=pad_multiple,
            )
        if self.generator == "grid":
            w, h = self.grid
            return grid_population(
                w, h, density=self.num_people / (w * h), seed=self.seed,
                name=self.name, pad_multiple=pad_multiple,
            )
        raise ValueError(self.generator)
