"""Config registry: ``get_config(name)`` / ``get_epidemic(name)``."""

from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    DECODE_32K,
    EpidemicConfig,
    LM_SHAPES,
    LONG_500K,
    ModelConfig,
    PREFILL_32K,
    ShapeConfig,
    TRAIN_4K,
    supports_shape,
)
from repro.configs.archs import ARCHS, reduced_config  # noqa: F401
from repro.configs.epidemics import EPIDEMICS  # noqa: F401
from repro.configs.presets import (  # noqa: F401
    DISEASES,
    INTERVENTION_PRESETS,
)
from repro.configs.sweep import Scenario, ScenarioBatch  # noqa: F401


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def get_epidemic(name: str) -> EpidemicConfig:
    if name not in EPIDEMICS:
        raise KeyError(f"unknown epidemic dataset '{name}'; have {sorted(EPIDEMICS)}")
    return EPIDEMICS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)
