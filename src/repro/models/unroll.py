"""Global unroll-mode switch for roofline accounting.

XLA's cost analysis counts while-loop bodies once (verified empirically),
so the dry-run compiles 1-/2-layer variants with every structural loop
(layer scan, attention KV-chunk scan) truly unrolled. Activating the mode
around ``.lower()`` affects tracing only — production programs always use
``lax.scan``.
"""

from __future__ import annotations

import contextlib

_MODE = [False]


def enabled() -> bool:
    return _MODE[0]


@contextlib.contextmanager
def unroll_mode():
    old = _MODE[0]
    _MODE[0] = True
    try:
        yield
    finally:
        _MODE[0] = old
