"""Parameter declaration machinery for the model zoo.

Each model family declares its parameters once as a tree of ``ParamSpec``
(shape + logical axes + init rule). From that single source of truth we
derive: concrete initialization (smoke tests, real training), abstract
``ShapeDtypeStruct`` trees (the dry-run lowers against these — no
allocation), and ``PartitionSpec`` trees via models/sharding.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.sharding import MeshRules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "fanin"  # fanin | embed | zeros | ones | small
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(spec: ParamSpec, key) -> jnp.ndarray:
    shape, dtype = spec.shape, jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "embed":
        # detlint: ignore[DET001] — LM param init uses JAX's keyed PRNG by
        # design; the LM side-stack is outside the epidemic stream contract.
        return jax.random.normal(key, shape, dtype) * 0.02
    if spec.init == "small":
        return jax.random.normal(key, shape, dtype) * 0.006  # detlint: ignore[DET001] — keyed LM init
    # fanin: normal with 1/sqrt(fan_in); fan_in = product of all dims that
    # are contracted on input — heuristically all but the last (for stacked
    # layer params the leading 'layers' dim is excluded).
    dims = [d for d, a in zip(shape, spec.axes) if a not in ("layers",)]
    fan_in = int(np.prod(dims[:-1])) if len(dims) > 1 else 1
    # float(): np.sqrt returns a non-weak np.float64 scalar that would
    # promote float32 params to float64 under JAX_ENABLE_X64.
    scale = float(1.0 / max(np.sqrt(fan_in), 1.0))
    return jax.random.normal(key, shape, dtype) * scale  # detlint: ignore[DET001] — keyed LM init


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, key):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))  # detlint: ignore[DET001] — keyed LM init
    return jax.tree.unflatten(
        treedef, [_init_one(s, k) for s, k in zip(leaves, keys)]
    )


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        spec_tree,
        is_leaf=is_spec,
    )


def param_partition_specs(spec_tree, rules: MeshRules):
    return jax.tree.map(
        lambda s: rules.spec(s.shape, s.axes), spec_tree, is_leaf=is_spec
    )


def param_count(spec_tree) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(spec_tree, is_leaf=is_spec)
    )
