"""Top-level model API: build/init params, loss, train/prefill/decode steps,
and ``input_specs`` (abstract inputs for every (arch × shape) dry-run cell).

All functions dispatch on ``cfg.family``:
  dense | moe | vlm | hybrid | ssm -> models/transformer.py
  audio (enc-dec)                  -> models/encdec.py
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import base as base_lib
from repro.models import encdec as encdec_lib
from repro.models import layers as L
from repro.models import transformer as tf_lib


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def model_specs(cfg: ModelConfig, max_target_positions: int = 0) -> dict:
    if cfg.family == "audio":
        return encdec_lib.model_specs(cfg, max(max_target_positions, 448))
    return tf_lib.model_specs(cfg)


def init_params(cfg: ModelConfig, key, max_target_positions: int = 0):
    return base_lib.init_params(model_specs(cfg, max_target_positions), key)


def abstract_params(cfg: ModelConfig, max_target_positions: int = 0):
    return base_lib.abstract_params(model_specs(cfg, max_target_positions))


def param_partition_specs(cfg: ModelConfig, rules, max_target_positions: int = 0):
    return base_lib.param_partition_specs(
        model_specs(cfg, max_target_positions), rules
    )


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count — the N in MODEL_FLOPS=6ND."""
    specs = model_specs(cfg)
    total = base_lib.param_count(specs)
    if active_only and cfg.family == "moe":
        # replace expert count with experts_per_token for the active count
        E, K = cfg.num_experts, cfg.experts_per_token
        expert_params = 3 * cfg.num_layers * cfg.num_experts * cfg.d_model * cfg.d_ff
        total = total - expert_params + expert_params * K // E
    return total


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _cast(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, tree
    )


def forward_train(cfg: ModelConfig, params, rules, batch) -> tuple:
    """Returns (loss, metrics). batch keys per family (see input_specs)."""
    compute = jnp.dtype(cfg.compute_dtype)
    p = _cast(params, compute)

    if cfg.family == "audio":
        enc_out = encdec_lib.encode(cfg, p, rules, batch["frames"].astype(compute))
        logits = encdec_lib.decode_train(cfg, p, rules, batch["tokens"], enc_out)
        loss = L.cross_entropy_loss(
            logits[:, :-1], batch["tokens"][:, 1:], batch.get("loss_mask")
        )
        return loss, {"loss": loss}

    tokens = batch["tokens"]
    x = p["embed"][tokens].astype(compute)
    if rules is not None:
        x = rules.constraint(x, "batch", "seq", "embed")
    npatch = 0
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(compute)  # (B, Np, D)
        x = jnp.concatenate([patches, x], axis=1)
        npatch = patches.shape[1]
    h, _, aux = tf_lib.stack_forward(cfg, p, rules, x)
    h = L.rms_norm(h, p["final_norm"], cfg.norm_eps)
    table = p["embed"] if cfg.tie_embeddings else p["unembed"]
    logits = L.unembed(h, table.astype(compute), rules)
    if cfg.family == "vlm":
        # token t_j sits at position npatch+j; loss over the text span only
        loss = L.cross_entropy_loss(logits[:, npatch:-1], tokens[:, 1:])
    else:
        loss = L.cross_entropy_loss(logits[:, :-1], tokens[:, 1:])
    metrics = {"loss": loss}
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
        metrics.update(
            {"load_balance": aux["load_balance"],
             "dropped_fraction": aux["dropped_fraction"]}
        )
    return loss, metrics


def forward_prefill(cfg: ModelConfig, params, rules, batch):
    """Full-sequence forward producing last-position logits + decode cache."""
    compute = jnp.dtype(cfg.compute_dtype)
    p = _cast(params, compute)
    if cfg.family == "audio":
        enc_out = encdec_lib.encode(cfg, p, rules, batch["frames"].astype(compute))
        logits = encdec_lib.decode_train(cfg, p, rules, batch["tokens"], enc_out)
        return logits[:, -1:], {"enc_out": enc_out}
    tokens = batch["tokens"]
    x = p["embed"][tokens].astype(compute)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patch_embeds"].astype(compute), x], axis=1)
    S = x.shape[1]
    h, cache, _ = tf_lib.stack_forward(
        cfg, p, rules, x, want_cache=True, cache_len=S
    )
    h = L.rms_norm(h, p["final_norm"], cfg.norm_eps)
    table = p["embed"] if cfg.tie_embeddings else p["unembed"]
    logits = L.unembed(h[:, -1:], table.astype(compute), rules)
    return logits, cache


def decode_step(cfg: ModelConfig, params, rules, cache, token, pos):
    """One decode step. token: (B, 1); pos: scalar int32 absolute position."""
    compute = jnp.dtype(cfg.compute_dtype)
    p = _cast(params, compute)
    if cfg.family == "audio":
        return encdec_lib.decode_step(cfg, p, rules, cache, token, pos)
    x = p["embed"][token].astype(compute)
    h, cache = tf_lib.decode_stack(cfg, p, rules, x, cache, pos)
    h = L.rms_norm(h, p["final_norm"], cfg.norm_eps)
    table = p["embed"] if cfg.tie_embeddings else p["unembed"]
    logits = L.unembed(h, table.astype(compute), rules)
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, abstract=False):
    if cfg.family == "audio":
        return encdec_lib.init_cache(cfg, batch, cache_len, abstract=abstract)
    return tf_lib.init_cache(cfg, batch, cache_len, abstract=abstract)


def cache_axes(cfg: ModelConfig, cache):
    if cfg.family == "audio":
        return encdec_lib.cache_axes_tree(cfg, cache)
    return tf_lib.cache_axes_tree(cfg, cache)


def cache_partition_specs(cfg: ModelConfig, cache, rules):
    axes = cache_axes(cfg, cache)
    return jax.tree.map(
        lambda leaf, ax_key: rules.spec(leaf.shape, axes[ax_key]),
        cache,
        {k: k for k in cache},
    )


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; also shapes for the data pipeline)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for one (arch, shape) cell. ShapeDtypeStructs only."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16

    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((B, cfg.enc_frames, cfg.d_model), bf16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.family == "vlm":
            return {
                "patch_embeds": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), bf16),
                "tokens": jax.ShapeDtypeStruct((B, S - cfg.num_patches), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}

    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((B, cfg.enc_frames, cfg.d_model), bf16),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.family == "vlm":
            return {
                "patch_embeds": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), bf16),
                "tokens": jax.ShapeDtypeStruct((B, S - cfg.num_patches), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}

    # decode: one new token against a cache of length S
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": init_cache(cfg, B, S, abstract=True),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def batch_partition_specs(cfg: ModelConfig, shape: ShapeConfig, rules):
    """PartitionSpecs matching input_specs."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if k == "tokens" or k == "token":
            out[k] = rules.spec(v.shape, ("batch", "seq"))
        elif k == "frames":
            out[k] = rules.spec(v.shape, ("batch", "frames", "embed"))
        elif k == "patch_embeds":
            out[k] = rules.spec(v.shape, ("batch", "patches", "embed"))
        elif k == "pos":
            out[k] = rules.spec((), ())
        elif k == "cache":
            out[k] = cache_partition_specs(cfg, v, rules)
    return out
