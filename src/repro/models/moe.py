"""Mixture-of-Experts FFN with capacity-bucketed scatter dispatch.

This is the paper's visit-exchange pattern applied to experts (DESIGN.md
§4): tokens are routed to a fixed-capacity per-expert bucket (static
shapes), processed as dense per-expert matmuls, and combined back weighted
by router gates. The position-within-expert prefix-count plays the role of
the visit slot assignment in core/exchange.py, and dropped tokens (over
capacity) are the analog of bucket overflow — counted and reported.

Sharding (baseline): experts use TP-within-expert — w_* shard the `mlp`
dim over 'model', so the collective profile matches the dense FFN (one
all-reduce after the down-projection) and any expert count works on any
mesh. An expert-parallel variant (experts sharded over 'model' with an
all_to_all dispatch) is the §Perf hillclimb for moonshot's 64 experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def capacity(cfg, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * num_tokens
            / max(cfg.num_experts, 1))
    return max((c + 7) // 8 * 8, 8)


def moe_ffn(x, p, cfg, rules=None):
    """x: (B, S, D) or (T, D). Returns same shape + aux dict."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xt = x.reshape(-1, D)
    T = xt.shape[0]
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, T)

    router_logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    gate_v, gate_i = jax.lax.top_k(router_logits, K)  # (T, K)
    gates = jax.nn.softmax(gate_v, axis=-1).astype(x.dtype)

    flat_e = gate_i.reshape(-1)  # (T*K,) token-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    # Position-within-expert via log-depth associative scan. A plain
    # jnp.cumsum lowers to reduce-window whose *counted* cost is O(n^2)
    # (and is serial on long axes); associative_scan is O(n log n) work,
    # log depth — measured 40x on the moonshot train cell (§Perf).
    cum = jax.lax.associative_scan(jnp.add, onehot, axis=0)
    pos_in_e = jnp.take_along_axis(cum - 1, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C
    pos_c = jnp.minimum(pos_in_e, C - 1)

    # Over-capacity tokens are zeroed and land on slot (e, C-1); the
    # zeroed payload makes collisions harmless (no sentinel row — keeps
    # the buffer 2-D scatter GSPMD-friendly).
    x_rep = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C, D), x.dtype)
    if rules is not None:
        buf = rules.constraint(buf, "expert", "expert_cap", "embed")
    h = buf.at[flat_e, pos_c].add(x_rep)
    if rules is not None:
        h = rules.constraint(h, "expert", "expert_cap", "embed")

    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    act = jax.nn.silu(g) * u
    if rules is not None:
        act = rules.constraint(act, "expert", "expert_cap", "mlp")
    y = jnp.einsum("ecf,efd->ecd", act, p["w_down"])  # (E, C, D)

    out_tok = y[flat_e, pos_c] * (gates.reshape(-1)[:, None]
                                  * keep[:, None].astype(y.dtype))
    out = out_tok.reshape(T, K, D).sum(axis=1)

    aux = {
        "dropped_fraction": 1.0 - keep.mean(),
        "router_z": jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2),
        # load-balance loss (Switch-style): E * sum_e f_e * p_e
        "load_balance": _load_balance_loss(router_logits, gate_i, E),
    }
    return out.reshape(orig_shape), aux


def moe_ffn_dispatch(x, p, cfg, rules=None):
    """MoE with the dispatch strategy selected by cfg.moe_dispatch.

    'pjit': the global scatter above — GSPMD decides the collectives
    (baseline; measured collective-bound on the 64-expert moonshot cell).
    'shard_map': the paper's pattern done properly — dispatch is LOCAL to
    each data shard (exactly like the per-worker visit buckets in
    core/exchange.py), expert weights stay sharded over 'model'
    (TP-within-expert) under GSPMD auto mode. The only inter-chip traffic
    is the model-axis all-reduce of the down-projection — the same
    collective profile as a dense FFN.
    """
    if rules is None or cfg.moe_dispatch != "shard_map":
        return moe_ffn(x, p, cfg, rules)
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    manual = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not manual:
        return moe_ffn(x, p, cfg, rules)
    bspec = P(manual)

    def inner(xl, pl):
        out, aux = moe_ffn(xl, pl, cfg, None)
        aux = {k: _jax.lax.pmean(v, manual) for k, v in aux.items()}
        return out, aux

    from repro.core import compat

    return compat.shard_map(
        inner, mesh=mesh, in_specs=(bspec, P()), out_specs=(bspec, P()),
        axis_names=set(manual),
    )(x, p)


def _load_balance_loss(router_logits, gate_i, E):
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_i[:, 0], E, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)
