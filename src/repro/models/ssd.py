"""Mamba2 / SSD (state-space duality) layer — arXiv:2405.21060.

The SSD chunked algorithm: split the sequence into chunks of Q; compute
the intra-chunk (quadratic-in-Q, matmul-friendly) term and carry the
(H, P, N) state across chunks with an associative scan. This is the
TPU-native formulation: the intra-chunk einsums hit the MXU, the
inter-chunk recurrence is a log-depth associative scan, and nothing is
sequential in S beyond the chunk scan.

``ssd_scan_ref`` is the pure-jnp oracle mirrored by the Pallas kernel in
kernels/ssd_scan/.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(x):
    """Stable 'segment sum' producing the (..., Q, Q) decay matrix exponent:
    out[i, j] = sum_{k in (j, i]} x[k] for j <= i else -inf."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_ref(x, dt, A, B, C, chunk: int, initial_state=None):
    """x: (b, S, H, P); dt: (b, S, H) post-softplus; A: (H,) negative;
    B, C: (b, S, G, N). Returns (y (b,S,H,P), final_state (b,H,P,N))."""
    b, S, H, P = x.shape
    G, N = B.shape[-2], B.shape[-1]
    if S % chunk:
        # Pad to a chunk multiple with dt=0 entries: decay exp(0)=1 and
        # input contribution dt*x=0, so the final state is unaffected and
        # the padded y rows are sliced off below.
        pad = chunk - S % chunk
        padf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = padf(x), padf(dt), padf(B), padf(C)
        y, state = ssd_scan_ref(x, dt, A, B, C, chunk, initial_state)
        return y[:, :S], state
    nc = S // chunk
    rep = H // G

    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, G, N), rep, axis=3)  # (b,c,q,H,N)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, G, N), rep, axis=3)

    dA = dtc * A  # (b, c, q, H)
    dAc = jnp.cumsum(dA, axis=2)

    # Intra-chunk: Y_intra[i] = sum_{j<=i} C_i B_j^T exp(sum_{(j,i]} dA) dt_j x_j
    L = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))  # (b, c, H, q, q)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)  # (b, c, H, q, k)
    scores = CB * L  # masked by L's -inf -> 0
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)

    # Chunk states: S_c = sum_j exp(sum_{(j, end]} dA) B_j dt_j x_j
    decay_to_end = jnp.exp(dAc[:, :, -1:, :] - dAc)  # (b, c, q, H)
    S_c = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, dtc * decay_to_end, xc)

    # Inter-chunk recurrence: h_c = h_{c-1} * exp(sum dA_c) + S_c
    chunk_decay = jnp.exp(dAc[:, :, -1, :])  # (b, c, H)

    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_scan, h_scan = jax.lax.associative_scan(
        combine, (chunk_decay, S_c), axis=1
    )
    if initial_state is not None:
        h_scan = h_scan + a_scan[..., None, None] * initial_state[:, None]
    # States entering each chunk (shifted by one).
    h0 = (
        initial_state[:, None]
        if initial_state is not None
        else jnp.zeros_like(h_scan[:, :1])
    )
    h_prev = jnp.concatenate([h0, h_scan[:, :-1]], axis=1)
    y_inter = jnp.einsum(
        "bcqhn,bcqh,bchpn->bcqhp", Cc, jnp.exp(dAc), h_prev
    )
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, h_scan[:, -1]


def ssd_decode_step(x, dt, A, B, C, state):
    """One-token recurrence. x: (b, H, P); dt: (b, H); B, C: (b, G, N);
    state: (b, H, P, N). Returns (y (b,H,P), new state)."""
    G = B.shape[-2]
    H = x.shape[1]
    rep = H // G
    Br = jnp.repeat(B, rep, axis=1)  # (b, H, N)
    Cr = jnp.repeat(C, rep, axis=1)
    da = jnp.exp(dt * A)  # (b, H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x, Br)
    new_state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cr)
    return y, new_state


def causal_conv1d(x, w, b=None):
    """Depthwise causal conv. x: (B, S, Cdim); w: (k, Cdim)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out if b is None else out + b


def conv_decode_step(x_new, conv_state, w, b=None):
    """x_new: (B, Cdim); conv_state: (B, k-1, Cdim). Returns (y, new_state)."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,k,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b
    return y, window[:, 1:, :]
