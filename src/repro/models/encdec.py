"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/log-mel frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, enc_frames, D). The backbone is
faithful: pre-LN transformer with GELU MLPs and biased projections,
sinusoidal encoder positions, learned decoder positions, causal decoder
self-attention + cross-attention to the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models.base import ParamSpec
from repro.models.transformer import _scan_layers as _scan


def _attn_specs(cfg, n, prefix=""):
    D, H, M, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = prefix
    return {
        p + "wq": ParamSpec((n, D, H, Dh), ("layers", "embed_fsdp", "heads", "head_dim")),
        p + "wk": ParamSpec((n, D, M, Dh), ("layers", "embed_fsdp", "kv_heads", "head_dim")),
        p + "wv": ParamSpec((n, D, M, Dh), ("layers", "embed_fsdp", "kv_heads", "head_dim")),
        p + "wo": ParamSpec((n, H, Dh, D), ("layers", "heads", "head_dim", "embed_fsdp")),
        p + "bq": ParamSpec((n, H, Dh), ("layers", "heads", "head_dim"), "zeros"),
        p + "bk": ParamSpec((n, M, Dh), ("layers", "kv_heads", "head_dim"), "zeros"),
        p + "bv": ParamSpec((n, M, Dh), ("layers", "kv_heads", "head_dim"), "zeros"),
        p + "bo": ParamSpec((n, D), ("layers", None), "zeros"),
    }


def _mlp_specs(cfg, n):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_in": ParamSpec((n, D, F), ("layers", "embed_fsdp", "mlp")),
        "b_in": ParamSpec((n, F), ("layers", "mlp"), "zeros"),
        "w_out": ParamSpec((n, F, D), ("layers", "mlp", "embed_fsdp")),
        "b_out": ParamSpec((n, D), ("layers", None), "zeros"),
    }


def _ln(n, D, prefix):
    return {
        prefix + "_w": ParamSpec((n, D), ("layers", None), "ones"),
        prefix + "_b": ParamSpec((n, D), ("layers", None), "zeros"),
    }


def model_specs(cfg, max_target_positions: int = 448) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    ne, nd = cfg.enc_layers, cfg.num_layers
    return {
        "embed": ParamSpec((V, D), ("vocab", "embed_fsdp"), "embed"),
        "pos_dec": ParamSpec(
            (max_target_positions, D), ("seq", "embed_fsdp"), "embed"
        ),
        "enc_layers": {
            **_attn_specs(cfg, ne), **_mlp_specs(cfg, ne),
            **_ln(ne, D, "ln1"), **_ln(ne, D, "ln2"),
        },
        "dec_layers": {
            **_attn_specs(cfg, nd), **_attn_specs(cfg, nd, "x_"),
            **_mlp_specs(cfg, nd),
            **_ln(nd, D, "ln1"), **_ln(nd, D, "ln2"), **_ln(nd, D, "ln3"),
        },
        "enc_norm_w": ParamSpec((D,), (None,), "ones"),
        "enc_norm_b": ParamSpec((D,), (None,), "zeros"),
        "dec_norm_w": ParamSpec((D,), (None,), "ones"),
        "dec_norm_b": ParamSpec((D,), (None,), "zeros"),
    }


def _mha(x, kv_x, layer, cfg, rules, prefix="", causal=False, mask=None):
    """Generic (self or cross) full attention with biases, no RoPE."""
    H, M, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, Sq, _ = x.shape
    Sk = kv_x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, layer[prefix + "wq"]) + layer[prefix + "bq"]
    k = jnp.einsum("bsd,dmk->bsmk", kv_x, layer[prefix + "wk"]) + layer[prefix + "bk"]
    v = jnp.einsum("bsd,dmk->bsmk", kv_x, layer[prefix + "wv"]) + layer[prefix + "bv"]
    q = q.reshape(B, Sq, M, H // M, Dh)
    # Chunked/flash paths for long causal self-attention; cross-attention
    # keys are short (enc_frames) — naive is optimal there.
    if cfg.attn_impl == "flash" and mask is None and causal and Sq == Sk:
        out = attn_lib.flash_sharded(q, k, v, cfg, rules, causal=True)
    elif cfg.attn_impl == "chunked" and mask is None and Sk % min(cfg.attn_chunk, Sk) == 0:
        out = attn_lib.attend_chunked(
            q, k, v, cfg, causal=causal, window=None, chunk=cfg.attn_chunk
        )
    else:
        if mask is None:
            if causal:
                mask = attn_lib.causal_window_mask(Sq, 0, Sk, None)[None, None, None]
            else:
                mask = jnp.ones((1, 1, 1, Sq, Sk), bool)
        out = attn_lib.attend(q, k, v, mask, cfg, rules)
    return jnp.einsum("bshk,hkd->bsd", out, layer[prefix + "wo"]) + layer[prefix + "bo"]


def encode(cfg, params, rules, frames, unroll=False):
    """frames: (B, F, D) precomputed embeddings (frontend stub)."""
    x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model)[None].astype(
        frames.dtype
    )

    def body(h, layer):
        hn = L.layer_norm(h, layer["ln1_w"], layer["ln1_b"], cfg.norm_eps)
        h = h + _mha(hn, hn, layer, cfg, rules)
        hn = L.layer_norm(h, layer["ln2_w"], layer["ln2_b"], cfg.norm_eps)
        h = h + L.gelu_mlp(hn, layer["w_in"], layer["b_in"], layer["w_out"], layer["b_out"])
        return h, None

    from repro.models.transformer import _ckpt
    x, _ = _scan(_ckpt(body, cfg), x, params["enc_layers"], unroll)
    return L.layer_norm(x, params["enc_norm_w"], params["enc_norm_b"], cfg.norm_eps)


def decode_train(cfg, params, rules, tokens, enc_out, unroll=False):
    """Teacher-forced decoder. tokens: (B, S). Returns logits (B, S, V)."""
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos_dec"][None, :S]
    x = x.astype(enc_out.dtype)

    def body(h, layer):
        hn = L.layer_norm(h, layer["ln1_w"], layer["ln1_b"], cfg.norm_eps)
        h = h + _mha(hn, hn, layer, cfg, rules, causal=True)
        hn = L.layer_norm(h, layer["ln2_w"], layer["ln2_b"], cfg.norm_eps)
        h = h + _mha(hn, enc_out, layer, cfg, rules, prefix="x_")
        hn = L.layer_norm(h, layer["ln3_w"], layer["ln3_b"], cfg.norm_eps)
        h = h + L.gelu_mlp(hn, layer["w_in"], layer["b_in"], layer["w_out"], layer["b_out"])
        return h, None

    from repro.models.transformer import _ckpt
    x, _ = _scan(_ckpt(body, cfg), x, params["dec_layers"], unroll)
    x = L.layer_norm(x, params["dec_norm_w"], params["dec_norm_b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits if rules is None else rules.constraint(logits, "batch", "seq", "vocab")


def init_cache(cfg, batch, cache_len, enc_frames=None, dtype=jnp.bfloat16, abstract=False):
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d)
    )
    n = cfg.num_layers
    M, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    F = enc_frames or cfg.enc_frames
    return {
        "k": mk((n, batch, M, cache_len, Dh), dtype),
        "v": mk((n, batch, M, cache_len, Dh), dtype),
        # Cross-attention K/V precomputed from the encoder output.
        "xk": mk((n, batch, M, F, Dh), dtype),
        "xv": mk((n, batch, M, F, Dh), dtype),
    }


def cache_axes_tree(cfg, cache):
    ax = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    xax = ("layers", "batch", "kv_heads", "frames", "head_dim")
    return {"k": ax, "v": ax, "xk": xax, "xv": xax}


def decode_step(cfg, params, rules, cache, token, pos, unroll=False):
    """token: (B, 1). Returns (logits (B, 1, V), new cache)."""
    B = token.shape[0]
    x = params["embed"][token] + params["pos_dec"][pos][None, None, :]
    H, M, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    def body(h, xs):
        layer, k, v, xk, xv = xs
        hn = L.layer_norm(h, layer["ln1_w"], layer["ln1_b"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hn, layer["wq"]) + layer["bq"]
        kn = jnp.einsum("bsd,dmk->bsmk", hn, layer["wk"]) + layer["bk"]
        vn = jnp.einsum("bsd,dmk->bsmk", hn, layer["wv"]) + layer["bv"]
        T = k.shape[2]
        slot = (pos % T).astype(jnp.int32)
        k = jax.lax.dynamic_update_slice_in_dim(
            k, kn.astype(k.dtype).transpose(0, 2, 1, 3), slot, 2
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            v, vn.astype(v.dtype).transpose(0, 2, 1, 3), slot, 2
        )
        i = jnp.arange(T, dtype=jnp.int32)
        valid = (pos - ((pos - i) % T)) >= 0
        q5 = q.reshape(B, 1, M, H // M, Dh)
        out = attn_lib.attend(
            q5, k.transpose(0, 2, 1, 3).astype(q.dtype),
            v.transpose(0, 2, 1, 3).astype(q.dtype),
            valid[None, None, None, None, :], cfg, rules,
        )
        h = h + jnp.einsum("bshk,hkd->bsd", out, layer["wo"]) + layer["bo"]
        # cross attention against precomputed enc K/V
        hn = L.layer_norm(h, layer["ln2_w"], layer["ln2_b"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hn, layer["x_wq"]) + layer["x_bq"]
        qx = qx.reshape(B, 1, M, H // M, Dh)
        outx = attn_lib.attend(
            qx, xk.transpose(0, 2, 1, 3).astype(qx.dtype),
            xv.transpose(0, 2, 1, 3).astype(qx.dtype),
            jnp.ones((1, 1, 1, 1, xk.shape[2]), bool), cfg, rules,
        )
        h = h + jnp.einsum("bshk,hkd->bsd", outx, layer["x_wo"]) + layer["x_bo"]
        hn = L.layer_norm(h, layer["ln3_w"], layer["ln3_b"], cfg.norm_eps)
        h = h + L.gelu_mlp(hn, layer["w_in"], layer["b_in"], layer["w_out"], layer["b_out"])
        return h, (k, v)

    x, (k, v) = _scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll,
    )
    x = L.layer_norm(x, params["dec_norm_w"], params["dec_norm_b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits, {"k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"]}
