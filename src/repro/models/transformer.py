"""Decoder-only model assembly for dense / MoE / hybrid / SSM / VLM
families: parameter specs, train/prefill forward, and cached decode.

Layers are stacked on a leading axis and iterated with ``lax.scan`` +
``jax.checkpoint`` (remat) — essential for 512-device compile times and
activation memory. Hybrid (RecurrentGemma) scans over whole pattern cycles
(rec, rec, attn) and unrolls the remainder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssd as ssd_lib
from repro.models.base import ParamSpec
from repro.models import unroll as unroll_lib

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_specs(cfg, n: int) -> dict:
    D, H, M, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": ParamSpec((n, D, H, Dh), ("layers", "embed_fsdp", "heads", "head_dim")),
        "wk": ParamSpec((n, D, M, Dh), ("layers", "embed_fsdp", "kv_heads", "head_dim")),
        "wv": ParamSpec((n, D, M, Dh), ("layers", "embed_fsdp", "kv_heads", "head_dim")),
        "wo": ParamSpec((n, H, Dh, D), ("layers", "heads", "head_dim", "embed_fsdp")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((n, H, Dh), ("layers", "heads", "head_dim"), "zeros")
        s["bk"] = ParamSpec((n, M, Dh), ("layers", "kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamSpec((n, M, Dh), ("layers", "kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((n, Dh), ("layers", "head_dim"), "ones")
        s["k_norm"] = ParamSpec((n, Dh), ("layers", "head_dim"), "ones")
    return s


def mlp_specs(cfg, n: int) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((n, D, F), ("layers", "embed_fsdp", "mlp")),
        "w_up": ParamSpec((n, D, F), ("layers", "embed_fsdp", "mlp")),
        "w_down": ParamSpec((n, F, D), ("layers", "mlp", "embed_fsdp")),
    }


def moe_specs(cfg, n: int) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamSpec((n, D, E), ("layers", "embed_fsdp", None), "small"),
        "w_gate": ParamSpec((n, E, D, F), ("layers", "expert", "embed_fsdp", "mlp")),
        "w_up": ParamSpec((n, E, D, F), ("layers", "expert", "embed_fsdp", "mlp")),
        "w_down": ParamSpec((n, E, F, D), ("layers", "expert", "mlp", "embed_fsdp")),
    }


def ssd_specs(cfg, n: int) -> dict:
    D = cfg.d_model
    Din = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = Din + 2 * G * N
    proj_out = 2 * Din + 2 * G * N + H
    return {
        "in_proj": ParamSpec((n, D, proj_out), ("layers", "embed_fsdp", None)),
        "conv_w": ParamSpec((n, cfg.d_conv, conv_dim), ("layers", "conv", None)),
        "conv_b": ParamSpec((n, conv_dim), ("layers", None), "zeros"),
        "A_log": ParamSpec((n, H), ("layers", None), "ones"),
        "D": ParamSpec((n, H), ("layers", None), "ones"),
        "dt_bias": ParamSpec((n, H), ("layers", None), "zeros"),
        "norm": ParamSpec((n, Din), ("layers", None), "ones"),
        "out_proj": ParamSpec((n, Din, D), ("layers", None, "embed_fsdp")),
    }


def rec_specs(cfg, n: int) -> dict:
    D = cfg.d_model
    W = cfg.lru_width or D
    return {
        "w_gelu": ParamSpec((n, D, W), ("layers", "embed_fsdp", "lru")),
        "w_lin": ParamSpec((n, D, W), ("layers", "embed_fsdp", "lru")),
        "conv_w": ParamSpec((n, 4, W), ("layers", "conv", "lru")),
        "conv_b": ParamSpec((n, W), ("layers", "lru"), "zeros"),
        "w_a": ParamSpec((n, W, W), ("layers", "lru", None), "small"),
        "b_a": ParamSpec((n, W), ("layers", "lru"), "zeros"),
        "w_x": ParamSpec((n, W, W), ("layers", "lru", None), "small"),
        "b_x": ParamSpec((n, W), ("layers", "lru"), "zeros"),
        "lam": ParamSpec((n, W), ("layers", "lru"), "ones"),
        "w_out": ParamSpec((n, W, D), ("layers", "lru", "embed_fsdp")),
    }


def _norm(n, D):
    return ParamSpec((n, D), ("layers", None), "ones")


def hybrid_layer_types(cfg) -> list[str]:
    pat = cfg.block_pattern or ("attn",)
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def model_specs(cfg) -> dict:
    D, V, n = cfg.d_model, cfg.vocab_size, cfg.num_layers
    specs: dict = {
        "embed": ParamSpec((V, D), ("vocab", "embed_fsdp"), "embed"),
        "final_norm": ParamSpec((D,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((V, D), ("vocab", "embed_fsdp"), "embed")
    if cfg.family == "ssm":
        specs["layers"] = {**ssd_specs(cfg, n), "ln": _norm(n, D)}
    elif cfg.family == "hybrid":
        types = hybrid_layer_types(cfg)
        n_rec = types.count("rec")
        n_attn = types.count("attn")
        specs["rec_layers"] = {
            **rec_specs(cfg, n_rec), "ln1": _norm(n_rec, D),
            **{f"mlp_{k}": v for k, v in mlp_specs(cfg, n_rec).items()},
            "ln2": _norm(n_rec, D),
        }
        specs["attn_layers"] = {
            **attn_specs(cfg, n_attn), "ln1": _norm(n_attn, D),
            **{f"mlp_{k}": v for k, v in mlp_specs(cfg, n_attn).items()},
            "ln2": _norm(n_attn, D),
        }
    else:  # dense / moe / vlm
        ffn = moe_specs(cfg, n) if cfg.family == "moe" else mlp_specs(cfg, n)
        specs["layers"] = {
            **attn_specs(cfg, n), **ffn,
            "ln1": _norm(n, D), "ln2": _norm(n, D),
        }
    return specs


def _ckpt(fn, cfg):
    """Remat policy knob (cfg.remat_policy): 'nothing' (recompute all),
    'dots' (save matmul outputs), 'none' (no remat)."""
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _mlp_of(layer, prefix=""):
    return {k[len(prefix):]: v for k, v in layer.items() if k.startswith(prefix)} \
        if prefix else layer


def attn_block(x, layer, cfg, rules, *, window, pos_offset=0, want_kv=False):
    h = L.rms_norm(x, layer["ln1"], cfg.norm_eps)
    out, kv = attn_lib.self_attention(
        h, layer, cfg, rules, window=window, pos_offset=pos_offset
    )
    x = x + out
    h = L.rms_norm(x, layer["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_lib.moe_ffn_dispatch(h, layer, cfg, rules)
    else:
        mlp = {k[4:]: v for k, v in layer.items() if k.startswith("mlp_")}
        mlp = mlp if mlp else layer
        m, aux = L.swiglu(h, mlp["w_gate"], mlp["w_up"], mlp["w_down"], rules), {}
    return x + m, kv, aux


def ssd_block(x, layer, cfg, rules, state=None):
    """Mamba2 block. Returns (x, (conv_tail, ssm_state))."""
    h = L.rms_norm(x, layer["ln"], cfg.norm_eps)
    Din = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,dp->bsp", h, layer["in_proj"])
    z, xBC, dt_raw = jnp.split(zxbcdt, [Din, 2 * Din + 2 * G * N], axis=-1)
    xBC = jax.nn.silu(
        ssd_lib.causal_conv1d(xBC, layer["conv_w"], layer["conv_b"])
    )
    xs, B_, C_ = jnp.split(xBC, [Din, Din + G * N], axis=-1)
    b, S = x.shape[:2]
    xs = xs.reshape(b, S, H, Din // H)
    B_ = B_.reshape(b, S, G, N)
    C_ = C_.reshape(b, S, G, N)
    dt = jax.nn.softplus(dt_raw + layer["dt_bias"])  # (b,S,H)
    A = -jnp.exp(layer["A_log"].astype(jnp.float32))
    init = state[1] if state is not None else None
    y, ssm_state = ssd_lib.ssd_scan_ref(
        xs.astype(jnp.float32), dt.astype(jnp.float32), A,
        B_.astype(jnp.float32), C_.astype(jnp.float32),
        min(cfg.ssd_chunk, S), initial_state=init,
    )
    y = y.astype(x.dtype) + xs * layer["D"][None, None, :, None]
    y = y.reshape(b, S, Din)
    y = L.rms_norm(y * jax.nn.silu(z), layer["norm"], cfg.norm_eps)
    out = jnp.einsum("bsp,pd->bsd", y, layer["out_proj"])
    # conv state for decode: last (k-1) *pre-activation* conv inputs
    k = layer["conv_w"].shape[0]
    conv_tail = zxbcdt[:, -(k - 1):, Din: 2 * Din + 2 * G * N]
    return x + out, (conv_tail, ssm_state)


def rec_block(x, layer, cfg, rules, state=None):
    h = L.rms_norm(x, layer["ln1"], cfg.norm_eps)
    out, new_state = rglru_lib.recurrent_block(h, layer, cfg, rules, state)
    x = x + out
    h = L.rms_norm(x, layer["ln2"], cfg.norm_eps)
    mlp = {k[4:]: v for k, v in layer.items() if k.startswith("mlp_")}
    return x + L.swiglu(h, mlp["w_gate"], mlp["w_up"], mlp["w_down"], rules), new_state


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _scan_layers(body, x, stacked, unroll: bool):
    """lax.scan over stacked layer params, or a true python unroll.

    The unroll path exists for roofline accounting: XLA's cost analysis
    counts a while-loop body ONCE regardless of trip count, so
    analysis/roofline.py compiles 1- and 2-layer unrolled variants to
    recover per-layer cost (see DESIGN.md §7)."""
    if not (unroll or unroll_lib.enabled()):
        return jax.lax.scan(body, x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        layer = jax.tree.map(lambda a: a[i], stacked)
        x, y = body(x, layer)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return x, ys


def stack_forward(cfg, params, rules, x, *, want_cache=False, cache_len=0,
                  unroll=False):
    """x: (B, S, D) embedded input. Returns (hidden (B,S,D), cache, aux)."""
    B, S, _ = x.shape
    aux_sum = {"load_balance": 0.0, "router_z": 0.0, "dropped_fraction": 0.0}

    if cfg.family == "ssm":

        def body(h, layer):
            h2, st = ssd_block(h, layer, cfg, rules)
            return h2, st if want_cache else None

        body = _ckpt(body, cfg)
        x, states = _scan_layers(body, x, params["layers"], unroll)
        cache = states if want_cache else None
        return x, cache, aux_sum

    if cfg.family == "hybrid":
        return _hybrid_forward(cfg, params, rules, x, want_cache, cache_len,
                               unroll=unroll)

    # dense / moe / vlm
    window = cfg.attn_window

    def body(h, layer):
        h2, kv, aux = attn_block(h, layer, cfg, rules, window=window)
        out = None
        if want_cache:
            out = _kv_to_cache(kv, cache_len, window)
        if cfg.family == "moe":
            out = (out, aux) if want_cache else aux
        return h2, out

    body = _ckpt(body, cfg)
    x, ys = _scan_layers(body, x, params["layers"], unroll)
    cache = None
    if cfg.family == "moe":
        if want_cache:
            cache, auxs = ys
        else:
            auxs = ys
        aux_sum = jax.tree.map(lambda a: jnp.mean(a), auxs)
    elif want_cache:
        cache = ys
    return x, cache, aux_sum


def _kv_to_cache(kv, cache_len, window):
    """(k, v) of (B, S, M, Dh) -> ring-buffer cache (B, M, T, Dh)."""
    k, v = kv
    S = k.shape[1]
    T = min(cache_len or S, window or S, S) if (window or cache_len) else S
    T = min(T, S)
    idx = jnp.arange(S - T, S, dtype=jnp.int32)
    slots = idx % T
    kk = jnp.zeros((k.shape[0], k.shape[2], T, k.shape[3]), k.dtype)
    kk = kk.at[:, :, slots, :].set(k[:, S - T :, :, :].transpose(0, 2, 1, 3))
    vv = jnp.zeros_like(kk)
    vv = vv.at[:, :, slots, :].set(v[:, S - T :, :, :].transpose(0, 2, 1, 3))
    return {"k": kk, "v": vv}


def _hybrid_forward(cfg, params, rules, x, want_cache, cache_len, unroll=False):
    types = hybrid_layer_types(cfg)
    pat = len(cfg.block_pattern)
    cycles = cfg.num_layers // pat
    rem = types[cycles * pat :]
    n_rec_cycle = cfg.block_pattern.count("rec")

    rec_p = params["rec_layers"]
    attn_p = params["attn_layers"]
    # Split stacks: per-cycle slices + remainder.
    rec_cycle = jax.tree.map(
        lambda a: a[: cycles * n_rec_cycle].reshape(
            (cycles, n_rec_cycle) + a.shape[1:]
        ),
        rec_p,
    )
    window = cfg.local_window

    def cycle_body(h, xs):
        rec_layers, attn_layer = xs
        states = []
        rj = 0
        for t in cfg.block_pattern:
            if t == "rec":
                idx = rj
                layer_j = jax.tree.map(lambda a: a[idx], rec_layers)
                h, st = rec_block(h, layer_j, cfg, rules)
                states.append(st)
                rj += 1
            else:
                h, kv, _ = attn_block(h, attn_layer, cfg, rules, window=window)
                states.append(_kv_to_cache(kv, cache_len, window) if want_cache else None)
        out = tuple(states) if want_cache else None
        return h, out

    cycle_body = _ckpt(cycle_body, cfg)
    x, cycle_states = _scan_layers(cycle_body, x, (rec_cycle, attn_p), unroll)

    rem_states = []
    rec_off = cycles * n_rec_cycle
    for i, t in enumerate(rem):
        layer = jax.tree.map(lambda a: a[rec_off + i], rec_p)
        x, st = rec_block(x, layer, cfg, rules)
        rem_states.append(st)

    cache = None
    if want_cache:
        cache = {"cycles": cycle_states, "rem": tuple(rem_states)}
    aux = {"load_balance": 0.0, "router_z": 0.0, "dropped_fraction": 0.0}
    return x, cache, aux


# ---------------------------------------------------------------------------
# Decode (single token with cache)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16, abstract=False):
    """Stacked per-layer decode state."""
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d)
    )
    n = cfg.num_layers
    if cfg.family == "ssm":
        Din, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        conv_dim = Din + 2 * G * N
        return {
            "conv": mk((n, batch, cfg.d_conv - 1, conv_dim), dtype),
            "ssm": mk((n, batch, H, Din // H, N), jnp.float32),
        }
    if cfg.family == "hybrid":
        types = hybrid_layer_types(cfg)
        n_rec, n_attn = types.count("rec"), types.count("attn")
        W = cfg.lru_width or cfg.d_model
        T = min(cache_len, cfg.local_window)
        M, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "conv": mk((n_rec, batch, 3, W), dtype),
            "lru": mk((n_rec, batch, W), jnp.float32),
            "k": mk((n_attn, batch, M, T, Dh), dtype),
            "v": mk((n_attn, batch, M, T, Dh), dtype),
        }
    T = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    M, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": mk((n, batch, M, T, Dh), dtype),
        "v": mk((n, batch, M, T, Dh), dtype),
    }


def cache_axes_tree(cfg, cache):
    """Logical axes for each cache leaf (for shardings)."""
    ax = {
        "k": ("layers", "batch", "kv_heads", "cache_seq", "head_dim"),
        "v": ("layers", "batch", "kv_heads", "cache_seq", "head_dim"),
        "conv": ("layers", "batch", "conv", "lru"),
        "lru": ("layers", "batch", "lru"),
        "ssm": ("layers", "batch", None, "head_dim", "state"),
    }
    return {k: ax[k] for k in cache}


def decode_stack(cfg, params, rules, x, cache, pos, unroll=False):
    """x: (B, 1, D); pos: scalar. Returns (hidden, new cache)."""
    if cfg.family == "ssm":

        def body(h, xs):
            layer, conv_st, ssm_st = xs
            h2, (conv2, ssm2) = _ssd_decode_block(h, layer, cfg, (conv_st, ssm_st))
            return h2, (conv2, ssm2)

        x, (conv, ssm) = _scan_layers(
            body, x, (params["layers"], cache["conv"], cache["ssm"]), unroll
        )
        return x, {"conv": conv, "ssm": ssm}

    if cfg.family == "hybrid":
        return _hybrid_decode(cfg, params, rules, x, cache, pos)

    window = cfg.attn_window

    def body(h, xs):
        layer, k, v = xs
        hn = L.rms_norm(h, layer["ln1"], cfg.norm_eps)
        out, kv2 = attn_lib.decode_attention(
            hn, layer, {"k": k, "v": v}, pos, cfg, rules, window=window
        )
        h = h + out
        hn = L.rms_norm(h, layer["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = moe_lib.moe_ffn_dispatch(hn, layer, cfg, rules)
        else:
            m = L.swiglu(hn, layer["w_gate"], layer["w_up"], layer["w_down"], rules)
        return h + m, (kv2["k"], kv2["v"])

    x, (k, v) = _scan_layers(
        body, x, (params["layers"], cache["k"], cache["v"]), unroll
    )
    return x, {"k": k, "v": v}


def _ssd_decode_block(x, layer, cfg, state):
    conv_st, ssm_st = state
    h = L.rms_norm(x, layer["ln"], cfg.norm_eps)
    Din, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,dp->bsp", h, layer["in_proj"])[:, 0]
    z, xBC_new, dt_raw = jnp.split(zxbcdt, [Din, 2 * Din + 2 * G * N], axis=-1)
    xBC, conv_st = ssd_lib.conv_decode_step(
        xBC_new, conv_st.astype(xBC_new.dtype), layer["conv_w"], layer["conv_b"]
    )
    xBC = jax.nn.silu(xBC)
    xs, B_, C_ = jnp.split(xBC, [Din, Din + G * N], axis=-1)
    b = x.shape[0]
    xs = xs.reshape(b, H, Din // H)
    B_ = B_.reshape(b, G, N)
    C_ = C_.reshape(b, G, N)
    dt = jax.nn.softplus(dt_raw + layer["dt_bias"])
    A = -jnp.exp(layer["A_log"].astype(jnp.float32))
    y, ssm_st = ssd_lib.ssd_decode_step(
        xs.astype(jnp.float32), dt.astype(jnp.float32), A,
        B_.astype(jnp.float32), C_.astype(jnp.float32), ssm_st
    )
    y = y.astype(x.dtype) + xs * layer["D"][None, :, None]
    y = y.reshape(b, Din)
    y = L.rms_norm(y * jax.nn.silu(z), layer["norm"], cfg.norm_eps)
    out = jnp.einsum("bp,pd->bd", y, layer["out_proj"])
    return x + out[:, None, :], (conv_st, ssm_st)


def _hybrid_decode(cfg, params, rules, x, cache, pos):
    types = hybrid_layer_types(cfg)
    ri, ai = 0, 0
    conv, lru = cache["conv"], cache["lru"]
    ks, vs = cache["k"], cache["v"]
    new_conv, new_lru, new_k, new_v = [], [], [], []
    for i, t in enumerate(types):
        if t == "rec":
            layer = jax.tree.map(lambda a: a[ri], params["rec_layers"])
            hn = L.rms_norm(x, layer["ln1"], cfg.norm_eps)
            out, (c2, l2) = rglru_lib.recurrent_block_decode(
                hn, layer, (conv[ri].astype(x.dtype), lru[ri])
            )
            x = x + out
            hn = L.rms_norm(x, layer["ln2"], cfg.norm_eps)
            mlp = {k[4:]: v for k, v in layer.items() if k.startswith("mlp_")}
            x = x + L.swiglu(hn, mlp["w_gate"], mlp["w_up"], mlp["w_down"], rules)
            new_conv.append(c2)
            new_lru.append(l2)
            ri += 1
        else:
            layer = jax.tree.map(lambda a: a[ai], params["attn_layers"])
            hn = L.rms_norm(x, layer["ln1"], cfg.norm_eps)
            out, kv2 = attn_lib.decode_attention(
                hn, layer, {"k": ks[ai], "v": vs[ai]}, pos, cfg, rules,
                window=cfg.local_window,
            )
            x = x + out
            hn = L.rms_norm(x, layer["ln2"], cfg.norm_eps)
            mlp = {k[4:]: v for k, v in layer.items() if k.startswith("mlp_")}
            x = x + L.swiglu(hn, mlp["w_gate"], mlp["w_up"], mlp["w_down"], rules)
            new_k.append(kv2["k"])
            new_v.append(kv2["v"])
            ai += 1
    return x, {
        "conv": jnp.stack(new_conv),
        "lru": jnp.stack(new_lru),
        "k": jnp.stack(new_k),
        "v": jnp.stack(new_v),
    }
