"""Common layers: norms, RoPE, MLPs, embeddings. Pure functions on jnp."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight + bias).astype(dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: (..., S, n, d_head) or (..., S, d_head);
    positions: (..., S) absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    while angles.ndim < x.ndim:
        angles = angles[..., None, :]  # broadcast over head dims
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down, rules=None):
    """SwiGLU MLP. x: (B, S, D); w_gate/w_up: (D, F); w_down: (F, D)."""
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g) * u
    if rules is not None:
        h = rules.constraint(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_in) + b_in)
    return jnp.einsum("bsf,fd->bsd", h, w_out) + b_out


def embed(tokens, table):
    return table[tokens]


def unembed(x, table, rules=None):
    """x: (B, S, D); table: (V, D) -> logits (B, S, V)."""
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    if rules is not None:
        logits = rules.constraint(logits, "batch", "seq", "vocab")
    return logits


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32)
        * (-jnp.log(jnp.float32(10000.0)) / dim)
    )
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def cross_entropy_loss(logits, labels, mask=None):
    """Mean CE over valid positions; logits (B, S, V), labels (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
