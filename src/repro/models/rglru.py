"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    log_a_t = -c * softplus(Lambda) * r_t   # c = 8
    h_t = exp(log_a_t) * h_{t-1} + sqrt(1 - exp(2 log_a_t)) * (i_t * x_t)

The linear recurrence is computed with a log-depth associative scan
(TPU-native — no sequential loop over S). The enclosing recurrent block is
Griffin's: two branches (GeLU gate, temporal-conv + RG-LRU), multiplied,
projected out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssd import causal_conv1d, conv_decode_step

C_FACTOR = 8.0


def _gates(x, p):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_x"]) + p["b_x"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r  # (B, S, W)
    return log_a, i


def rglru_scan(x, p, initial_state=None):
    """x: (B, S, W). Returns (h (B,S,W), final state (B,W))."""
    log_a, gate_i = _gates(x, p)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gx = beta * (gate_i * x)

    def combine(left, right):
        a1, h1 = left
        a2, h2 = right
        return a1 * a2, a2 * h1 + h2

    a_s, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    if initial_state is not None:
        h = h + a_s * initial_state[:, None, :]
    return h, h[:, -1, :]


def rglru_decode_step(x, p, state):
    """x: (B, W); state: (B, W)."""
    log_a, gate_i = _gates(x[:, None, :], p)
    log_a, gate_i = log_a[:, 0], gate_i[:, 0]
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * state + beta * (gate_i * x)
    return h, h


def recurrent_block(x, p, cfg, rules=None, state=None):
    """Griffin recurrent block, full-sequence. x: (B, S, D).
    Returns (out (B,S,D), (conv_tail, lru_state))."""
    y_gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gelu"]))
    xl = jnp.einsum("bsd,dw->bsw", x, p["w_lin"])
    if rules is not None:
        xl = rules.constraint(xl, "batch", "seq", "lru")
    xc = causal_conv1d(xl, p["conv_w"], p["conv_b"])
    h, lru_state = rglru_scan(xc, p, initial_state=state[1] if state else None)
    out = jnp.einsum("bsw,wd->bsd", y_gate * h, p["w_out"])
    k = p["conv_w"].shape[0]
    conv_tail = xl[:, -(k - 1):, :]
    return out, (conv_tail, lru_state)


def recurrent_block_decode(x, p, state):
    """One-token decode. x: (B, 1, D); state = (conv_state (B,k-1,W),
    lru_state (B,W))."""
    conv_state, lru_state = state
    x0 = x[:, 0, :]
    y_gate = jax.nn.gelu(jnp.einsum("bd,dw->bw", x0, p["w_gelu"]))
    xl = jnp.einsum("bd,dw->bw", x0, p["w_lin"])
    xc, conv_state = conv_decode_step(xl, conv_state, p["conv_w"], p["conv_b"])
    h, lru_state = rglru_decode_step(xc, p, lru_state)
    out = jnp.einsum("bw,wd->bd", y_gate * h, p["w_out"])
    return out[:, None, :], (conv_state, lru_state)
