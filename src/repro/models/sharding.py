"""Logical-axis sharding rules (t5x/MaxText-style), with divisibility guard.

Every parameter and activation in the model zoo is annotated with *logical*
axis names; a ``MeshRules`` table maps logical axes to mesh axes. This makes
sharding data-driven: the §Perf hillclimb edits rules, not model code.

The guard: pjit requires input dims to divide evenly by the mesh-axis
product. When a logical dim is not divisible (e.g. qwen3's 40 heads over a
16-way model axis), the rule is dropped to replicated **and the event is
recorded** — the dry-run report surfaces these so the waste is visible in
the roofline table instead of silently changing the model (no padding of
real head counts).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, tuple, None]

# Default logical->mesh mapping (the paper-faithful GSPMD baseline).
DEFAULT_RULES: dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,
    "q_seq": None,  # query-seq sharding for attn when heads don't divide
    "embed": None,
    "embed_fsdp": "data",  # FSDP dim on params
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": None,  # experts use TP-within-expert on 'mlp' by default
    "expert_cap": None,
    "cache_seq": "model",  # decode KV caches shard the sequence dim
    "state": None,  # SSM state
    "lru": "model",  # RG-LRU width
    "conv": None,
    "frames": None,
    "layers": None,
    "patches": None,
}


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    rules: dict[str, Axis]
    dropped: list = dataclasses.field(default_factory=list)

    @classmethod
    def for_mesh(cls, mesh: Mesh, overrides: Optional[dict] = None) -> "MeshRules":
        rules = dict(DEFAULT_RULES)
        if overrides:
            rules.update(overrides)
        # Prune mesh axes that don't exist (e.g. 'pod' on single-pod mesh).
        names = set(mesh.axis_names)

        def prune(v):
            if v is None:
                return None
            if isinstance(v, str):
                return v if v in names else None
            t = tuple(a for a in v if a in names)
            return t if t else None

        return cls(mesh=mesh, rules={k: prune(v) for k, v in rules.items()})

    def _axis_size(self, v: Axis) -> int:
        if v is None:
            return 1
        if isinstance(v, str):
            return self.mesh.shape[v]
        return int(np.prod([self.mesh.shape[a] for a in v]))

    def spec(self, shape: tuple, axes: tuple) -> P:
        """PartitionSpec for `shape` with logical `axes`, guarding
        divisibility and duplicate mesh-axis use."""
        assert len(shape) == len(axes), (shape, axes)
        used: set[str] = set()
        out = []
        for dim, ax in zip(shape, axes):
            v = self.rules.get(ax) if ax is not None else None
            if v is not None:
                size = self._axis_size(v)
                mesh_axes = (v,) if isinstance(v, str) else tuple(v)
                if dim % size != 0:
                    self.dropped.append((axes, ax, dim, size, "indivisible"))
                    v = None
                elif any(m in used for m in mesh_axes):
                    self.dropped.append((axes, ax, dim, size, "duplicate"))
                    v = None
                else:
                    used.update(mesh_axes)
            out.append(v)
        return P(*out)

    def sharding(self, shape: tuple, axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constraint(self, x, *axes):
        """Apply a sharding constraint to an activation."""
        import jax

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x.shape, axes))
        )


@dataclasses.dataclass
class NullRules:
    """No-op rules for single-device smoke tests."""

    def spec(self, shape, axes) -> P:
        return P()

    def constraint(self, x, *axes):
        return x


def spec_tree(params_with_axes):
    """Split a tree of (array_or_struct, axes) leaves into (arrays, specs)."""
    import jax

    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], tuple)
    arrays = jax.tree.map(lambda x: x[0], params_with_axes, is_leaf=is_leaf)
    axes = jax.tree.map(lambda x: x[1], params_with_axes, is_leaf=is_leaf)
    return arrays, axes
