"""Attention: GQA with optional qk-norm, QKV biases, sliding/local windows,
RoPE, and a unified KV cache (linear or rolling ring buffer for SWA).

Shapes: H query heads grouped over M kv heads (G = H // M). Attention math
is written grouped — (B, S, M, G, Dh) — so kv-head sharding composes with
GQA without materializing repeated K/V.

Cache contract (decode): ``cache`` is a dict with k/v of shape
(B, M, T, Dh) where T = allocated slots (full length, or the window for
SWA archs). Slot for absolute position p is ``p % T`` (identical for the
linear case since p < T). Keys are stored *post-RoPE at absolute
positions*, so relative attention holds in the ring buffer. Slot validity
for query position `pos`: slot i holds absolute position
``pos - ((pos - i) mod T)``; valid iff that is >= 0 (and automatically
within the window by construction).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models import unroll as unroll_lib

NEG_INF = -1e30


def qkv_project(x, p, cfg, rules, positions):
    """x: (B, S, D) -> q (B,S,M,G,Dh), k,v (B,S,M,Dh), roped."""
    H, M, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // M
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dmk->bsmk", x, p["wk"])
    v = jnp.einsum("bsd,dmk->bsmk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    B, S = x.shape[:2]
    q = q.reshape(B, S, M, G, Dh)
    return q, k, v


def attend(q, k, v, mask, cfg, rules=None):
    """q: (B,Sq,M,G,Dh); k,v: (B,Sk,M,Dh); mask broadcastable to
    (B,M,G,Sq,Sk). Returns (B,Sq,H,Dh)."""
    scale = cfg.resolved_head_dim**-0.5
    logits = jnp.einsum("bsmgk,btmk->bmgst", q, k) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bmgst,btmk->bsmgk", probs, v)
    B, Sq = out.shape[0], out.shape[1]
    return out.reshape(B, Sq, cfg.num_heads, cfg.resolved_head_dim)


def causal_window_mask(sq: int, sk_offset: int, sk: int, window: Optional[int]):
    """(Sq, Sk) mask; query i is at absolute position sk_offset + i."""
    qpos = sk_offset + jnp.arange(sq, dtype=jnp.int32)[:, None]
    kpos = jnp.arange(sk, dtype=jnp.int32)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def attend_chunked(q, k, v, cfg, *, causal=True, window=None, chunk=1024,
                   unroll=False):
    """Online-softmax attention over KV chunks (flash-attention algorithm
    in pure XLA — the jnp oracle for kernels/flash_attention).

    Never materializes (Sq, Sk) — peak intermediate is (Sq, chunk). For
    causal masks, chunks strictly above the diagonal contribute nothing but
    are still computed (static shapes); the Pallas kernel skips them.

    q: (B,Sq,M,G,Dh); k,v: (B,Sk,M,Dh). Returns (B,Sq,H,Dh).
    """
    B, Sq, M, G, Dh = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0, (Sk, chunk)
    nch = Sk // chunk
    scale = cfg.resolved_head_dim**-0.5
    q = q * scale

    kc = k.reshape(B, nch, chunk, M, Dh)
    vc = v.reshape(B, nch, chunk, M, Dh)
    qpos = jnp.arange(Sq, dtype=jnp.int32)[:, None]

    def body(carry, xs):
        m, l, acc = carry
        j, kj, vj = xs
        logits = jnp.einsum("bsmgk,btmk->bmgst", q, kj).astype(jnp.float32)
        kpos = j * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bmgst,btmk->bsmgk", p.astype(q.dtype), vj)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None].astype(acc.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, M, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, M, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, M, G, Dh), q.dtype)
    xs = (jnp.arange(nch, dtype=jnp.int32), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4))
    if unroll or unroll_lib.enabled():
        carry = (m0, l0, acc0)
        for j in range(nch):
            carry, _ = body(carry, (jnp.asarray(j), kc[:, j], vc[:, j]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None].astype(acc.dtype)
    return out.reshape(B, Sq, cfg.num_heads, cfg.resolved_head_dim)


def flash_sharded(q, k, v, cfg, rules, *, causal=True, window=None):
    """Pallas flash-attention under a full shard_map: the (B, M, G) planes
    shard over the data axes, the model axis is replicated (attention at
    these shapes is data-parallel). HBM traffic = Q+K+V+O (the kernel's
    VMEM contract). Forward-only — used for prefill/decode, not train.

    Falls back to the chunked XLA path when there is no mesh or the plane
    count doesn't divide the data axes."""
    from repro.kernels.flash_attention.kernel import flash_attention_bhsd

    B, Sq, M, G, Dh = q.shape
    Sk = k.shape[1]
    blk = max(min(512, Sq, Sk), 128)
    if rules is None or not hasattr(rules, "mesh"):
        return attend_chunked(q, k, v, cfg, causal=causal, window=window,
                              chunk=cfg.attn_chunk)
    mesh = rules.mesh
    manual = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    import numpy as _np

    dsize = int(_np.prod([mesh.shape[a] for a in manual])) if manual else 1
    if not manual or (B * M * G) % dsize or Sq % blk or Sk % blk:
        return attend_chunked(q, k, v, cfg, causal=causal, window=window,
                              chunk=cfg.attn_chunk)
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * M * G, Sq, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * M * G, Sk, Dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * M * G, Sk, Dh)
    from jax.sharding import PartitionSpec as P

    spec = P(manual)

    def inner(ql, kl, vl):
        return flash_attention_bhsd(
            ql, kl, vl, causal=causal, window=window, blk_q=blk, blk_k=blk,
            interpret=True,
        )

    from repro.core import compat

    out = compat.shard_map(
        inner, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
    )(qf, kf, vf)
    return out.reshape(B, M, G, Sq, Dh).transpose(0, 3, 1, 2, 4).reshape(
        B, Sq, M * G, Dh
    )


def self_attention(x, p, cfg, rules, *, window=None, causal=True, pos_offset=0,
                   unroll=False):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    positions = pos_offset + jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = qkv_project(x, p, cfg, rules, positions)
    if rules is not None:
        q = rules.constraint(q, "batch", "q_seq", "kv_heads", None, "head_dim")
        k = rules.constraint(k, "batch", "seq", "kv_heads", "head_dim")
        v = rules.constraint(v, "batch", "seq", "kv_heads", "head_dim")
    if cfg.attn_impl == "flash":
        out = flash_sharded(q, k, v, cfg, rules, causal=causal, window=window)
    elif cfg.attn_impl == "chunked":
        out = attend_chunked(
            q, k, v, cfg, causal=causal, window=window,
            chunk=cfg.attn_chunk, unroll=unroll,
        )
    else:
        if causal:
            mask = causal_window_mask(S, 0, S, window)[None, None, None]
        else:
            mask = jnp.ones((1, 1, 1, S, S), bool)
        out = attend(q, k, v, mask, cfg, rules)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def init_cache_entry(cfg, batch: int, alloc: int, dtype=jnp.bfloat16):
    M, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, M, alloc, Dh), dtype),
        "v": jnp.zeros((batch, M, alloc, Dh), dtype),
    }


def cache_entry_struct(cfg, batch: int, alloc: int, dtype=jnp.bfloat16):
    M, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    s = jax.ShapeDtypeStruct((batch, M, alloc, Dh), dtype)
    return {"k": s, "v": s}


def cache_axes():
    return ("batch", "kv_heads", "cache_seq", "head_dim")


def decode_attention(x, p, cache, pos, cfg, rules, *, window=None):
    """Single-token decode. x: (B, 1, D); pos: scalar absolute position.
    Returns (out (B,1,D), updated cache)."""
    B = x.shape[0]
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k_new, v_new = qkv_project(x, p, cfg, rules, positions)
    T = cache["k"].shape[2]
    slot = (pos % T).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype).transpose(0, 2, 1, 3), slot, 2
    )
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype).transpose(0, 2, 1, 3), slot, 2
    )
    # Slot validity (see module docstring).
    i = jnp.arange(T, dtype=jnp.int32)
    slot_pos = pos - ((pos - i) % T)
    valid = slot_pos >= 0
    if window is not None:
        valid = valid & (slot_pos > pos - window)
    mask = valid[None, None, None, None, :]  # (1,1,1,1,T)
    kk = k.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, T, M, Dh) view
    vv = v.transpose(0, 2, 1, 3).astype(q.dtype)
    out = attend(q, kk, vv, mask, cfg, rules)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": k, "v": v}
