"""The resident simulation server: compile once, serve forever.

A :class:`SimulationServer` keeps a BoundedLRU table of warm buckets —
each a resident :class:`~repro.engine.core.EngineCore` compiled for one
:class:`~repro.serve.buckets.BucketKey` — and serves admitted
:class:`~repro.api.spec.ExperimentSpec` requests by **batching them onto
the scenario axis** of the bucket's already-compiled scan:

1. each request's scenarios are packed into consecutive slots of the
   bucket's width-``b_bucket`` batch; leftover slots run inert
   :func:`~repro.engine.core.no_op_params`;
2. the dispatch runs as ``n_chunks`` invocations of ONE compiled runner
   (``chunk_days`` days each, ``observables=()``), streaming each chunk's
   day stats to every request's ticket as it leaves the device;
3. each request's history is sliced back out of its slot columns and
   trimmed to its own day count, observables are replayed post-run with
   the request's own ObsContext, and a RunResult is produced.

Bitwise contract (test-enforced in tests/test_serve.py): a served result
equals a solo ``api.run`` of the same spec bit for bit — scenario slots
are vmapped and independent, no-op padding is inert, chunked scans equal
unchunked ones, history prefixes are causal, and observable replay is a
pure reduction of the history.

Zero-recompile contract: once a bucket's runner is compiled (its first
dispatch, or :meth:`SimulationServer.warm_up`), every later dispatch of
that bucket runs inside :class:`repro.analysis.hlo.recompile_sentinel`.
A cache miss in steady state trips the sentinel: counted in
``metrics.executables.recompile_violations`` and — under
``ServeConfig.strict`` — failing the batch loudly instead of silently
eating a compile.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

import numpy as np
import jax

from repro.analysis import hlo
from repro.analysis.report import summarize_sweep
from repro.api import observables as obs_lib
from repro.api.result import RunResult
from repro.api.runner import _sweep_axes
from repro.api.spec import ExperimentSpec
from repro.configs import get_epidemic
from repro.engine import core as engine_lib
from repro.engine.cache import BoundedLRU
from repro.serve.batcher import (
    RequestBatcher,
    ServeError,
    ServeRequest,
    ServeTicket,
)
from repro.serve.buckets import BucketKey, ServeConfig, bucketize
from repro.serve.metrics import ServeMetrics


class WarmBucket:
    """One resident executable: an EngineCore built for a bucket key,
    its cached stacked initial state (identical for every request in the
    bucket — it is a function of disease + slot count only), and dispatch
    bookkeeping."""

    def __init__(self, key: BucketKey, core, pop, chunk_days: int):
        self.key = key
        self.core = core
        self.pop = pop
        self.chunk_days = chunk_days
        self.init = core.init_state()  # reused: run_days never mutates it
        self.dispatches = 0
        self.compile_s: Optional[float] = None

    def runner(self):
        """The one jitted callable this bucket ever runs — the sentinel
        watches exactly this object's jit cache."""
        return self.core.runner_fn(self.chunk_days, ())

    def is_warm(self) -> bool:
        return self.core.runner_cached(self.chunk_days, ())


class SimulationServer:
    """Request queue + warm bucket table + dispatch loop.

    Usable two ways: synchronously (``submit`` then ``drain``, or the
    one-call :meth:`run`) — what tests and benchmarks do — or with a
    background dispatch thread (``start``/``stop``, or as a context
    manager) so ``submit`` returns immediately and tickets stream."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = (config or ServeConfig()).validate()
        self.metrics = ServeMetrics()
        self._pops: Dict[str, object] = {}
        self._evicted_labels: List[str] = []
        self._buckets: BoundedLRU = BoundedLRU(
            max_entries=self.config.max_executables,
            on_evict=lambda k, b: self._evicted_labels.append(k.label()),
        )
        self._batcher = RequestBatcher()
        self._lock = threading.Lock()  # guards the pending queue
        self._cv = threading.Condition(self._lock)
        self._dispatch_lock = threading.Lock()  # serializes device work
        # One finisher thread keeps per-request host work (observable
        # replay, result assembly) off the dispatch loop, FIFO-ordered.
        self._finisher = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="sim-serve-finish")
        # Jitted replay cache: eager scan_history re-traces ~100ms per
        # request; a resident server serves the same (observables, shape)
        # replay over and over, so the traced scan is cached like any
        # other executable here. Same ops, same order — the bitwise
        # parity with solo runs is asserted in tests/test_serve.py.
        self._replays: BoundedLRU = BoundedLRU(max_entries=32)
        self._thread: Optional[threading.Thread] = None
        self._stopping = False

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "SimulationServer":
        if self._thread is not None:
            return self
        self._stopping = False
        self._thread = threading.Thread(
            target=self._loop, name="sim-serve-dispatch", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        if self._thread is None:
            return
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()
        else:
            self.flush()

    def __enter__(self) -> "SimulationServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.stop()
        return False

    # -- admission -------------------------------------------------------
    def submit(self, spec: ExperimentSpec) -> ServeTicket:
        """Admit a spec: validate, normalize onto the bucket lattice,
        enqueue. Raises ValueError (and counts a rejection) for specs the
        serving tier refuses — see :func:`repro.serve.buckets.bucketize`."""
        try:
            spec = spec.validate()
            shape = bucketize(spec, self.config)
        except ValueError:
            self.metrics.on_reject()
            raise
        req = ServeRequest(spec, shape)
        self.metrics.on_submit()
        with self._cv:
            self._batcher.add(req)
            self._cv.notify_all()
        return ServeTicket(req)

    def run(self, spec: ExperimentSpec,
            timeout: Optional[float] = None) -> RunResult:
        """Submit one spec and block for its result (drains inline when
        no dispatch thread is running)."""
        ticket = self.submit(spec)
        if self._thread is None:
            self.drain()
        return ticket.result(timeout=timeout)

    def drain(self) -> int:
        """Dispatch every pending request in the caller's thread and wait
        out the finisher backlog; returns the number of batches."""
        n = 0
        while True:
            with self._lock:
                group = self._batcher.take_group()
            if not group:
                self.flush()
                return n
            self._dispatch(group)
            n += 1

    def flush(self) -> None:
        """Block until every already-dispatched request has finished
        (the finisher queue is FIFO, so a barrier job suffices)."""
        self._finisher.submit(lambda: None).result()

    def pending(self) -> int:
        with self._lock:
            return len(self._batcher)

    # -- warmup ----------------------------------------------------------
    def warm_up(self, spec: ExperimentSpec) -> dict:
        """Build the bucket a spec lands in and compile its runner by
        priming it with an all-no-op batch (no request is served).
        Returns ``{"bucket", "already_warm", "compile_s"}``; after this,
        every dispatch of the bucket must be recompile-free."""
        spec = spec.validate()
        shape = bucketize(spec, self.config)
        with self._dispatch_lock:
            bucket = self._bucket_for(spec, shape.bucket)
            if bucket.is_warm():
                return {"bucket": bucket.key.label(), "already_warm": True,
                        "compile_s": bucket.compile_s}
            slots = len(bucket.core.padded)
            noop = engine_lib.stack_params([
                engine_lib.no_op_params(
                    engine_lib.index_params(bucket.core.params, i))
                for i in range(slots)
            ])
            t0 = time.time()
            bucket.core.run_days(self.config.chunk_days, params=noop,
                                 state=bucket.init)
            bucket.compile_s = time.time() - t0
            self.metrics.on_batch(real=0, padded=slots, warm=False, chunks=1)
            return {"bucket": bucket.key.label(), "already_warm": False,
                    "compile_s": bucket.compile_s}

    # -- readout ---------------------------------------------------------
    def metrics_dict(self) -> dict:
        return self.metrics.to_dict(bucket_stats={
            "table": self._buckets.stats(),
            "resident": [k.label() for k in self._buckets],
            "evicted": list(self._evicted_labels),
        })

    # -- internals -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._stopping and len(self._batcher) == 0:
                    self._cv.wait(timeout=0.1)
                if self._stopping:
                    return
            # Batching window: linger briefly so concurrent same-bucket
            # submissions share the dispatch instead of trickling.
            if self.config.max_wait_s > 0:
                time.sleep(self.config.max_wait_s)
            with self._lock:
                group = self._batcher.take_group()
            if group:
                self._dispatch(group)

    def _pop(self, dataset: str):
        pop = self._pops.get(dataset)
        if pop is None:
            pop = get_epidemic(dataset).build()
            self._pops[dataset] = pop
        return pop

    def _bucket_for(self, spec: ExperimentSpec, key: BucketKey) -> WarmBucket:
        """Fetch (recency-bumping) or build the bucket for ``key``. Called
        under the dispatch lock only."""
        bucket = self._buckets.get(key)
        if bucket is not None:
            return bucket
        pop = self._pop(spec.dataset)
        # The template batch only supplies trace-time structure (slot
        # kinds, width); every dispatch passes its own traced params.
        template = engine_lib.pad_batch(spec.build_batch(), key.b_bucket)
        core = engine_lib.EngineCore(
            pop, template,
            layout=self.config.layout,
            workers=self.config.workers,
            scen_shards=self.config.scen_shards,
            backend=key.backend,
            block_size=key.block_size,
            pack_visits=key.pack_visits,
            max_seed_per_day=key.seed_cap,
            max_runners=2,  # serving uses exactly one (chunk_days, ())
        )
        bucket = WarmBucket(key, core, pop, self.config.chunk_days)
        self._buckets.put(key, bucket)
        return bucket

    def _build_dispatch_params(self, bucket: WarmBucket,
                               group: List[ServeRequest]):
        """Pack the group's scenarios into the bucket's slots: request
        scenarios in FIFO order, then no-op padding. Returns
        ``(stacked_params, cols, names, n_real)`` where ``cols[i]`` is
        request i's ``(offset, width)`` column slice and ``names[i]`` its
        scenario names."""
        core = bucket.core
        scen, cols, names = [], [], []
        for req in group:
            b = req.spec.build_batch()
            cols.append((len(scen), len(b)))
            names.append(b.names)
            scen.extend(b.scenarios)
        n_real = len(scen)
        from repro.configs.sweep import ScenarioBatch
        dispatch = engine_lib.pad_batch(
            engine_lib.pad_batch(ScenarioBatch(scenarios=tuple(scen)),
                                 bucket.key.b_bucket),
            core.scen_shards,
        )
        iv_slots, pa_slots, plist = engine_lib.build_batch_params(
            bucket.pop, dispatch)
        if (iv_slots, pa_slots) != (core.iv_slots, core.pa_slots):
            raise ServeError(
                f"dispatch slot structure {iv_slots + pa_slots} does not "
                f"match bucket '{bucket.key.label()}' structure "
                f"{core.iv_slots + core.pa_slots}")
        if core.plan is not None:  # worker-sharded layouts pad people axes
            from repro.core import simulator_dist as sd
            plist = [sd.pad_params(p, core.plan) for p in plist]
        for i in range(n_real, len(plist)):
            plist[i] = engine_lib.no_op_params(plist[i])
        if len(plist) != len(core.padded):
            raise ServeError(
                f"dispatch width {len(plist)} != bucket width "
                f"{len(core.padded)}")
        return engine_lib.stack_params(plist), cols, names, n_real

    def _dispatch(self, group: List[ServeRequest]) -> None:
        """Run one batched dispatch end to end. All device work happens
        here, serialized by the dispatch lock."""
        with self._dispatch_lock:
            now = time.time()
            for req in group:
                req.dispatched_at = now
            try:
                self._dispatch_inner(group)
            except BaseException as err:  # noqa: BLE001 - requests must resolve
                self.metrics.on_fail(len(group))
                for req in group:
                    req.fail(err)

    def _dispatch_inner(self, group: List[ServeRequest]) -> None:
        shape = group[0].shape
        bucket = self._bucket_for(group[0].spec, shape.bucket)
        params, cols, names, n_real = self._build_dispatch_params(
            bucket, group)
        chunk_days = self.config.chunk_days
        n_chunks = shape.n_chunks
        warm = bucket.is_warm()
        runner = bucket.runner()

        hists: List[dict] = []

        def run_chunks():
            state = bucket.init
            for c in range(n_chunks):
                state, _, hist, _ = bucket.core.run_days(
                    chunk_days, params=params, state=state)
                hists.append(hist)
                day0 = c * chunk_days
                for req, (off, width) in zip(group, cols):
                    take = min(req.spec.days, day0 + chunk_days) - day0
                    if take > 0:
                        req.push_chunk(day0, take, {
                            k: v[:take, off:off + width]
                            for k, v in hist.items()
                        })

        t0 = time.time()
        try:
            if warm:
                # Steady state: the jit cache must not grow. The sentinel
                # re-raises nothing mid-run — it checks at exit, so a trip
                # means the work finished but paid a hidden compile.
                with hlo.recompile_sentinel(runner):
                    run_chunks()
            else:
                run_chunks()  # the bucket's one legitimate compile
        except AssertionError as err:
            self.metrics.on_recompile_violation()
            if self.config.strict:
                raise ServeError(
                    f"steady-state recompile in bucket "
                    f"'{bucket.key.label()}': {err}") from err
            # Non-strict: the results are still valid (the dispatch ran to
            # completion before the sentinel checked) — serve them, counted.
        wall = time.time() - t0
        bucket.dispatches += 1
        padded = len(bucket.core.padded) - n_real
        self.metrics.on_batch(real=n_real, padded=padded, warm=warm,
                              chunks=n_chunks)

        full = {
            k: np.concatenate([h[k] for h in hists], axis=0)
            for k in hists[0]
        }
        # Per-request finishing (observable replay, summaries, RunResult
        # assembly) is host work off the compiled path — hand it to the
        # finisher thread so the dispatch loop moves straight to the next
        # group's device work instead of serializing behind replays.
        jobs = []
        for i, (req, (off, width)) in enumerate(zip(group, cols)):
            hist_r = {
                k: v[:req.spec.days, off:off + width] for k, v in full.items()
            }
            jobs.append((req, hist_r, names[i], off))
        self._finisher.submit(self._finish_group, jobs, bucket, warm, wall,
                              len(group))

    def _finish_group(self, jobs, bucket: WarmBucket, warm: bool,
                      wall: float, batch_requests: int) -> None:
        for req, hist_r, scenario_names, off in jobs:
            try:
                result = self._finish(req, bucket, hist_r, scenario_names,
                                      off, warm=warm, wall=wall,
                                      batch_requests=batch_requests)
            except BaseException as err:  # noqa: BLE001 - must resolve
                self.metrics.on_fail(1)
                req.fail(err)
                continue
            req.done_at = time.time()  # stamp before metrics + wakeup so
            # a caller unblocked by finish() reads its own completion.
            if req.ttfd_s is not None:
                self.metrics.on_first_day(req.ttfd_s)
            self.metrics.on_complete(req.latency_s, req.queue_wait_s)
            req.finish(result)

    def _finish(self, req: ServeRequest, bucket: WarmBucket, hist: dict,
                scenario_names, slot_offset: int, *, warm: bool,
                wall: float, batch_requests: int) -> RunResult:
        """Assemble the request's RunResult exactly the way api.run does:
        replayed observables (pure reductions => bitwise-equal to
        in-scan), sweep summaries, provenance + ``served_from``."""
        spec = req.spec
        B = req.shape.b_request
        sweep_axes = _sweep_axes(spec, B)
        key = (spec.observables, spec.days, B, sweep_axes,
               bucket.pop.num_people)
        cached = self._replays.get(key)
        if cached is None:
            observables = obs_lib.make_observables(spec.observables)
            ctx = obs_lib.ObsContext(
                num_people=bucket.pop.num_people, num_scenarios=B,
                sweep_axes=sweep_axes,
            )
            scan = jax.jit(
                lambda h: obs_lib.scan_history(observables, h, ctx))
            cached = (observables, ctx, scan)
            self._replays.put(key, cached)
        observables, ctx, scan = cached
        carries, dailies = scan(hist)
        obs = obs_lib.observables_to_numpy(
            obs_lib.finalize_all(observables, carries, dailies, ctx))
        summaries = summarize_sweep(hist, scenario_names,
                                    bucket.pop.num_people)
        core = bucket.core
        provenance = {
            "engine": f"serve[{core.layout}]",
            "layout": core.layout,
            "topology": type(core.topo).__name__,
            "num_people": int(bucket.pop.num_people),
            "mesh": {"workers": core.workers, "scenarios": core.scen_shards},
            "num_devices": len(jax.devices()),
            "jax_backend": jax.default_backend(),
            "wall_s": round(req.latency_s or wall, 3),
            "run_wall_s": round(wall, 3),
            "chunks": req.shape.n_chunks,
            "chunk_days": self.config.chunk_days,
            "resumed_from_day": 0,
            "observables_in_scan": False,
            "core": engine_lib.CORE_VERSION,
            "served_from": {
                "bucket": bucket.key.label(),
                "b_bucket": bucket.key.b_bucket,
                "seed_cap": bucket.key.seed_cap,
                "slot_offset": int(slot_offset),
                "slots": int(B),
                "batch_requests": int(batch_requests),
                "warm": bool(warm),
                "chunk_days": self.config.chunk_days,
                "padded_days": req.shape.n_chunks * self.config.chunk_days,
                "dispatch_wall_s": round(wall, 3),
            },
        }
        if "teps" in obs:
            provenance["edges_total"] = float(obs["teps"]["edges_total"])
            provenance["teps"] = (
                float(obs["teps"]["edges_total"]) / max(wall, 1e-9))
        return RunResult(
            spec=spec,
            scenario_names=scenario_names,
            history=hist,
            observables=obs,
            summaries=summaries,
            provenance=provenance,
        )
