"""Shape buckets: normalizing heterogeneous specs onto a small executable
lattice.

The serving tier's economics rest on one fact about the engine: the
compiled day-loop scan is shaped *only* by a handful of static facts —
the dataset's padded person/location/visit axes, the batch's intervention
slot structure, the backend and its block size, the scenario-axis width B,
and the static seeding/testing top-k caps. Everything else (tau, seeds,
intervention on/off masks, seeding schedules) is a traced parameter: one
warm executable serves any request whose *statics* match.

So a :class:`BucketKey` is exactly that static tuple, with the two
request-varying axes quantized UP onto a small lattice:

- **B (scenario width)** → the smallest lattice width >= the request's
  batch. The lattice floor doubles as the cross-request batching width:
  two 2-scenario requests both land in the width-4 bucket and share one
  dispatch, padded slots running inert :func:`~repro.engine.core.
  no_op_params`.
- **seeding cap** (``seed_per_day``) → the smallest lattice cap >= the
  request's. Quantizing the static top-k width up is bitwise-safe: the
  local topology's threshold ignores the hint entirely (full sort), and
  the mesh topologies are exact whenever the hint covers the actual
  budget — which "quantize up" guarantees.
- **days** is *not* part of the executable identity at all: the server
  runs every request through fixed ``chunk_days`` chunks of the same
  compiled runner and trims each request's history to its own length
  (the scan is causal, so a prefix of a longer run is bitwise-identical
  to a shorter run). Days only group dispatches: requests batched
  together must want the same chunk count.

The person/location/visit axes need no lattice of their own here — they
are a pure function of ``(dataset, block_size, pack_visits)``, which the
fingerprint already pins.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.api.spec import ExperimentSpec


def quantize_up(value: int, lattice: Tuple[int, ...]) -> int:
    """The smallest lattice point >= ``value``; beyond the lattice, the
    next power of two (so oversized requests still get a stable, reusable
    bucket instead of an exact one-off width)."""
    if value < 1:
        raise ValueError(f"cannot bucket a size < 1, got {value}")
    for point in sorted(lattice):
        if value <= point:
            return int(point)
    return 1 << max(0, (value - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Capacity knobs for a :class:`~repro.serve.server.SimulationServer`.

    ``b_lattice``'s smallest point is the default batching width — keep it
    >= the typical concurrent-request width so requests actually share
    dispatches. ``chunk_days`` is the streaming granularity AND the one
    day-count every executable is compiled for. ``max_executables`` bounds
    the warm bucket table (LRU beyond it); ``strict`` makes any post-warmup
    recompile a request-failing error rather than just a counted one."""

    layout: str = "local"  # engine-core layout for every bucket
    workers: int = 1
    scen_shards: int = 1
    chunk_days: int = 8
    b_lattice: Tuple[int, ...] = (4, 8)
    seed_lattice: Tuple[int, ...] = (16, 64, 256)
    max_executables: int = 4
    max_wait_s: float = 0.002  # batching window: how long dispatch lingers
    #: for more same-bucket requests before running a partial batch.
    strict: bool = True

    def validate(self) -> "ServeConfig":
        if self.chunk_days < 1:
            raise ValueError("chunk_days must be >= 1")
        if not self.b_lattice or min(self.b_lattice) < 1:
            raise ValueError("b_lattice needs at least one width >= 1")
        if not self.seed_lattice or min(self.seed_lattice) < 1:
            raise ValueError("seed_lattice needs at least one cap >= 1")
        if self.max_executables < 1:
            raise ValueError("max_executables must be >= 1")
        if self.layout not in ("local", "workers", "scenarios", "hybrid"):
            raise ValueError(f"unknown layout '{self.layout}'")
        return self


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Executable identity: everything static about a compiled bucket.
    Hashable — it keys the server's BoundedLRU of warm cores."""

    dataset: str
    disease: str
    interventions: Tuple[str, ...]
    static_network: bool
    backend: str
    block_size: int
    pack_visits: bool
    b_bucket: int  # quantized scenario-axis width
    seed_cap: int  # quantized max seed_per_day (static top-k width)

    def label(self) -> str:
        """Compact human/JSON-friendly name for metrics and provenance."""
        iv = "+".join(self.interventions)
        return (f"{self.dataset}/{self.disease}/{iv}/{self.backend}"
                f"/B{self.b_bucket}/seed{self.seed_cap}"
                f"{'/static' if self.static_network else ''}")


@dataclasses.dataclass(frozen=True)
class RequestShape:
    """Where a request lands: its bucket plus the dispatch-grouping
    facts that are NOT executable identity. Requests batched into one
    dispatch must agree on the whole shape (same bucket => same compiled
    program; same ``n_chunks`` => same number of runner invocations)."""

    bucket: BucketKey
    n_chunks: int  # ceil(days / chunk_days)
    b_request: int  # the request's real scenario count (<= bucket.b_bucket)

    @property
    def padded_days(self) -> int:
        return self.n_chunks  # in chunk units; days = n_chunks * chunk_days


def bucketize(spec: ExperimentSpec, config: ServeConfig) -> RequestShape:
    """Normalize a validated spec onto the server's bucket lattice.

    Raises ``ValueError`` for specs the serving tier refuses: checkpoint/
    resilience policies (serving streams results, it does not snapshot)
    and pinned engines that fight the server's own placement.
    """
    if spec.checkpoint.directory is not None:
        raise ValueError(
            "serving refuses checkpointed specs — the server streams "
            "per-day stats instead of snapshotting; run it via api.run")
    if spec.resilience.enabled:
        raise ValueError(
            "serving refuses resilient specs — recovery policy belongs "
            "to batch runs; run it via api.run")
    if spec.engine != "auto":
        raise ValueError(
            f"serving refuses engine='{spec.engine}' — placement is the "
            "server's (ServeConfig.layout), pin layouts there instead")
    b_req = spec.num_scenarios
    fp = spec.compile_fingerprint()
    key = BucketKey(
        dataset=fp["dataset"],
        disease=fp["disease"],
        interventions=fp["interventions"],
        static_network=fp["static_network"],
        backend=fp["backend"],
        block_size=fp["block_size"],
        pack_visits=fp["pack_visits"],
        b_bucket=quantize_up(b_req, config.b_lattice),
        seed_cap=quantize_up(max(1, spec.seed_per_day), config.seed_lattice),
    )
    n_chunks = max(1, math.ceil(spec.days / config.chunk_days))
    return RequestShape(bucket=key, n_chunks=n_chunks, b_request=b_req)


def padded_days(shape: RequestShape, config: ServeConfig) -> int:
    """Total simulated days for a dispatch of this shape (>= spec.days;
    the surplus is trimmed from each request's history prefix)."""
    return shape.n_chunks * config.chunk_days
