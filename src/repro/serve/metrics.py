"""Serving-tier telemetry: counters + latency reservoirs.

Everything the acceptance targets are stated in lives here: time-to-
first-day percentiles (the interactive-latency number), specs/sec,
batch occupancy (real vs padded scenario slots), cold compiles vs warm
dispatches, bucket evictions, and — the hard invariant — recompile
violations: a jit-cache miss observed by the
:class:`repro.analysis.hlo.recompile_sentinel` *after* a bucket's
warmup, which steady-state serving must never produce.

Thread-safe: the server mutates these from its dispatch thread while
clients read :meth:`ServeMetrics.to_dict` concurrently.
"""

from __future__ import annotations

import threading


class LatencyStat:
    """A bounded reservoir of latency samples with percentile readout.

    Keeps the most recent ``cap`` samples (enough for p99 at CI scale);
    count/total keep the lifetime mean honest even after wraparound.
    """

    def __init__(self, name: str, cap: int = 4096):
        self.name = name
        self.cap = cap
        self._samples: list = []
        self._next = 0  # ring index once the reservoir is full
        self.count = 0
        self.total = 0.0

    def add(self, seconds: float) -> None:
        self.count += 1
        self.total += seconds
        if len(self._samples) < self.cap:
            self._samples.append(seconds)
        else:
            self._samples[self._next] = seconds
            self._next = (self._next + 1) % self.cap

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained samples (0 if none)."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "mean_s": (self.total / self.count) if self.count else 0.0,
            "p50_s": self.percentile(50),
            "p90_s": self.percentile(90),
            "p99_s": self.percentile(99),
            "max_s": max(self._samples) if self._samples else 0.0,
        }


class ServeMetrics:
    """The server's counter block. All mutation goes through methods that
    take the internal lock; ``to_dict`` snapshots under the same lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0  # refused at admission (validate/bucketize)
        self.batches = 0
        self.slots_real = 0  # scenario slots carrying a real request
        self.slots_padded = 0  # scenario slots running no_op_params
        self.chunks_run = 0
        self.cold_compiles = 0  # bucket warmups (executable builds)
        self.warm_dispatches = 0  # batches served from a warm executable
        self.recompile_violations = 0  # sentinel trips: MUST stay 0
        self.ttfd = LatencyStat("time_to_first_day")
        self.latency = LatencyStat("request_latency")
        self.queue_wait = LatencyStat("queue_wait")

    # -- mutation hooks (called by the server) ---------------------------
    def on_submit(self, n: int = 1):
        with self._lock:
            self.submitted += n

    def on_reject(self):
        with self._lock:
            self.rejected += 1

    def on_batch(self, real: int, padded: int, warm: bool, chunks: int):
        with self._lock:
            self.batches += 1
            self.slots_real += real
            self.slots_padded += padded
            self.chunks_run += chunks
            if warm:
                self.warm_dispatches += 1
            else:
                self.cold_compiles += 1

    def on_first_day(self, seconds: float):
        with self._lock:
            self.ttfd.add(seconds)

    def on_complete(self, latency_s: float, queue_wait_s: float):
        with self._lock:
            self.completed += 1
            self.latency.add(latency_s)
            self.queue_wait.add(queue_wait_s)

    def on_fail(self, n: int = 1):
        with self._lock:
            self.failed += n

    def on_recompile_violation(self):
        with self._lock:
            self.recompile_violations += 1

    # -- readout ---------------------------------------------------------
    def to_dict(self, bucket_stats: dict = None) -> dict:
        with self._lock:
            slots = self.slots_real + self.slots_padded
            d = {
                "requests": {
                    "submitted": self.submitted,
                    "completed": self.completed,
                    "failed": self.failed,
                    "rejected": self.rejected,
                },
                "batches": {
                    "dispatched": self.batches,
                    "chunks_run": self.chunks_run,
                    "slots_real": self.slots_real,
                    "slots_padded": self.slots_padded,
                    "occupancy": (self.slots_real / slots) if slots else 0.0,
                    "requests_per_batch": (
                        self.completed / self.batches if self.batches else 0.0
                    ),
                },
                "executables": {
                    "cold_compiles": self.cold_compiles,
                    "warm_dispatches": self.warm_dispatches,
                    "recompile_violations": self.recompile_violations,
                },
                "time_to_first_day": self.ttfd.to_dict(),
                "request_latency": self.latency.to_dict(),
                "queue_wait": self.queue_wait.to_dict(),
            }
        if bucket_stats is not None:
            d["buckets"] = bucket_stats
        return d
