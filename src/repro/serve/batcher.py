"""Request admission + scenario-axis batch formation.

A :class:`ServeRequest` is one admitted spec with its normalized
:class:`~repro.serve.buckets.RequestShape`, a per-chunk stream queue, and
a completion event; :class:`ServeTicket` is the client-facing handle over
it. :class:`RequestBatcher` holds the FIFO of pending requests and forms
dispatch groups: the oldest pending request seeds a group, and younger
requests join it while they (a) land in the same bucket (same compiled
executable), (b) want the same chunk count (same number of runner
invocations), and (c) fit in the bucket's remaining scenario slots.
FIFO-fair: a request is never passed over in favor of a younger one that
would fill the batch better — tail latency beats occupancy here.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import List, Optional

from repro.api.spec import ExperimentSpec
from repro.serve.buckets import RequestShape

_STREAM_END = object()


class ServeError(RuntimeError):
    """A request failed inside the serving tier (admission refusal is a
    plain ValueError at submit; this is a dispatch-time failure)."""


class ServeRequest:
    """Internal per-request record. The server fills it in; the ticket
    reads it out."""

    def __init__(self, spec: ExperimentSpec, shape: RequestShape):
        self.spec = spec
        self.shape = shape
        self.submitted_at = time.time()
        self.dispatched_at: Optional[float] = None
        self.first_day_at: Optional[float] = None
        self.done_at: Optional[float] = None
        self.result = None  # RunResult on success
        self.error: Optional[BaseException] = None
        self._stream: "queue.Queue" = queue.Queue()
        self._done = threading.Event()

    # -- producer side (server) -----------------------------------------
    def push_chunk(self, day_start: int, days: int, stats: dict) -> None:
        if self.first_day_at is None:
            self.first_day_at = time.time()
        self._stream.put({"day_start": day_start, "days": days,
                          "stats": stats})

    def finish(self, result) -> None:
        self.result = result
        if self.done_at is None:  # the finisher may stamp it pre-metrics
            self.done_at = time.time()
        self._stream.put(_STREAM_END)
        self._done.set()

    def fail(self, err: BaseException) -> None:
        self.error = err
        if self.done_at is None:
            self.done_at = time.time()
        self._stream.put(_STREAM_END)
        self._done.set()

    # -- timing readouts -------------------------------------------------
    @property
    def queue_wait_s(self) -> float:
        t = self.dispatched_at or self.done_at or time.time()
        return t - self.submitted_at

    @property
    def ttfd_s(self) -> Optional[float]:
        if self.first_day_at is None:
            return None
        return self.first_day_at - self.submitted_at

    @property
    def latency_s(self) -> Optional[float]:
        if self.done_at is None:
            return None
        return self.done_at - self.submitted_at


class ServeTicket:
    """The client's handle on a submitted spec: stream per-chunk day
    stats as they leave the scan, then collect the final RunResult."""

    def __init__(self, request: ServeRequest):
        self._req = request

    @property
    def shape(self) -> RequestShape:
        return self._req.shape

    def stream(self, timeout: Optional[float] = None):
        """Yield ``{"day_start", "days", "stats"}`` dicts per chunk, in
        day order, ending when the request completes (or fails — the
        failure surfaces in :meth:`result`, not mid-stream)."""
        while True:
            item = self._req._stream.get(timeout=timeout)
            if item is _STREAM_END:
                return
            yield item

    def result(self, timeout: Optional[float] = None):
        """Block for the RunResult; raises ServeError on dispatch
        failure, TimeoutError if the server doesn't finish in time."""
        if not self._req._done.wait(timeout=timeout):
            raise TimeoutError("serve request did not complete in time")
        if self._req.error is not None:
            raise ServeError(str(self._req.error)) from self._req.error
        return self._req.result

    def done(self) -> bool:
        return self._req._done.is_set()

    @property
    def ttfd_s(self) -> Optional[float]:
        return self._req.ttfd_s

    @property
    def latency_s(self) -> Optional[float]:
        return self._req.latency_s


class RequestBatcher:
    """FIFO pending queue + group formation. Not thread-safe by itself —
    the server serializes access under its own lock."""

    def __init__(self):
        self._pending: deque = deque()

    def __len__(self) -> int:
        return len(self._pending)

    def add(self, request: ServeRequest) -> None:
        self._pending.append(request)

    def take_group(self) -> List[ServeRequest]:
        """Pop the next dispatch group: seeded by the oldest pending
        request, greedily joined (in FIFO order) by same-bucket,
        same-chunk-count requests while scenario slots remain. Returns
        [] when nothing is pending."""
        if not self._pending:
            return []
        seed = self._pending.popleft()
        group = [seed]
        capacity = seed.shape.bucket.b_bucket - seed.shape.b_request
        survivors = deque()
        while self._pending:
            req = self._pending.popleft()
            if (req.shape.bucket == seed.shape.bucket
                    and req.shape.n_chunks == seed.shape.n_chunks
                    and req.shape.b_request <= capacity):
                group.append(req)
                capacity -= req.shape.b_request
            else:
                survivors.append(req)
        self._pending = survivors
        return group
