"""Compile-once serving tier: warm executable cache + request batching.

``api.run(spec)`` pays an XLA compile per spec shape; interactive what-if
traffic cannot. This package keeps compiled day-loop scans *resident* —
one :class:`~repro.serve.server.WarmBucket` per quantized shape bucket,
LRU-bounded — and serves concurrent :class:`~repro.api.spec.ExperimentSpec`
requests by packing them onto the scenario axis of an already-compiled
runner, bitwise-equal to solo runs. See docs/serving.md.

    from repro.serve import ServeConfig, SimulationServer
    server = SimulationServer(ServeConfig(chunk_days=8))
    server.warm_up(spec)             # the one compile
    result = server.run(spec)        # milliseconds, zero recompiles
    result.served_from["bucket"]
"""

from repro.serve.batcher import (  # noqa: F401
    RequestBatcher,
    ServeError,
    ServeRequest,
    ServeTicket,
)
from repro.serve.buckets import (  # noqa: F401
    BucketKey,
    RequestShape,
    ServeConfig,
    bucketize,
    quantize_up,
)
from repro.serve.metrics import LatencyStat, ServeMetrics  # noqa: F401
from repro.serve.server import SimulationServer, WarmBucket  # noqa: F401
