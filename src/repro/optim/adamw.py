"""AdamW with fp32 moments, global-norm clipping, decoupled weight decay.

Written directly (optax is not available offline): init/update are pure
pytree functions, jit/pjit friendly; moment tensors inherit parameter
shardings (same tree structure), so the optimizer state is automatically
FSDP-sharded wherever params are.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable] = None  # step -> lr multiplier


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule else 1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * g * g
        mhat = mu2 / bc1
        vhat = nu2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
