"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(warmup_steps: int):
    def f(step):
        return jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)

    return f


def cosine_schedule(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return f
