from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.grad_compress import (  # noqa: F401
    compress_int8,
    decompress_int8,
    error_feedback_update,
)
