"""Int8 gradient compression with error feedback, for the cross-pod
data-parallel all-reduce (DESIGN.md §6).

Cross-pod ICI/DCN links are the scarcest bandwidth at 2×256 scale; the
pod-axis gradient all-reduce moves |params| fp32 per step. Per-tensor
symmetric int8 quantization cuts that 4×; the quantization residual is
carried to the next step (error feedback), which keeps SGD/Adam convergence
(Karimireddy et al., 2019). Used by launch/train.py when
``--grad-compression=int8``: gradients are reduced in two stages —
full-precision within a pod ('data' axis), int8 across pods ('pod' axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g):
    """Per-tensor symmetric quantization. Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def error_feedback_update(g, residual):
    """Apply carried residual, quantize, compute new residual.

    Returns (quantized_pair, new_residual). The caller all-reduces the
    quantized payload over the pod axis and decompresses."""
    g_corrected = g.astype(jnp.float32) + residual
    q, scale = compress_int8(g_corrected)
    new_residual = g_corrected - decompress_int8(q, scale)
    return (q, scale), new_residual


def compressed_psum_tree(grads, residuals, axis_name: str):
    """shard_map-side helper: int8-compress each gradient leaf, psum the
    int8 payload over `axis_name`, decompress, and return new residuals."""
    outs, new_res = [], []
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    n = jax.lax.psum(1, axis_name)
    for g, r in zip(flat_g, flat_r):
        (q, scale), r2 = error_feedback_update(g, r)
        # int8 payloads sum without overflow in int32 across <=128 pods
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales differ per pod: psum the dequantized mean contribution
        scale_sum = jax.lax.psum(scale, axis_name)
        outs.append(summed.astype(jnp.float32) * (scale_sum / n) / n)
        new_res.append(r2)
    return (
        jax.tree.unflatten(treedef, outs),
        jax.tree.unflatten(treedef, new_res),
    )
