"""On-device observables: streaming reductions over the day loop.

An :class:`Observable` is an ``init / update / finalize`` triple over the
per-day stats pytree every engine's day step emits (keys
``repro.core.simulator.STAT_KEYS``, leaves carrying a leading scenario
axis ``(B,)``). ``update`` runs *inside* the scan — per-day outputs are
stacked by the scan itself and running reductions (attack rate, peak-day
argmax, cross-scenario mean/CI bands) live in the scan carry, so nothing
round-trips through the host per day. This closes the ROADMAP item
"cross-scenario reductions computed on-device inside the scan".

Two drivers consume the same observables:

  * the in-scan path — :func:`repro.api.runner` threads the carries through
    the vmapped day-loop scan for the ``ensemble`` engine, whose whole
    batch lives in one scan body;
  * :func:`observe_history` — an on-device ``lax.scan`` of the same update
    functions over a day-major history, used post-run for the shard_map
    engines (whose scan bodies only see a shard of the batch axis).

Because ``update`` is a pure deterministic function of the stats values,
both paths produce bit-identical results (tested in tests/test_api.py).

Observable carries are ordinary pytrees but are *not* persisted in
checkpoints: on resume, :func:`scan_history` replays the pure updates over
the checkpointed history-so-far, reconstructing the carries exactly. A
future observable whose carry is not a pure function of the daily stats
(e.g. one reading per-person state) would need its carry added to the
checkpoint payload in ``repro.api.runner``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ObsContext:
    """Static study geometry the reductions need at trace time.

    ``sweep_axes`` describes the factorial design the batch expands:
    ``((axis_name, (level_of_scenario_0, level_of_scenario_1, ...)), ...)``
    — one entry per sweep axis with more than one level, each scenario
    assigned its level index on that axis. Sensitivity observables (Sobol)
    group scenarios by level; everything is plain hashable tuples so the
    context can key jit caches."""

    num_people: int
    num_scenarios: int
    sweep_axes: tuple = ()


@dataclasses.dataclass(frozen=True)
class Observable:
    """Base streaming reduction. Subclasses override the three hooks;
    frozen/field-free so instances hash (jit-cache keys) and serialize by
    registry name."""

    name = "observable"

    def init(self, ctx: ObsContext):
        """Initial carry pytree (device arrays or empty tuples)."""
        return ()

    def update(self, carry, stats):
        """One day's update: ``(carry, stats) -> (carry, daily_output)``.
        ``stats`` leaves are ``(B,)``; runs inside jit/scan — jnp only.
        ``daily_output`` is stacked day-major by the scan; return ``()``
        for reductions with no per-day series."""
        return carry, ()

    def finalize(self, carry, ctx: ObsContext) -> dict:
        """Named end-of-run results from the final carry."""
        return {}


@dataclasses.dataclass(frozen=True)
class DailyNewInfections(Observable):
    """The day-major incidence series (one column per scenario)."""

    name = "daily_new_infections"

    def update(self, carry, stats):
        return carry, {"daily": stats["new_infections"]}


@dataclasses.dataclass(frozen=True)
class AttackRate(Observable):
    """Final cumulative infections / population, per scenario."""

    name = "attack_rate"

    def init(self, ctx):
        return jnp.zeros((ctx.num_scenarios,), jnp.int32)

    def update(self, carry, stats):
        return stats["cumulative"], ()

    def finalize(self, carry, ctx):
        return {
            "cumulative": carry,
            "attack_rate": carry.astype(jnp.float32) / ctx.num_people,
        }


@dataclasses.dataclass(frozen=True)
class PeakDay(Observable):
    """Running argmax of the infectious curve (first-peak semantics,
    matching ``np.argmax``), per scenario."""

    name = "peak_day"

    def init(self, ctx):
        B = ctx.num_scenarios
        return (jnp.full((B,), -1, jnp.int32), jnp.zeros((B,), jnp.int32))

    def update(self, carry, stats):
        best, best_day = carry
        inf = stats["infectious"].astype(jnp.int32)
        better = inf > best  # strict: ties keep the earlier day
        return (
            jnp.where(better, inf, best),
            jnp.where(better, stats["day"].astype(jnp.int32), best_day),
        ), ()

    def finalize(self, carry, ctx):
        best, best_day = carry
        return {"peak_infectious": best, "peak_day": best_day}


@dataclasses.dataclass(frozen=True)
class EnsembleMeanCI(Observable):
    """Cross-scenario mean and normal-approximation 95% CI band of the
    daily incidence and infectious curves — the ensemble-aware reduction
    computed where the batch axis lives (on device, inside the scan).
    Degenerates to the trajectory itself (zero-width band) at B=1."""

    name = "ensemble_mean_ci"
    Z = 1.96

    def update(self, carry, stats):
        out = {}
        for key in ("new_infections", "infectious"):
            x = stats[key].astype(jnp.float32)
            B = x.shape[0]  # static
            m = jnp.mean(x)
            sem = (jnp.std(x, ddof=1) / np.sqrt(B)) if B > 1 else jnp.float32(0.0)
            out[key] = {"mean": m, "lo": m - self.Z * sem, "hi": m + self.Z * sem}
        return carry, out


@dataclasses.dataclass(frozen=True)
class SobolFirstOrder(Observable):
    """First-order Sobol sensitivity indices of the final cumulative
    infection count over the study's sweep axes.

    For a full-factorial design the first-order index of axis ``a`` is
    estimated as the between-level variance fraction

        S1_a = Var_l( E[Y | X_a = l] ) / Var(Y),

    with ``E[Y | X_a = l]`` the mean outcome over the scenarios at level
    ``l`` (all other axes marginalized — exact for a balanced factorial,
    the classic Sobol/ANOVA decomposition) and both variances population
    variances over the batch. Streaming: the carry tracks the running
    cumulative count per scenario (the same carry AttackRate keeps);
    grouping happens once, in ``finalize``, from ``ctx.sweep_axes``.
    Host-side numpy reference in tests/test_api.py."""

    name = "sobol_first_order"

    def init(self, ctx):
        return jnp.zeros((ctx.num_scenarios,), jnp.int32)

    def update(self, carry, stats):
        return stats["cumulative"], ()

    def finalize(self, carry, ctx):
        y = carry.astype(jnp.float32)
        mu = jnp.mean(y)
        var = jnp.mean((y - mu) ** 2)
        s1 = {}
        for axis_name, levels in ctx.sweep_axes:
            g = jnp.asarray(levels, jnp.int32)
            L = int(max(levels)) + 1
            sums = jnp.zeros((L,), jnp.float32).at[g].add(y)
            cnts = jnp.zeros((L,), jnp.float32).at[g].add(1.0)
            gmean = sums / jnp.maximum(cnts, 1.0)
            var_between = jnp.sum(cnts * (gmean - mu) ** 2) / y.shape[0]
            s1[axis_name] = jnp.where(var > 0.0, var_between / var, jnp.nan)
        return {"variance": var, "S1": s1}


@dataclasses.dataclass(frozen=True)
class TEPS(Observable):
    """Traversed-edge telemetry: the day-major edge series per scenario
    plus a running total across days and scenarios — the numerator of the
    paper's headline metric (traversed edges per second; §VI reports 4.6B
    on the California twin). On the pallas-compact backend the per-day
    counts come from the kernel's in-SMEM accumulator; elsewhere they are
    host-derived (and everywhere equal to ``stats["contacts"]``, which
    tests assert). The denominator (measured wall clock) is a host-side
    quantity: :func:`repro.api.runner.run` divides it in after the scan."""

    name = "teps"

    def init(self, ctx):
        # Without x64 jnp has no 64-bit ints; f32 keeps the running total
        # exact below 2^24 edges (plenty for CI-scale runs) and the
        # day-major int series stays exact regardless.
        dt = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.float32
        return jnp.zeros((), dt)

    def update(self, carry, stats):
        e = stats["edges"]
        return carry + e.astype(carry.dtype).sum(), {"daily": e}

    def finalize(self, carry, ctx):
        return {"edges_total": carry}


@dataclasses.dataclass(frozen=True)
class TestsUsed(Observable):
    """Day-major tests-administered series plus the running total per
    scenario — the utilization of the capacity-limited test budget."""

    name = "tests_used"

    def init(self, ctx):
        return jnp.zeros((ctx.num_scenarios,), jnp.int32)

    def update(self, carry, stats):
        t = stats["tests_used"].astype(jnp.int32)
        return carry + t, {"daily": t}

    def finalize(self, carry, ctx):
        return {"tests_total": carry}


@dataclasses.dataclass(frozen=True)
class IsolatedCount(Observable):
    """Day-major count of people in isolation, with the per-scenario peak
    (the isolation-capacity planning number)."""

    name = "isolated_count"

    def init(self, ctx):
        return jnp.zeros((ctx.num_scenarios,), jnp.int32)

    def update(self, carry, stats):
        iso = stats["isolated"].astype(jnp.int32)
        return jnp.maximum(carry, iso), {"daily": iso}

    def finalize(self, carry, ctx):
        return {"peak_isolated": carry}


@dataclasses.dataclass(frozen=True)
class AvertedByTTI(Observable):
    """Infections averted relative to scenario 0, per scenario.

    Convention: the study's first scenario is the no-TTI (or reference)
    arm — ``averted[b] = cumulative[0] - cumulative[b]``, so the baseline
    row reads 0 and intervention arms read their absolute effect size.
    Cross-scenario, so it sees the gathered full batch on every topology."""

    name = "averted_by_tti"

    def init(self, ctx):
        return jnp.zeros((ctx.num_scenarios,), jnp.int32)

    def update(self, carry, stats):
        return stats["cumulative"].astype(jnp.int32), ()

    def finalize(self, carry, ctx):
        return {"cumulative": carry, "averted": carry[0] - carry}


OBSERVABLES = {
    o.name: type(o)
    for o in (DailyNewInfections(), AttackRate(), PeakDay(), EnsembleMeanCI(),
              SobolFirstOrder(), TEPS(), TestsUsed(), IsolatedCount(),
              AvertedByTTI())
}


def make_observables(names) -> tuple:
    return tuple(OBSERVABLES[n]() for n in names)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def init_carries(observables, ctx: ObsContext) -> tuple:
    return tuple(o.init(ctx) for o in observables)


def update_all(observables, carries, stats):
    """One day across every observable; returns (carries, {name: daily})."""
    new_carries, daily = [], {}
    for o, c in zip(observables, carries):
        c, d = o.update(c, stats)
        new_carries.append(c)
        daily[o.name] = d
    return tuple(new_carries), daily


def finalize_all(observables, carries, dailies, ctx: ObsContext) -> dict:
    """Merge each observable's finalized reductions with its stacked
    day-major series (under the ``"daily"``-rooted keys its update
    emitted)."""
    out = {}
    for o, c in zip(observables, carries):
        res = dict(o.finalize(c, ctx))
        d = dailies.get(o.name, ()) if dailies is not None else ()
        if jax.tree.leaves(d):
            res.update(d if isinstance(d, dict) else {"daily": d})
        out[o.name] = res
    return out


def scan_history(observables, hist, ctx: ObsContext):
    """One on-device ``lax.scan`` of the updates over a day-major history.

    ``hist`` maps STAT_KEYS to ``(days, B)`` arrays (device or host — host
    arrays are placed once). Returns ``(carries, dailies)`` mid-stream, so
    a resumed run can replay its pre-checkpoint reductions exactly and
    keep streaming from there."""
    hist_dev = {k: jnp.asarray(v) for k, v in hist.items()}
    carries = init_carries(observables, ctx)

    def body(c, stats):
        return update_all(observables, c, stats)

    return jax.lax.scan(body, carries, hist_dev)


def observe_history(observables, hist, ctx: ObsContext) -> dict:
    """Run the observables over an existing day-major history, on device.

    This is the post-run driver for engines whose scan bodies never see
    the whole batch axis (shard_map shards it); bit-identical to the
    in-scan path by purity of ``update``."""
    carries, dailies = scan_history(observables, hist, ctx)
    return finalize_all(observables, carries, dailies, ctx)


def observables_to_numpy(obs: dict) -> dict:
    """device pytrees -> host numpy (for RunResult / serialization)."""
    return jax.tree.map(lambda x: np.asarray(x), jax.device_get(obs))
