"""One front door: declarative :class:`ExperimentSpec` -> :func:`run`.

    from repro import api

    spec = api.ExperimentSpec(
        dataset="twin-2k", days=60,
        interventions=("none", "school-closure"),
        tau_scales=(1.0, 0.8), replicates=2,
    )
    result = api.run(spec)           # engine derived from batch x mesh
    result.save("run_result.json")   # uniform RunResult, any engine

Specs serialize (``to_json``/``from_json``, ``from_toml``), so a study is
an artifact; results carry day-major histories, on-device observables, and
provenance. See :mod:`repro.api.runner` for the engine-dispatch table and
:mod:`repro.api.observables` for the reduction protocol.
"""

from repro.api.observables import (  # noqa: F401
    OBSERVABLES,
    Observable,
    ObsContext,
    make_observables,
    observe_history,
)
from repro.api.result import RunResult  # noqa: F401
from repro.api.runner import run, run_file  # noqa: F401
from repro.api.spec import (  # noqa: F401
    CheckpointSpec,
    ExperimentSpec,
    MeshSpec,
    ResilienceSpec,
)
