"""``run(spec) -> RunResult``: the one front door to the one engine core.

The facade derives the *layout* from the spec's batch size and mesh shape
(never hand-picked, though ``spec.engine`` can pin one for parity tests)
and hands everything to :mod:`repro.engine`: every layout executes the
identical topology-parameterized day-loop scan —

  =========  =========================  ================================
  engine     selected when              engine-core placement
  =========  =========================  ================================
  single     B == 1, workers == 1       ``EngineCore(layout="local")``
  dist       B == 1, workers > 1        ``EngineCore(layout="workers")``
  ensemble   B > 1, 1×1 mesh            ``EngineCore(layout="local")``
  sharded    B > 1, scenarios > 1       ``EngineCore(layout="scenarios")``
  hybrid     B > 1, workers > 1         ``EngineCore(layout="hybrid")``
  =========  =========================  ================================

Observable ``update()`` hooks run *inside* the scan body on every
placement (cross-scenario reductions see the full batch through the
topology's scenario-axis gather — a collective when the batch is sharded).
The only exception is a pinned single/dist engine with B > 1, which runs
scenarios sequentially through one compiled program and replays the pure
reductions post-run (bitwise-identical by purity).

The day-chunked checkpoint/resume loop lives in the engine core
(:func:`repro.engine.core.run_chunked`) and is bitwise on every layout.
Resume keys carry the engine-core generation marker — checkpoints written
by the pre-refactor per-engine loops are refused, not spliced.

Histories are normalized day-major with a scenario axis: every array is
``(days, B)``, B=1 included, so downstream analysis never branches on
engine; padded batch slots are inert no-ops that never appear here.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax

from repro.analysis.report import summarize_sweep
from repro.api import observables as obs_lib
from repro.api.result import RunResult
from repro.api.spec import ExperimentSpec
from repro.checkpoint import CheckpointManager
from repro.configs import get_epidemic
from repro.engine import core as engine_lib
from repro.runtime import resilience as resilience_lib


def _resume_key(spec: ExperimentSpec, engine: str) -> dict:
    """What must match for a checkpoint to be resumable under this spec:
    everything that shapes the state pytree or the science — but not the
    run length (extending a run IS the resume use case), the checkpoint
    policy itself, the study's display name, or the observables (pure
    reductions replayed from the restored history, never checkpointed).
    ``core`` marks the engine generation: checkpoints written by the
    pre-refactor engines carry no (or another) marker and are refused."""
    d = spec.to_dict()
    # resilience is pure recovery policy — it never changes the science,
    # so toggling it must not invalidate existing checkpoints.
    for k in ("days", "checkpoint", "name", "engine", "observables",
              "resilience"):
        d.pop(k, None)
    d["engine_resolved"] = engine
    d["core"] = engine_lib.CORE_VERSION
    return d


def _resolve_engine(spec: ExperimentSpec, B: int) -> str:
    if spec.engine != "auto":
        return spec.engine
    W, S = spec.mesh.workers, spec.mesh.scenarios
    if W > 1:
        return "hybrid" if B > 1 else "dist"
    if B > 1:
        return "sharded" if S > 1 else "ensemble"
    return "single"


_LAYOUTS = {
    "single": "local",
    "ensemble": "local",
    "dist": "workers",
    "sharded": "scenarios",
    "hybrid": "hybrid",
}


def _make_core(engine: str, spec: ExperimentSpec, pop, batch):
    if engine == "sharded" and spec.mesh.scenarios > len(jax.devices()):
        raise ValueError(
            f"mesh.scenarios={spec.mesh.scenarios} but only "
            f"{len(jax.devices())} devices are visible")
    return engine_lib.EngineCore(
        pop, batch,
        layout=_LAYOUTS[engine],
        workers=spec.mesh.workers,
        scen_shards=spec.mesh.scenarios,
        backend=spec.backend,
        block_size=spec.block_size,
        pack_visits=spec.pack_visits,
        max_seed_per_day=max(s.seed_per_day for s in batch),
    )


def _sweep_axes(spec: ExperimentSpec, B: int) -> tuple:
    """Per-scenario level assignments of the factorial sweep axes (axes
    with a single level carry no information and are dropped). Order
    matches ScenarioBatch.from_product: interventions × tau × replicates,
    replicates innermost."""
    n_iv = len(spec.interventions)
    n_tau = len(spec.tau_scales)
    n_rep = spec.replicates
    if n_iv * n_tau * n_rep != B:  # hand-built batch: no factorial info
        return ()
    idx = np.arange(B)
    axes = []
    if n_iv > 1:
        axes.append(("interventions", tuple((idx // (n_tau * n_rep)).tolist())))
    if n_tau > 1:
        axes.append(("tau_scales", tuple(((idx // n_rep) % n_tau).tolist())))
    if n_rep > 1:
        axes.append(("replicates", tuple((idx % n_rep).tolist())))
    return tuple(axes)


def run(spec: ExperimentSpec, *, population=None, chaos=None,
        on_straggler=None) -> RunResult:
    """Execute an :class:`ExperimentSpec` end to end; the one public entry
    point. ``population=`` substitutes a prebuilt Population for
    ``spec.dataset`` (a testing hook — parity tests reuse one build).

    ``chaos=`` injects a deterministic fault schedule
    (:class:`repro.runtime.chaos.ChaosSchedule`) into the chunk loop and
    implies the resilient path — the chaos-harness hook the recovery
    matrix in CI runs through. ``on_straggler(day, dt, median)`` observes
    straggler detections (the adaptive-repartition seam)."""
    spec = spec.validate()
    t0 = time.time()
    pop = population if population is not None else \
        get_epidemic(spec.dataset).build()
    batch = spec.build_batch()
    B = len(batch)
    engine = _resolve_engine(spec, B)
    observables = obs_lib.make_observables(spec.observables)
    ctx = obs_lib.ObsContext(
        num_people=pop.num_people, num_scenarios=B,
        sweep_axes=_sweep_axes(spec, B),
    )

    # Pinned one-scenario-at-a-time layouts run sequentially: lowest
    # memory footprint; cross-scenario reductions replay post-run
    # (pure => bitwise).
    in_scan = not (engine in ("single", "dist") and B > 1)
    built = {}  # the most recently constructed core (provenance below)

    def make_driver(workers=None):
        """(Re)build the chunk driver — ``workers`` overrides the mesh
        width, the elastic-degradation / repartition rebuild seam."""
        s = spec
        if workers is not None and workers != spec.mesh.workers:
            s = dataclasses.replace(
                spec, mesh=dataclasses.replace(spec.mesh, workers=workers))
        core = _make_core(engine, s, pop, batch)
        built["core"] = core
        if not in_scan:
            return engine_lib.SequentialDriver(core)
        return engine_lib.CoreDriver(core, observables)

    ck = spec.checkpoint
    mgr = CheckpointManager(ck.directory, keep=ck.keep) if ck.directory else None
    rs = spec.resilience
    resilient = rs.enabled or chaos is not None
    report = None

    t_run = time.time()
    if resilient:
        if mgr is None:
            raise ValueError(
                "the resilient path (resilience.enabled or chaos injection) "
                "needs checkpoint.directory — recovery restores from "
                "snapshots")
        policy = resilience_lib.ResiliencePolicy(
            max_restarts=rs.max_restarts, backoff_s=rs.backoff_s,
            guards=rs.guards, elastic=rs.elastic,
            straggler_window=rs.straggler_window,
            straggler_factor=rs.straggler_factor,
            repartition_on_straggler=rs.repartition_on_straggler,
        )
        state, hist, carries, dailies, resumed_from, num_chunks, report = \
            resilience_lib.run_resilient(
                make_driver, spec.days, observables, ctx,
                manager=mgr, every=ck.every, resume=ck.resume,
                resume_key=_resume_key(spec, engine),
                policy=policy, chaos=chaos, on_straggler=on_straggler,
            )
    else:
        state, hist, carries, dailies, resumed_from, num_chunks = \
            engine_lib.run_chunked(
                make_driver(None), spec.days, observables, ctx,
                manager=mgr, every=ck.every, resume=ck.resume,
                resume_key=_resume_key(spec, engine),
            )
    run_wall = time.time() - t_run
    core = built["core"]

    # --- observables ----------------------------------------------------
    if in_scan:
        obs = obs_lib.finalize_all(observables, carries, dailies, ctx)
    else:
        obs = obs_lib.observe_history(observables, hist, ctx)
    obs = obs_lib.observables_to_numpy(obs)

    # Padded batch slots are inert no-ops inside the core and must never
    # surface: every history column corresponds to a real scenario.
    assert all(v.shape[1] == B for v in hist.values()), \
        "engine core leaked padded scenario slots into the history"

    summaries = summarize_sweep(hist, batch.names, pop.num_people)
    wall = time.time() - t0
    provenance = {
        "engine": engine,
        "layout": core.layout,
        "topology": type(core.topo).__name__,
        "num_people": int(pop.num_people),
        "mesh": {"workers": spec.mesh.workers,
                 "scenarios": spec.mesh.scenarios},
        "num_devices": len(jax.devices()),
        "jax_backend": jax.default_backend(),
        "wall_s": round(wall, 3),  # end to end, incl. pop build + compile
        "run_wall_s": round(run_wall, 3),  # the day-chunk loop only
        "chunks": num_chunks,
        "chunk_days": ck.every if mgr is not None else spec.days,
        "resumed_from_day": resumed_from,
        "observables_in_scan": in_scan,
        "core": engine_lib.CORE_VERSION,
    }
    if report is not None:
        # What recovery actually did: restarts, chunks replayed, snapshots
        # quarantined, straggler/device-loss events, final layout.
        provenance["resilience"] = report.to_dict()
    # Measured TEPS: the observables' (deterministic, bitwise-tested) edge
    # total over the measured scan wall clock. The rate mixes in host time,
    # so it lives with the other wall-clock facts here — not in the pure
    # observable outputs.
    if "teps" in obs:
        provenance["edges_total"] = float(obs["teps"]["edges_total"])
        provenance["teps"] = float(obs["teps"]["edges_total"]) / max(
            run_wall, 1e-9
        )
    return RunResult(
        spec=spec,
        scenario_names=batch.names,
        history=hist,
        observables=obs,
        summaries=summaries,
        provenance=provenance,
    )


def run_file(path: str, **overrides) -> RunResult:
    """Load a JSON/TOML spec from disk (``--spec`` path) and run it."""
    return run(ExperimentSpec.from_file(path).with_overrides(**overrides))
