"""``run(spec) -> RunResult``: the one front door to all four engines.

The facade derives the engine from the spec's batch size and mesh shape
(never hand-picked, though ``spec.engine`` can pin one for parity tests):

  =========  =========================  =====================================
  engine     selected when              executes as
  =========  =========================  =====================================
  single     B == 1, workers == 1       ``EpidemicSimulator`` (one scan per
                                        scenario; B > 1 loops one compiled
                                        program over per-scenario params)
  dist       B == 1, workers > 1        ``DistSimulator`` (people/locations
                                        sharded; same per-params loop)
  ensemble   B > 1, 1×1 mesh            ``EnsembleSimulator`` (vmapped scan,
                                        observables *inside* the scan body)
  sharded    B > 1, scenarios > 1       ``ShardedEnsemble`` (batch axis
                                        sharded; observables post-scan)
  hybrid     B > 1, workers > 1         ``HybridEnsemble`` (2-D mesh)
  =========  =========================  =====================================

Every engine funnels through the same day-chunked loop: ``checkpoint.every``
days per jitted scan, state + history-so-far snapshotted through
``CheckpointManager`` at each chunk boundary, resume replaying the
observable reductions over the restored history (pure updates, so the
resumed run is bitwise-equal to an uninterrupted one — tests/test_api.py).
Histories are normalized day-major with a scenario axis: every array is
``(days, B)``, B=1 included, so downstream analysis never branches on
engine.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax

from repro.analysis.report import summarize_sweep
from repro.api import observables as obs_lib
from repro.api.result import RunResult
from repro.api.spec import ExperimentSpec
from repro.checkpoint import CheckpointManager
from repro.configs import get_epidemic
from repro.core import simulator as sim_lib
from repro.core import simulator_dist as sd
from repro.launch.mesh import make_hybrid_mesh, make_worker_mesh
from repro.sweep import EnsembleSimulator, HybridEnsemble, ShardedEnsemble
from repro.sweep import engine as engine_lib
from repro.sweep.sharded import make_scenario_mesh

_STATE_FIELDS = tuple(f.name for f in dataclasses.fields(sim_lib.SimState))


def _state_to_tree(state: sim_lib.SimState) -> dict:
    """SimState -> plain dict (stable checkpoint key paths)."""
    return {f: getattr(state, f) for f in _STATE_FIELDS}


def _state_from_flat(flat: dict) -> sim_lib.SimState:
    return sim_lib.SimState(**{f: flat[f"state/{f}"] for f in _STATE_FIELDS})


def _resume_key(spec: ExperimentSpec, engine: str) -> dict:
    """What must match for a checkpoint to be resumable under this spec:
    everything that shapes the state pytree or the science — but not the
    run length (extending a run IS the resume use case), the checkpoint
    policy itself, the study's display name, or the observables (pure
    reductions replayed from the restored history, never checkpointed)."""
    d = spec.to_dict()
    for k in ("days", "checkpoint", "name", "engine", "observables"):
        d.pop(k, None)
    d["engine_resolved"] = engine
    return d


def _resolve_engine(spec: ExperimentSpec, B: int) -> str:
    if spec.engine != "auto":
        return spec.engine
    W, S = spec.mesh.workers, spec.mesh.scenarios
    if W > 1:
        return "hybrid" if B > 1 else "dist"
    if B > 1:
        return "sharded" if S > 1 else "ensemble"
    return "single"


# ---------------------------------------------------------------------------
# engine drivers: a uniform chunk-run surface over the four engines
# ---------------------------------------------------------------------------


class _SequentialDriver:
    """Shared loop for the single-scenario-at-a-time engines (single/dist):
    one compiled scan program, iterated over per-scenario params with the
    stacked state sliced/restacked around it."""

    in_scan = False  # observables run post-scan (batch axis not in one scan)

    def __init__(self, batch):
        self.batch = batch

    def _run_one(self, n, state_i, params_i):  # -> (final_i, hist_i)
        raise NotImplementedError

    def _init_one(self, scenario):
        raise NotImplementedError

    def init_state(self):
        return engine_lib.stack_params(
            [self._init_one(s) for s in self.batch]
        )

    def run_chunk(self, n, state, carries):
        finals, hists = [], []
        for i in range(len(self.batch)):
            f, h = self._run_one(n, engine_lib.index_params(state, i),
                                 self.params_list[i])
            finals.append(f)
            hists.append(h)
        state = engine_lib.stack_params(finals)
        hist = {k: np.stack([h[k] for h in hists], axis=1)
                for k in sim_lib.STAT_KEYS}
        return state, hist, carries, None


class _SingleDriver(_SequentialDriver):
    def __init__(self, spec, pop, batch):
        super().__init__(batch)
        s0 = batch[0]
        self.sim = sim_lib.EpidemicSimulator(
            pop, s0.disease, s0.tm, interventions=s0.interventions,
            seed=s0.seed, backend=spec.backend, block_size=spec.block_size,
            pack_visits=spec.pack_visits, static_network=s0.static_network,
            seed_per_day=s0.seed_per_day, seed_days=s0.seed_days,
            iv_enabled=s0.iv_enabled,
        )
        # scenario 0's params were already built by __post_init__
        self.params_list = [self.sim.params]
        for s in batch[1:]:
            slots, p = sim_lib.build_params(
                pop, s.disease, s.tm, s.interventions, s.seed,
                seed_per_day=s.seed_per_day, seed_days=s.seed_days,
                static_network=s.static_network, iv_enabled=s.iv_enabled,
            )
            assert slots == self.sim.iv_slots, "batch slot structure drift"
            self.params_list.append(p)

    def _init_one(self, s):
        return sim_lib.init_state(
            s.disease, self.sim.pop.num_people, len(self.sim.iv_slots)
        )

    def _run_one(self, n, state_i, params_i):
        return self.sim.run(n, state_i, params_i)


class _DistDriver(_SequentialDriver):
    def __init__(self, spec, pop, batch):
        super().__init__(batch)
        s0 = batch[0]
        self.sim = sd.DistSimulator(
            pop, s0.disease, make_worker_mesh(spec.mesh.workers), s0.tm,
            interventions=s0.interventions, seed=s0.seed,
            block_size=spec.block_size, backend=spec.backend,
            pack_visits=spec.pack_visits, static_network=s0.static_network,
            seed_per_day=s0.seed_per_day, seed_days=s0.seed_days,
            iv_enabled=s0.iv_enabled,
            max_seed_per_day=max(s.seed_per_day for s in batch),
        )
        # scenario 0's padded params were already built by __post_init__
        self.params_list = [self.sim.params]
        for s in batch[1:]:
            slots, p = sim_lib.build_params(
                pop, s.disease, s.tm, s.interventions, s.seed,
                seed_per_day=s.seed_per_day, seed_days=s.seed_days,
                static_network=s.static_network, iv_enabled=s.iv_enabled,
            )
            assert slots == self.sim.iv_slots, "batch slot structure drift"
            self.params_list.append(sd.pad_params(p, self.sim.plan))

    def _init_one(self, s):
        return sd.dist_init_state(s.disease, self.sim.plan,
                                  len(self.sim.iv_slots))

    def _run_one(self, n, state_i, params_i):
        return self.sim.run(n, state_i, params_i)


class _EnsembleDriver:
    """The vmap engine — the whole batch lives in one scan body, so the
    observable updates run *inside* it (the tentpole's on-device path)."""

    in_scan = True

    def __init__(self, spec, pop, batch, observables):
        self.ens = EnsembleSimulator(
            pop, batch, backend=spec.backend, block_size=spec.block_size,
            pack_visits=spec.pack_visits,
        )
        self.observables = observables
        self._scan = self._make_observed_scan()

    def init_state(self):
        return self.ens.init_state()

    def _make_observed_scan(self):
        ens, observables = self.ens, self.observables

        def fn(params, state, carries, *, days):
            step = jax.vmap(
                lambda p, st: sim_lib.day_step(
                    ens.static, ens.week, ens.contact_prob, p, st
                )
            )

            def body(carry, _):
                st, oc = carry
                st, stats = step(params, st)
                oc, daily = obs_lib.update_all(observables, oc, stats)
                return (st, oc), (stats, daily)

            return jax.lax.scan(body, (state, carries), None, length=days)

        return jax.jit(fn, static_argnames=("days",))  # caches per days

    def run_chunk(self, n, state, carries):
        (state, carries), (hist, dailies) = self._scan(
            self.ens.params, state, carries, days=n
        )
        hist = {k: np.asarray(v) for k, v in jax.device_get(hist).items()}
        return state, hist, carries, jax.device_get(dailies)


class _ShardedDriver:
    in_scan = False

    def __init__(self, spec, pop, batch):
        mesh = make_scenario_mesh(spec.mesh.scenarios)
        if int(mesh.shape["scenarios"]) != spec.mesh.scenarios:
            raise ValueError(
                f"mesh.scenarios={spec.mesh.scenarios} but only "
                f"{len(jax.devices())} devices are visible")
        self.num_real = len(batch)
        self.ens = ShardedEnsemble(
            pop, batch, mesh=mesh, backend=spec.backend,
            block_size=spec.block_size, pack_visits=spec.pack_visits,
        )

    def init_state(self):
        return self.ens.init_state()

    def run_chunk(self, n, state, carries):
        state, hist = self.ens.run(n, state, drop_padding=False)
        return state, {k: v[:, : self.num_real] for k, v in hist.items()}, \
            carries, None


class _HybridDriver:
    in_scan = False

    def __init__(self, spec, pop, batch):
        self.num_real = len(batch)
        self.ens = HybridEnsemble(
            pop, batch,
            mesh=make_hybrid_mesh(spec.mesh.workers, spec.mesh.scenarios),
            backend=spec.backend, block_size=spec.block_size,
            pack_visits=spec.pack_visits,
        )

    def init_state(self):
        return self.ens.init_state()

    def run_chunk(self, n, state, carries):
        state, hist = self.ens.run(n, state, drop_padding=False)
        return state, {k: v[:, : self.num_real] for k, v in hist.items()}, \
            carries, None


def _make_driver(engine, spec, pop, batch, observables):
    if engine == "single":
        return _SingleDriver(spec, pop, batch)
    if engine == "dist":
        return _DistDriver(spec, pop, batch)
    if engine == "ensemble":
        return _EnsembleDriver(spec, pop, batch, observables)
    if engine == "sharded":
        return _ShardedDriver(spec, pop, batch)
    if engine == "hybrid":
        return _HybridDriver(spec, pop, batch)
    raise ValueError(f"unknown engine '{engine}'")


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


def _concat_hists(hists: list) -> dict:
    return {k: np.concatenate([h[k] for h in hists], axis=0)
            for k in hists[0]}


def _concat_dailies(chunks: list):
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *chunks)


def run(spec: ExperimentSpec, *, population=None) -> RunResult:
    """Execute an :class:`ExperimentSpec` end to end; the one public entry
    point. ``population=`` substitutes a prebuilt Population for
    ``spec.dataset`` (a testing hook — parity tests reuse one build)."""
    spec = spec.validate()
    t0 = time.time()
    pop = population if population is not None else \
        get_epidemic(spec.dataset).build()
    batch = spec.build_batch()
    B = len(batch)
    engine = _resolve_engine(spec, B)
    observables = obs_lib.make_observables(spec.observables)
    ctx = obs_lib.ObsContext(num_people=pop.num_people, num_scenarios=B)
    driver = _make_driver(engine, spec, pop, batch, observables)

    ck = spec.checkpoint
    mgr = CheckpointManager(ck.directory, keep=ck.keep) if ck.directory else None

    # --- resume ---------------------------------------------------------
    state, carries, hists, daily_chunks = None, None, [], []
    day, resumed_from = 0, None
    if mgr is not None and ck.resume and mgr.latest_step() is not None:
        step = mgr.latest_step()
        if step > spec.days:
            raise ValueError(
                f"checkpoint at day {step} is beyond spec.days={spec.days}")
        saved_key = mgr.manifest(step).get("extra", {}).get("resume_key")
        if saved_key != _resume_key(spec, engine):
            raise ValueError(
                f"checkpoint at day {step} in {ck.directory} was "
                + ("written by an incompatible spec (different parameters, "
                   "sweep axes, or engine/mesh)" if saved_key is not None
                   else "not written by repro.api.run (no resume_key in "
                        "its manifest)")
                + "; refusing to splice trajectories — point "
                "checkpoint.directory elsewhere or set "
                "checkpoint.resume=false")
        flat = mgr.restore_flat(step)
        state = _state_from_flat(flat)
        hists = [{k: flat[f"hist/{k}"] for k in sim_lib.STAT_KEYS}]
        if driver.in_scan:
            # Replay the pure reductions over the restored history so the
            # carries continue exactly where the interrupted scan left off.
            carries, pre = obs_lib.scan_history(observables, hists[0], ctx)
            daily_chunks = [jax.device_get(pre)]
        day, resumed_from = step, step
    if state is None:
        state = driver.init_state()
    if carries is None and driver.in_scan:
        carries = obs_lib.init_carries(observables, ctx)

    # --- day-chunked scan loop -----------------------------------------
    chunk = ck.every if mgr is not None else spec.days
    num_chunks = 0
    t_run = time.time()
    while day < spec.days:
        n = min(chunk, spec.days - day)
        state, h, carries, dl = driver.run_chunk(n, state, carries)
        hists.append(h)
        if dl is not None:
            daily_chunks.append(dl)
        day += n
        num_chunks += 1
        if mgr is not None:
            # Each boundary rewrites the full history-so-far: O(days^2)
            # bytes over a run, but history is ~6 scalars/scenario/day
            # (a 1000-day, 100-scenario run totals a few MB), and a
            # self-contained latest checkpoint keeps restore trivial.
            mgr.save(day, {
                "day": np.asarray(day, np.int32),
                "state": _state_to_tree(state),
                "hist": _concat_hists(hists),
            }, extra={"resume_key": _resume_key(spec, engine)})
    if mgr is not None:
        mgr.wait()
    run_wall = time.time() - t_run

    hist = _concat_hists(hists)

    # --- observables ----------------------------------------------------
    if driver.in_scan:
        obs = obs_lib.finalize_all(
            observables, carries, _concat_dailies(daily_chunks), ctx
        )
    else:
        obs = obs_lib.observe_history(observables, hist, ctx)
    obs = obs_lib.observables_to_numpy(obs)

    summaries = summarize_sweep(hist, batch.names, pop.num_people)
    wall = time.time() - t0
    provenance = {
        "engine": engine,
        "num_people": int(pop.num_people),
        "mesh": {"workers": spec.mesh.workers,
                 "scenarios": spec.mesh.scenarios},
        "num_devices": len(jax.devices()),
        "jax_backend": jax.default_backend(),
        "wall_s": round(wall, 3),  # end to end, incl. pop build + compile
        "run_wall_s": round(run_wall, 3),  # the day-chunk loop only
        "chunks": num_chunks,
        "chunk_days": chunk,
        "resumed_from_day": resumed_from,
        "observables_in_scan": driver.in_scan,
    }
    return RunResult(
        spec=spec,
        scenario_names=batch.names,
        history=hist,
        observables=obs,
        summaries=summaries,
        provenance=provenance,
    )


def run_file(path: str, **overrides) -> RunResult:
    """Load a JSON/TOML spec from disk (``--spec`` path) and run it."""
    return run(ExperimentSpec.from_file(path).with_overrides(**overrides))
