"""Uniform run result: what every engine hands back through the facade.

A :class:`RunResult` carries the day-major history pytree (every array
``(days, B)`` — B=1 for single runs, so downstream analysis never branches
on engine), the finalized observables, per-scenario summary rows, the spec
echo, and provenance metadata. ``to_json``/``from_json`` round-trip through
plain JSON (arrays become nested lists) so results are CI artifacts and
``analysis/report.py`` inputs.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Tuple

import numpy as np

from repro.api.spec import ExperimentSpec


def _jsonify(x):
    if isinstance(x, dict):
        return {k: _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.generic,)):
        return x.item()
    return x


@dataclasses.dataclass
class RunResult:
    """What :func:`repro.api.run` returns, for all four engines."""

    spec: ExperimentSpec
    scenario_names: Tuple[str, ...]
    history: Dict[str, np.ndarray]  # day-major, every array (days, B)
    observables: Dict[str, Any]  # {observable name: numpy pytree}
    summaries: list  # one dict row per scenario (analysis/report.py)
    provenance: Dict[str, Any]  # engine, devices, wall clock, resume info

    # ------------------------------------------------------------------
    @property
    def num_scenarios(self) -> int:
        return len(self.scenario_names)

    @property
    def days(self) -> int:
        return int(next(iter(self.history.values())).shape[0])

    def scenario_history(self, i: int) -> Dict[str, np.ndarray]:
        """Scenario ``i``'s (days,) trajectory slices."""
        return {k: v[:, i] for k, v in self.history.items()}

    @property
    def served_from(self) -> Dict[str, Any]:
        """Serving-tier provenance (bucket label, slot placement, warm/
        cold, batch occupancy) when this result came out of a
        :class:`repro.serve.server.SimulationServer`; ``None`` for plain
        :func:`repro.api.run` results."""
        return self.provenance.get("served_from")

    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "spec": self.spec.to_dict(),
            "scenario_names": list(self.scenario_names),
            "history": _jsonify(self.history),
            "observables": _jsonify(self.observables),
            "summaries": _jsonify(self.summaries),
            "provenance": _jsonify(self.provenance),
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def from_dict(cls, d: dict) -> "RunResult":
        hist = {k: np.asarray(v) for k, v in d["history"].items()}
        return cls(
            spec=ExperimentSpec.from_dict(d["spec"]),
            scenario_names=tuple(d["scenario_names"]),
            history=hist,
            observables=d["observables"],
            summaries=list(d["summaries"]),
            provenance=dict(d["provenance"]),
        )

    @classmethod
    def load(cls, path: str) -> "RunResult":
        with open(path) as f:
            return cls.from_dict(json.load(f))
