"""Declarative experiment specification — the input to :func:`repro.api.run`.

An :class:`ExperimentSpec` describes a *study*, not an engine invocation:
the population (by dataset name), the disease (by preset name), the
intervention sweep axes, transmissibility scales, Monte Carlo replicates,
run length, kernel backend, the device-mesh shape, the checkpoint policy,
and the observables to reduce on-device. Everything is plain data —
``to_json``/``from_json`` round-trip exactly, and ``from_toml`` loads the
same fields from a TOML file (the ``--spec experiment.toml`` CLI path).

Which of the four engines executes the study is *derived* from the spec
(`mesh.workers` × `mesh.scenarios` × batch size) by
:func:`repro.api.runner.run`, never hand-picked — though ``engine`` can pin
one for parity testing.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Tuple

from repro.configs import epidemics as epi_lib
from repro.configs import presets
from repro.configs.sweep import ScenarioBatch
from repro.core import transmission as tx_lib

ENGINES = ("auto", "single", "dist", "ensemble", "sharded", "hybrid")
BACKENDS = ("jnp", "scan", "compact", "pallas", "pallas-compact")


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device-mesh shape. ``workers`` shards people/locations of each
    scenario; ``scenarios`` shards the batch axis. (1, 1) means a single
    device; both >1 selects the hybrid 2-D engine."""

    workers: int = 1
    scenarios: int = 1


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    """Day-chunked checkpoint policy, engine-independent: the run loop
    scans ``every``-day chunks and snapshots state + history-so-far at
    each chunk boundary through CheckpointManager (observable carries are
    replayed from the history on resume — they are pure reductions).
    ``directory=None`` disables checkpointing (one unchunked scan)."""

    directory: Optional[str] = None
    every: int = 50
    keep: int = 3
    resume: bool = True  # resume from the latest checkpoint when present


@dataclasses.dataclass(frozen=True)
class ResilienceSpec:
    """Recovery policy for the day-chunked run loop (see
    :mod:`repro.runtime.resilience`). With ``enabled`` the chunk loop runs
    under failure→restore→replay recovery (needs ``checkpoint.directory``):
    capped, backed-off restarts from the newest *valid* snapshot (corrupt
    ones are quarantined), a post-chunk invariant pack treated as a fault
    on violation, per-chunk straggler detection, and elastic shrink onto
    fewer workers on device loss. Pure policy — it never changes the
    science, so it is not part of the checkpoint resume key and recovered
    runs are bitwise-equal to uninterrupted ones."""

    enabled: bool = False
    max_restarts: int = 3
    backoff_s: float = 0.0
    guards: bool = True  # post-chunk invariant pack (runtime/guards.py)
    elastic: bool = True  # device loss -> rebuild on fewer workers
    straggler_window: int = 5
    straggler_factor: float = 4.0
    repartition_on_straggler: bool = False


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One fully-specified epidemic study.

    Sweep axes (``interventions`` × ``tau_scales`` × ``replicates``) expand
    to a :class:`ScenarioBatch` via :meth:`build_batch`; scalar axes mean a
    single run. All fields are JSON/TOML-serializable scalars, strings, or
    lists — diseases and interventions are referenced by preset name
    (:mod:`repro.configs.presets`).
    """

    name: str = "experiment"
    dataset: str = "twin-2k"
    disease: str = "covid"
    days: int = 60
    # --- sweep axes ----------------------------------------------------
    interventions: Tuple[str, ...] = ("none",)
    tau: Optional[float] = None  # base tau; None = the dataset's default
    tau_scales: Tuple[float, ...] = (1.0,)
    replicates: int = 1
    seed: int = 0  # replicate r runs with Monte Carlo seed `seed + r`
    # --- epidemic knobs ------------------------------------------------
    seed_per_day: int = 10
    seed_days: int = 7
    static_network: bool = False
    # --- execution -----------------------------------------------------
    backend: str = "jnp"
    block_size: int = 128
    pack_visits: bool = True
    engine: str = "auto"
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)
    checkpoint: CheckpointSpec = dataclasses.field(default_factory=CheckpointSpec)
    resilience: ResilienceSpec = dataclasses.field(default_factory=ResilienceSpec)
    # --- analysis ------------------------------------------------------
    observables: Tuple[str, ...] = (
        "daily_new_infections", "attack_rate", "peak_day", "ensemble_mean_ci",
        "teps",
    )

    # ------------------------------------------------------------------
    def __post_init__(self):
        # Normalize list-y fields to tuples so frozen specs hash/compare.
        object.__setattr__(self, "interventions", tuple(self.interventions))
        object.__setattr__(self, "tau_scales",
                           tuple(float(t) for t in self.tau_scales))
        object.__setattr__(self, "observables", tuple(self.observables))

    def validate(self) -> "ExperimentSpec":
        from repro.api import observables as obs_lib  # cycle-free at call time

        if self.dataset not in epi_lib.EPIDEMICS:
            raise ValueError(f"unknown dataset '{self.dataset}'; "
                             f"have {sorted(epi_lib.EPIDEMICS)}")
        if self.disease not in presets.DISEASES:
            raise ValueError(f"unknown disease '{self.disease}'; "
                             f"have {sorted(presets.DISEASES)}")
        for name in self.interventions:
            if name not in presets.INTERVENTION_PRESETS:
                raise ValueError(
                    f"unknown intervention preset '{name}'; "
                    f"have {sorted(presets.INTERVENTION_PRESETS)}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got '{self.backend}'")
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got '{self.engine}'")
        for name in self.observables:
            if name not in obs_lib.OBSERVABLES:
                raise ValueError(
                    f"unknown observable '{name}'; "
                    f"have {sorted(obs_lib.OBSERVABLES)}")
        if self.days < 1 or self.replicates < 1:
            raise ValueError("days and replicates must be >= 1")
        if self.mesh.workers < 1 or self.mesh.scenarios < 1:
            raise ValueError("mesh axes must be >= 1")
        if self.num_scenarios == 1 and self.mesh.scenarios > 1:
            raise ValueError(
                f"mesh.scenarios={self.mesh.scenarios} but the sweep axes "
                "produce a single scenario — add replicates/interventions/"
                "tau_scales, or drop the scenarios axis")
        if self.checkpoint.every < 1:
            raise ValueError("checkpoint.every must be >= 1")
        rs = self.resilience
        if rs.enabled and not self.checkpoint.directory:
            raise ValueError(
                "resilience.enabled needs checkpoint.directory — recovery "
                "restores from snapshots")
        if rs.max_restarts < 0 or rs.straggler_window < 2 or \
                rs.straggler_factor <= 1.0:
            raise ValueError(
                "resilience: max_restarts >= 0, straggler_window >= 2, "
                "straggler_factor > 1 required")
        return self

    # ------------------------------------------------------------------
    @property
    def num_scenarios(self) -> int:
        return len(self.interventions) * len(self.tau_scales) * self.replicates

    def compile_fingerprint(self) -> dict:
        """The spec fields that shape a compiled executable, as opposed to
        the ones that merely feed it traced values. Two specs with equal
        fingerprints (plus equal quantized batch width / seeding cap —
        see :mod:`repro.serve.buckets`) can share one warm XLA program:
        tau/seeds/replicate counts ride in as traced parameters, days is
        served by chunked dispatch, and observables are replayed post-run.
        The interventions *tuple* (names, in order) is part of the
        fingerprint because it fixes the batch's shared slot structure."""
        return {
            "dataset": self.dataset,
            "disease": self.disease,
            "interventions": tuple(self.interventions),
            "static_network": bool(self.static_network),
            "backend": self.backend,
            "block_size": int(self.block_size),
            "pack_visits": bool(self.pack_visits),
        }

    def base_tau(self) -> float:
        if self.tau is not None:
            return float(self.tau)
        epi = epi_lib.EPIDEMICS[self.dataset]
        tau = getattr(epi, "tau", None)
        return float(tau) if tau is not None else tx_lib.TransmissionModel().tau

    def build_batch(self) -> ScenarioBatch:
        """Expand the sweep axes to the factorial ScenarioBatch
        (interventions × tau × seeds, seeds innermost)."""
        self.validate()
        base = self.base_tau()
        return ScenarioBatch.from_product(
            interventions={
                n: presets.INTERVENTION_PRESETS[n] for n in self.interventions
            },
            tau=[base * s for s in self.tau_scales],
            disease=presets.DISEASES[self.disease](),
            seeds=[self.seed + r for r in range(self.replicates)],
            seed_per_day=self.seed_per_day,
            seed_days=self.seed_days,
            static_network=self.static_network,
        )

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["interventions"] = list(self.interventions)
        d["tau_scales"] = list(self.tau_scales)
        d["observables"] = list(self.observables)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        _check_fields(cls, d, "ExperimentSpec")
        if "mesh" in d and isinstance(d["mesh"], dict):
            _check_fields(MeshSpec, d["mesh"], "mesh")
            d["mesh"] = MeshSpec(**d["mesh"])
        if "checkpoint" in d and isinstance(d["checkpoint"], dict):
            _check_fields(CheckpointSpec, d["checkpoint"], "checkpoint")
            d["checkpoint"] = CheckpointSpec(**d["checkpoint"])
        if "resilience" in d and isinstance(d["resilience"], dict):
            _check_fields(ResilienceSpec, d["resilience"], "resilience")
            d["resilience"] = ResilienceSpec(**d["resilience"])
        return cls(**d).validate()

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_toml(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(_load_toml(s))

    @classmethod
    def from_file(cls, path: str) -> "ExperimentSpec":
        with open(path, "rb") as f:
            raw = f.read()
        if path.endswith((".toml", ".tml")):
            return cls.from_toml(raw.decode())
        return cls.from_json(raw.decode())

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # ------------------------------------------------------------------
    def with_overrides(self, **kwargs) -> "ExperimentSpec":
        """Functional update; ``None`` values are ignored (the CLI passes
        every flag, with None meaning "not given"). Mesh/checkpoint/
        resilience fields go through flat aliases ``workers``/
        ``scenarios``/``ckpt_dir``/``ckpt_every``/``resilient``/
        ``max_restarts``."""
        updates = {k: v for k, v in kwargs.items() if v is not None}
        mesh = self.mesh
        if "workers" in updates or "scenarios" in updates:
            mesh = dataclasses.replace(
                mesh,
                workers=int(updates.pop("workers", mesh.workers)),
                scenarios=int(updates.pop("scenarios", mesh.scenarios)),
            )
        ckpt = self.checkpoint
        if "ckpt_dir" in updates or "ckpt_every" in updates:
            ckpt = dataclasses.replace(
                ckpt,
                directory=updates.pop("ckpt_dir", ckpt.directory),
                every=int(updates.pop("ckpt_every", ckpt.every)),
            )
        res = self.resilience
        if "resilient" in updates or "max_restarts" in updates:
            res = dataclasses.replace(
                res,
                enabled=bool(updates.pop("resilient", res.enabled)),
                max_restarts=int(updates.pop("max_restarts",
                                             res.max_restarts)),
            )
        return dataclasses.replace(
            self, mesh=mesh, checkpoint=ckpt, resilience=res, **updates
        ).validate()


def _check_fields(cls, d: dict, label: str) -> None:
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown {label} field(s) {sorted(unknown)}; "
                         f"have {sorted(known)}")


def _load_toml(s: str) -> dict:
    try:
        import tomllib  # Python >= 3.11
    except ImportError:
        try:
            import tomli as tomllib  # the pre-3.11 backport
        except ImportError as e:  # pragma: no cover - both baked into CI image
            raise ImportError(
                "TOML specs need tomllib (py>=3.11) or tomli; "
                "use a JSON spec instead"
            ) from e
    return tomllib.loads(s)
