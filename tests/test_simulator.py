import numpy as np
import pytest

from repro.core import disease, simulator, transmission
from repro.data import digital_twin_population
from repro.engine.core import EngineCore, state_to_tree

import jax.numpy as jnp


def make_sim(pop, *, seed, **kw):
    return EngineCore.single(
        pop, disease.covid_model(),
        transmission.TransmissionModel(tau=1.5e-5), seed=seed, **kw,
    )


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(1500, seed=2, name="t1500")


@pytest.fixture(scope="module")
def run60(pop):
    sim = make_sim(pop, seed=11)
    final, hist = sim.run1(60)
    return sim, final, hist


def test_monotone_cumulative(run60):
    _, _, hist = run60
    assert (np.diff(hist["cumulative"]) >= 0).all()


def test_population_conserved(run60):
    sim, final, hist = run60
    S = sim.batch[0].disease.num_states
    counts = np.bincount(np.asarray(final.health), minlength=S)
    assert counts.sum() == sim.pop.num_people


def test_bounded_by_population(run60):
    sim, _, hist = run60
    assert hist["cumulative"][-1] <= sim.pop.num_people
    assert (hist["infectious"] <= sim.pop.num_people).all()


def test_epidemic_occurs(run60):
    _, _, hist = run60
    assert hist["cumulative"][-1] > 100  # outbreak took off
    assert hist["contacts"].sum() > 0


def test_same_seed_identical(pop):
    h1 = make_sim(pop, seed=5).run1(20)[1]
    h2 = make_sim(pop, seed=5).run1(20)[1]
    np.testing.assert_array_equal(h1["cumulative"], h2["cumulative"])
    np.testing.assert_array_equal(h1["contacts"], h2["contacts"])


def test_different_seed_differs(pop):
    h1 = make_sim(pop, seed=5).run1(25)[1]
    h2 = make_sim(pop, seed=6).run1(25)[1]
    assert not np.array_equal(h1["cumulative"], h2["cumulative"])


def test_backends_agree_end_to_end(pop):
    hists = {}
    for backend in ("jnp", "scan", "compact"):
        hists[backend] = make_sim(pop, seed=5, backend=backend).run1(15)[1]
    for backend in ("scan", "compact"):
        np.testing.assert_array_equal(
            hists["jnp"]["cumulative"], hists[backend]["cumulative"]
        )
        np.testing.assert_array_equal(
            hists["jnp"]["contacts"], hists[backend]["contacts"]
        )


def test_packed_and_unpacked_layouts_agree(pop):
    """Occupancy-aware packing is epidemiologically inert end-to-end: the
    packed (default) and canonical layouts produce the same trajectory."""
    h_packed = make_sim(pop, seed=5, pack_visits=True).run1(15)[1]
    h_plain = make_sim(pop, seed=5, pack_visits=False).run1(15)[1]
    np.testing.assert_array_equal(h_packed["cumulative"], h_plain["cumulative"])
    np.testing.assert_array_equal(h_packed["contacts"], h_plain["contacts"])


def test_static_network_weekly_repeat(pop):
    """EpiHiper-mode: contact draws keyed by day-of-week => with everyone
    infectious+susceptible held fixed, contacts repeat weekly."""
    tm = transmission.TransmissionModel(tau=0.0)  # no state evolution
    sim = EngineCore.single(
        pop, disease.covid_model(), tm, seed=5, static_network=True,
        seed_per_day=0, seed_days=0,
    )
    # make everyone mildly infectious & susceptible so contacts are counted
    state = sim.init_state1()
    import dataclasses as dc
    # seed a fixed set of infectious people via the disease model
    h = np.zeros(pop.num_people, np.int32)
    h[:50] = sim.batch[0].disease.state_index("Isym")
    state = dc.replace(
        state, health=jnp.asarray(h),
        dwell=jnp.full((pop.num_people,), 1e9, jnp.float32),
    )
    _, hist = sim.run1(14, state=state)
    c = hist["contacts"]
    np.testing.assert_array_equal(c[:7], c[7:14])


def test_run_eager_matches_scan(pop):
    sim = make_sim(pop, seed=5)
    _, h1 = sim.run1(10)
    _, h2, times = simulator.run_eager(sim, 10)
    np.testing.assert_array_equal(h1["cumulative"], h2["cumulative"])
    assert set(times) == {"visits", "interact", "update"}


def test_checkpoint_restore_exact(pop):
    sim = make_sim(pop, seed=5)
    s_mid, h1 = sim.run1(10)
    payload = {k: np.asarray(v) for k, v in state_to_tree(s_mid).items()}
    # run 10 more from the round-tripped checkpoint payload
    restored = simulator.SimState(
        **{k: jnp.asarray(v) for k, v in payload.items()}
    )
    _, h_resumed = sim.run1(10, state=restored)
    _, h_full = sim.run1(20)
    np.testing.assert_array_equal(
        h_full["cumulative"][10:], h_resumed["cumulative"]
    )
