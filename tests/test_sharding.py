import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.sharding import MeshRules


def one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_divisibility_guard():
    rules = MeshRules.for_mesh(one_device_mesh())
    rules.rules["heads"] = "model"
    # 1-device mesh: everything divides; fake a 16-wide axis via rule check
    spec = rules.spec((40, 64), ("heads", "head_dim"))
    assert spec == P("model", None)  # divides by 1


def test_prunes_missing_pod_axis():
    rules = MeshRules.for_mesh(one_device_mesh())
    assert rules.rules["batch"] == ("data",)  # 'pod' pruned


def test_duplicate_axis_dropped():
    rules = MeshRules.for_mesh(one_device_mesh())
    rules.rules["embed"] = "model"
    rules.rules["mlp"] = "model"
    spec = rules.spec((64, 128), ("embed", "mlp"))
    # second use of 'model' must drop to None
    assert spec == P("model", None)
    assert any(w == "duplicate" for *_, w in rules.dropped)


def test_param_specs_cover_all_leaves():
    from repro.configs import ARCHS
    from repro.models import model as M

    rules = MeshRules.for_mesh(one_device_mesh())
    for name in ("qwen3-14b", "mixtral-8x7b", "recurrentgemma-9b",
                 "mamba2-130m", "whisper-base"):
        specs = M.param_partition_specs(ARCHS[name], rules, 64)
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert all(isinstance(l, P) for l in leaves)
        abstract = M.abstract_params(ARCHS[name], 64)
        assert len(leaves) == len(jax.tree.leaves(abstract))
