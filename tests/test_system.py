"""End-to-end behaviour tests for the paper's system (Loimos-in-JAX)."""

import numpy as np
import pytest

from repro.core import disease, transmission
from repro.core import interventions as iv
from repro.data import watts_strogatz_population
from repro.engine.core import EngineCore


@pytest.fixture(scope="module")
def ws_pop():
    return watts_strogatz_population(1200, 300, seed=7, name="ws-sys")


def test_epidemic_curve_shape(ws_pop):
    """Tuned transmissibility produces the paper's canonical curve: ramp,
    peak, decline (the workload pattern Figs. 4/7 are about)."""
    sim = EngineCore.single(
        ws_pop, disease.covid_model(),
        transmission.TransmissionModel(tau=6e-6), seed=1,
    )
    _, hist = sim.run1(120)
    inf = hist["infectious"]
    peak = int(np.argmax(inf))
    assert 5 < peak < 115  # interior peak
    assert inf[peak] > 50
    assert inf[-1] < inf[peak] * 0.7  # declining tail


def test_interaction_load_tracks_infectious(ws_pop):
    """§V-D: with short-circuit, interaction work tracks infectious count.
    We verify the *semantic* precondition: contacts correlate strongly with
    the infectious count over the run."""
    sim = EngineCore.single(
        ws_pop, disease.covid_model(),
        transmission.TransmissionModel(tau=6e-6), seed=1,
    )
    _, hist = sim.run1(120)
    c = hist["contacts"].astype(float)
    i = hist["infectious"].astype(float)
    mask = i > 0
    rho = np.corrcoef(c[mask], i[mask])[0, 1]
    # contacts require sus x inf co-presence, so the correlation weakens
    # once susceptibles deplete — 0.6 still demonstrates load tracking
    assert rho > 0.6


def test_full_workflow_with_interventions(ws_pop):
    """Trigger -> selector -> action pipeline changes the epidemic."""
    ivs = [
        iv.Intervention("mask-mandate", iv.CaseThreshold(on=30),
                        iv.Everyone(), iv.ScaleInfectivity(0.4)),
        iv.Intervention("vaccinate-seniors", iv.DayRange(10),
                        iv.AgeGroupIs(2), iv.Vaccinate(0.8)),
    ]
    base = EngineCore.single(
        ws_pop, disease.covid_model(),
        transmission.TransmissionModel(tau=6e-6), seed=1,
    ).run1(120)[1]
    treated = EngineCore.single(
        ws_pop, disease.covid_model(),
        transmission.TransmissionModel(tau=6e-6), seed=1, interventions=ivs,
    ).run1(120)[1]
    assert treated["cumulative"][-1] < base["cumulative"][-1]


def test_dynamic_vs_static_network_differs():
    """Fig 9's mechanism: the dynamic-network mode re-samples contacts
    every week while the static mode reuses day-of-week draws; outcomes
    differ for the same seed."""
    pop = watts_strogatz_population(800, 200, seed=3, name="ws-val")
    tm = transmission.TransmissionModel(tau=6e-6)
    dyn = EngineCore.single(
        pop, disease.sir_model(), tm, seed=5, static_network=False
    ).run1(40)[1]
    sta = EngineCore.single(
        pop, disease.sir_model(), tm, seed=5, static_network=True
    ).run1(40)[1]
    assert not np.array_equal(dyn["cumulative"], sta["cumulative"])
