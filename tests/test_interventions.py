import pytest

from repro.core import disease, interventions as iv, transmission
from repro.engine.core import EngineCore
from repro.data import digital_twin_population


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(1500, seed=3, name="ivpop")


def run(pop, ivs, days=50, tau=2e-5, seed=4):
    sim = EngineCore.single(
        pop, disease.covid_model(), transmission.TransmissionModel(tau=tau),
        interventions=ivs, seed=seed,
    )
    return sim.run1(days)[1]


def test_school_closure_reduces_attack_rate(pop):
    base = run(pop, [])
    closed = run(pop, [iv.Intervention(
        "close-schools", iv.DayRange(0), iv.LocTypeIs(2), iv.CloseLocations()
    )])
    assert closed["cumulative"][-1] < base["cumulative"][-1]


def test_vaccination_reduces_attack_rate(pop):
    base = run(pop, [])
    vax = run(pop, [iv.Intervention(
        "vaccinate", iv.DayRange(0), iv.RandomFraction(0.6, salt=1),
        iv.Vaccinate(efficacy=0.9),
    )])
    assert vax["cumulative"][-1] < 0.9 * base["cumulative"][-1]


def test_isolation_of_everyone_stops_spread(pop):
    isolated = run(pop, [iv.Intervention(
        "lockdown", iv.DayRange(0), iv.Everyone(), iv.Isolate()
    )])
    # only the seeded infections occur (10/day for 7 days)
    assert isolated["cumulative"][-1] == 70


def test_case_threshold_trigger_fires(pop):
    ivs = [iv.Intervention(
        "emergency", iv.CaseThreshold(on=50), iv.Everyone(), iv.Isolate()
    )]
    hist = run(pop, ivs)
    base = run(pop, [])
    assert hist["cumulative"][-1] < base["cumulative"][-1]
    # spread is throttled soon after the threshold crossing
    assert hist["infectious"].max() <= base["infectious"].max()


def test_masking_scales_transmission(pop):
    masked = run(pop, [iv.Intervention(
        "masks", iv.DayRange(0), iv.Everyone(), iv.ScaleInfectivity(0.3)
    )])
    base = run(pop, [])
    assert masked["cumulative"][-1] < base["cumulative"][-1]


# ---------------------------------------------------------------------------
# slot-name uniqueness (both families share one scenario-level namespace)
# ---------------------------------------------------------------------------


def test_duplicate_slot_names_raise():
    dup = [
        iv.Intervention("masks", iv.DayRange(0), iv.Everyone(),
                        iv.ScaleInfectivity(0.5)),
        iv.Intervention("masks", iv.DayRange(10), iv.Everyone(),
                        iv.ScaleInfectivity(0.3)),
    ]
    with pytest.raises(ValueError, match="duplicate intervention name"):
        iv.compile_interventions(dup, _DummyPop(), seed=0)
    with pytest.raises(ValueError, match="duplicate intervention name"):
        iv.compile_iv_params(dup, _DummyPop(), seed=0)


def test_duplicate_names_across_families_raise():
    mixed = [
        iv.Intervention("tti", iv.DayRange(0), iv.Everyone(),
                        iv.ScaleInfectivity(0.5)),
        iv.TestTraceIsolate("tti", tests_per_day=10),
    ]
    with pytest.raises(ValueError, match="duplicate intervention name"):
        iv.compile_iv_params(mixed, _DummyPop(), seed=0)


class _DummyPop:
    import numpy as _np

    num_people = 8
    num_locations = 2
    loc_type = _np.zeros(2, _np.int32)
    age_group = _np.zeros(8, _np.int32)


# ---------------------------------------------------------------------------
# per-agent family: test-trace-isolate behavior
# ---------------------------------------------------------------------------


def test_tti_reduces_attack_rate(pop):
    base = run(pop, [])
    tti = run(pop, [iv.TestTraceIsolate("tti", tests_per_day=60)])
    assert tti["cumulative"][-1] < base["cumulative"][-1]
    assert tti["tests_used"].sum() > 0
    assert tti["isolated"].sum() > 0
    assert tti["traced"].sum() > 0
    # baseline arm emits the constant-zero TTI stats
    assert base["tests_used"].sum() == 0
    assert base["isolated"].sum() == 0


def test_tti_budget_never_exceeded(pop):
    hist = run(pop, [iv.TestTraceIsolate("tti", tests_per_day=25)])
    assert hist["tests_used"].max() <= 25
    # the budget saturates once the symptomatic queue outgrows it
    assert hist["tests_used"].max() == 25


def test_tti_tracing_outperforms_testing_alone(pop):
    no_trace = run(pop, [iv.TestTraceIsolate(
        "ti", tests_per_day=60, trace=False)])
    traced = run(pop, [iv.TestTraceIsolate("tti", tests_per_day=60)])
    assert no_trace["traced"].sum() == 0
    assert traced["traced"].sum() > 0
    assert traced["cumulative"][-1] <= no_trace["cumulative"][-1]


def test_tti_zero_budget_is_baseline_bitwise(pop):
    """An enabled tracing slot with zero capacity never produces a
    positive, so the source channel is identically zero and the traced
    program's trajectory matches the baseline bitwise — the algebraic
    no-op guarantee of the second accumulator."""
    base = run(pop, [])
    zero = run(pop, [iv.TestTraceIsolate("tti", tests_per_day=0)])
    for k in base:
        assert (base[k] == zero[k]).all(), k


def test_tti_disabled_slot_is_baseline_bitwise(pop):
    """iv_enabled=False on a per-agent slot reproduces the pre-PR history
    bitwise (the acceptance criterion for zero-TTI specs)."""
    base = run(pop, [])
    sim = EngineCore.single(
        pop, disease.covid_model(), transmission.TransmissionModel(tau=2e-5),
        interventions=[iv.TestTraceIsolate("tti", tests_per_day=50)],
        iv_enabled=[False], seed=4,
    )
    off = sim.run1(50)[1]
    for k in base:
        assert (base[k] == off[k]).all(), k


def test_tti_start_day_delays_testing(pop):
    hist = run(pop, [iv.TestTraceIsolate(
        "tti", tests_per_day=30, start_day=20)])
    assert hist["tests_used"][:20].sum() == 0
    assert hist["tests_used"][20:].sum() > 0


def test_tti_mixed_with_classic_family(pop):
    """Both families compose in one scenario: classic masks slot plus a
    per-agent TTI slot, each doing its job."""
    hist = run(pop, [
        iv.Intervention("masks", iv.DayRange(0), iv.Everyone(),
                        iv.ScaleInfectivity(0.5)),
        iv.TestTraceIsolate("tti", tests_per_day=40),
    ])
    base = run(pop, [])
    assert hist["cumulative"][-1] < base["cumulative"][-1]
    assert hist["tests_used"].sum() > 0


def test_trigger_hysteresis():
    trig = iv.CaseThreshold(on=100, off=50)
    import jax.numpy as jnp
    on = trig(0, {"infectious": jnp.asarray(120, jnp.int32)},
              jnp.asarray(False))
    assert bool(on)
    still_on = trig(1, {"infectious": jnp.asarray(80, jnp.int32)},
                    jnp.asarray(True))
    assert bool(still_on)
    off = trig(2, {"infectious": jnp.asarray(30, jnp.int32)},
               jnp.asarray(True))
    assert not bool(off)
