import pytest

from repro.core import disease, interventions as iv, transmission
from repro.engine.core import EngineCore
from repro.data import digital_twin_population


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(1500, seed=3, name="ivpop")


def run(pop, ivs, days=50, tau=2e-5, seed=4):
    sim = EngineCore.single(
        pop, disease.covid_model(), transmission.TransmissionModel(tau=tau),
        interventions=ivs, seed=seed,
    )
    return sim.run1(days)[1]


def test_school_closure_reduces_attack_rate(pop):
    base = run(pop, [])
    closed = run(pop, [iv.Intervention(
        "close-schools", iv.DayRange(0), iv.LocTypeIs(2), iv.CloseLocations()
    )])
    assert closed["cumulative"][-1] < base["cumulative"][-1]


def test_vaccination_reduces_attack_rate(pop):
    base = run(pop, [])
    vax = run(pop, [iv.Intervention(
        "vaccinate", iv.DayRange(0), iv.RandomFraction(0.6, salt=1),
        iv.Vaccinate(efficacy=0.9),
    )])
    assert vax["cumulative"][-1] < 0.9 * base["cumulative"][-1]


def test_isolation_of_everyone_stops_spread(pop):
    isolated = run(pop, [iv.Intervention(
        "lockdown", iv.DayRange(0), iv.Everyone(), iv.Isolate()
    )])
    # only the seeded infections occur (10/day for 7 days)
    assert isolated["cumulative"][-1] == 70


def test_case_threshold_trigger_fires(pop):
    ivs = [iv.Intervention(
        "emergency", iv.CaseThreshold(on=50), iv.Everyone(), iv.Isolate()
    )]
    hist = run(pop, ivs)
    base = run(pop, [])
    assert hist["cumulative"][-1] < base["cumulative"][-1]
    # spread is throttled soon after the threshold crossing
    assert hist["infectious"].max() <= base["infectious"].max()


def test_masking_scales_transmission(pop):
    masked = run(pop, [iv.Intervention(
        "masks", iv.DayRange(0), iv.Everyone(), iv.ScaleInfectivity(0.3)
    )])
    base = run(pop, [])
    assert masked["cumulative"][-1] < base["cumulative"][-1]


def test_trigger_hysteresis():
    trig = iv.CaseThreshold(on=100, off=50)
    import jax.numpy as jnp
    on = trig(0, {"infectious": jnp.asarray(120)}, jnp.asarray(False))
    assert bool(on)
    still_on = trig(1, {"infectious": jnp.asarray(80)}, jnp.asarray(True))
    assert bool(still_on)
    off = trig(2, {"infectious": jnp.asarray(30)}, jnp.asarray(True))
    assert not bool(off)
