"""The central correctness test: all interaction backends vs the dense
oracle vs the literal serial event-queue DES (Algorithm 1), on both the
canonical (loc, start)-sorted layout and the occupancy-packed layout."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import contact as contact_lib
from repro.core import population as pop_lib
from repro.kernels.interactions import ops as iops
from repro.kernels.interactions import ref as iref

from des_oracle import serial_des_day

ALL_BACKENDS = ("jnp", "scan", "compact", "pallas", "pallas-compact")


def make_case(seed, Vn=220, L=30, P=90, b=64):
    rs = np.random.default_rng(seed)
    person = rs.integers(0, P, Vn)
    loc = rs.integers(0, L, Vn)
    start = rs.uniform(0, 80000, Vn).astype(np.float32)
    end = (start + rs.uniform(600, 20000, Vn)).astype(np.float32)
    day_v = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    occ = contact_lib.max_occupancy_fast(L, loc, start, end)
    p_loc = np.asarray(contact_lib.MinMaxAlpha().probability(occ), np.float32)
    sus_pp = rs.uniform(0.0, 1.0, P).astype(np.float32)
    sus_pp[rs.random(P) < 0.3] = 0.0
    inf_pp = np.zeros(P, np.float32)
    inf_pp[rs.choice(P, 14, replace=False)] = rs.uniform(0.5, 1.0, 14)
    return day_v, p_loc, sus_pp, inf_pp, (person, loc, start, end)


def layout_args(layout, extent, p_loc, sus_pp, inf_pp, b, seed, day):
    """Backend args for any visit layout (DayVisits or PackedDayVisits)."""
    L = len(p_loc)
    sched = pop_lib.build_block_schedule(layout.loc, extent, b)
    safe = np.maximum(layout.person, 0)
    sus_v = jnp.asarray(sus_pp[safe] * layout.active)
    inf_v = jnp.asarray(inf_pp[safe] * layout.active)
    args = (
        jnp.asarray(layout.person), jnp.asarray(layout.loc),
        jnp.asarray(layout.start), jnp.asarray(layout.end),
        jnp.asarray(p_loc[np.minimum(layout.loc, L - 1)]),
        sus_v, inf_v,
        jnp.asarray(sched.row_block), jnp.asarray(sched.col_block),
        jnp.asarray(sched.row_start.astype(np.int32)),
        jnp.asarray(sched.pair_active.astype(np.int32)),
        iops.col_has_infectious(
            inf_v, jnp.asarray(layout.person), sched.num_blocks, b
        ),
        iops.row_has_susceptible(
            sus_v, jnp.asarray(layout.person), sched.num_blocks, b
        ),
        jnp.asarray([seed, day], jnp.uint32),
    )
    return args, sched


def backend_args(day_v, p_loc, sus_pp, inf_pp, b, seed, day):
    return layout_args(day_v, day_v.num_real, p_loc, sus_pp, inf_pp, b, seed, day)


def fold_to_people(num_people, layout, acc):
    A = np.zeros(num_people)
    np.add.at(A, np.maximum(layout.person, 0), np.asarray(acc) * layout.active)
    return A


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backends_match_dense(seed, backend):
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(seed, b=b)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 123, 5)
    acc_d, cnt_d = iref.interactions_dense(*args[:7], 123, 5)
    acc, cnt = iops.interactions_auto(*args, block_size=b, backend=backend)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_d), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_d))


@pytest.mark.parametrize("seed", [3, 4])
def test_matches_serial_event_queue_des(seed):
    """Tensorized pairwise-overlap == literal Algorithm 1, bitwise on the
    contact set and propensities (f32 sum tolerance)."""
    b = 64
    day_v, p_loc, sus_pp, inf_pp, raw = make_case(seed, b=b)
    person, loc, start, end = raw
    P = len(sus_pp)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 9, 2)
    acc, cnt = iops.interactions_auto(*args, block_size=b, backend="jnp")
    A_fast = fold_to_people(P, day_v, acc)
    A_serial, contacts_serial = serial_des_day(
        person, loc, start, end, p_loc, sus_pp, inf_pp, 9, 2
    )
    np.testing.assert_allclose(A_fast, A_serial, rtol=2e-4, atol=1e-4)
    assert int(np.asarray(cnt).sum()) == contacts_serial


def test_block_schedule_covers_all_same_loc_pairs():
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(7, b=32)
    sched = pop_lib.build_block_schedule(day_v.loc, day_v.num_real, 32)
    covered = set(zip(sched.row_block[sched.pair_active].tolist(),
                      sched.col_block[sched.pair_active].tolist()))
    n = day_v.num_real
    for i in range(n):
        for j in range(n):
            if day_v.loc[i] == day_v.loc[j]:
                assert (i // 32, j // 32) in covered


# ---------------------------------------------------------------------------
# Epidemic extremes: every backend must agree bitwise with every other and
# allclose with the dense oracle when the short-circuit flags are all-dead,
# all-live, or the schedule is degenerate.
# ---------------------------------------------------------------------------


_EXTREME_SEEDS = {
    "zero_infectious": 100, "all_infectious": 101,
    "all_padding_block": 102, "single_giant_location": 103,
}


def _extreme_case(kind, b=64):
    rs = np.random.default_rng(_EXTREME_SEEDS[kind])
    L, P = 20, 80
    if kind == "all_padding_block":
        # Real visits fill exactly one block; two more blocks are padding.
        Vn = b
        person = rs.integers(0, P, Vn)
        loc = rs.integers(0, L, Vn)
        start = rs.uniform(0, 40000, Vn).astype(np.float32)
        end = (start + rs.uniform(600, 9000, Vn)).astype(np.float32)
        day_v = pop_lib.pack_day(person, loc, start, end, pad_to=3 * b,
                                 pad_multiple=b)
    elif kind == "single_giant_location":
        # One location spanning a multi-block band (the paper's worst case).
        Vn = 4 * b + 17
        person = rs.integers(0, P, Vn)
        loc = np.zeros(Vn, np.int64)
        start = rs.uniform(0, 40000, Vn).astype(np.float32)
        end = (start + rs.uniform(600, 9000, Vn)).astype(np.float32)
        day_v = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    else:  # zero_infectious / all_infectious share a generic schedule
        Vn = 3 * b + 11
        person = rs.integers(0, P, Vn)
        loc = rs.integers(0, L, Vn)
        start = rs.uniform(0, 40000, Vn).astype(np.float32)
        end = (start + rs.uniform(600, 9000, Vn)).astype(np.float32)
        day_v = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    p_loc = np.full(L, 0.6, np.float32)
    sus_pp = rs.uniform(0.1, 1.0, P).astype(np.float32)
    inf_pp = rs.uniform(0.1, 1.0, P).astype(np.float32)
    if kind == "zero_infectious":
        inf_pp[:] = 0.0
    elif kind == "all_infectious":
        pass  # everyone infectious AND susceptible: every tile live
    else:
        inf_pp[rs.random(P) < 0.7] = 0.0
    return day_v, p_loc, sus_pp, inf_pp


@pytest.mark.parametrize("kind", [
    "zero_infectious", "all_infectious", "all_padding_block",
    "single_giant_location",
])
@pytest.mark.parametrize("packed", [False, True])
def test_extremes_all_backends_bitwise_equal(kind, packed):
    b = 64
    day_v, p_loc, sus_pp, inf_pp = _extreme_case(kind, b=b)
    if packed:
        layout = pop_lib.pack_day_occupancy(day_v, b)
        extent = layout.extent
    else:
        layout, extent = day_v, day_v.num_real
    args, _ = layout_args(layout, extent, p_loc, sus_pp, inf_pp, b, 77, 3)
    acc_d, cnt_d = iref.interactions_dense(*args[:7], 77, 3)
    outs = {
        be: iops.interactions_auto(*args, block_size=b, backend=be)
        for be in ALL_BACKENDS
    }
    for be, (acc, cnt) in outs.items():
        np.testing.assert_allclose(
            np.asarray(acc), np.asarray(acc_d), rtol=1e-6, err_msg=be
        )
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_d),
                                      err_msg=be)
        # bitwise equality across backends (accumulation-order contract)
        np.testing.assert_array_equal(
            np.asarray(acc), np.asarray(outs["jnp"][0]), err_msg=be
        )
    if kind == "zero_infectious":
        assert float(np.abs(np.asarray(outs["jnp"][0])).sum()) == 0.0
        assert int(np.asarray(outs["jnp"][1]).sum()) == 0
    if kind == "all_infectious":
        assert int(np.asarray(outs["jnp"][1]).sum()) > 0


# ---------------------------------------------------------------------------
# Occupancy-aware packing: same epidemiology, smaller schedule.
# ---------------------------------------------------------------------------


def _skewed_case(seed, b=64):
    """Many small locations + a few giants — the layout packing targets."""
    rs = np.random.default_rng(seed)
    L, P, Vn = 40, 150, 800
    person = rs.integers(0, P, Vn)
    loc = rs.integers(0, L, Vn)
    loc[rs.random(Vn) < 0.35] = 3  # giant location
    start = rs.uniform(0, 60000, Vn).astype(np.float32)
    end = (start + rs.uniform(600, 15000, Vn)).astype(np.float32)
    day_v = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    p_loc = rs.uniform(0.1, 0.9, L).astype(np.float32)
    sus_pp = rs.uniform(0.0, 1.0, P).astype(np.float32)
    inf_pp = np.where(rs.random(P) < 0.15,
                      rs.uniform(0.2, 1.0, P), 0.0).astype(np.float32)
    return day_v, p_loc, sus_pp, inf_pp


@pytest.mark.parametrize("seed", [11, 12])
def test_packed_layout_matches_dense_oracle(seed):
    """Per-person propensities on the packed layout == dense oracle on the
    canonical layout (layout is epidemiologically free), and the packed
    schedule is strictly smaller."""
    b = 64
    day_v, p_loc, sus_pp, inf_pp = _skewed_case(seed, b=b)
    P = len(sus_pp)
    packed = pop_lib.pack_day_occupancy(day_v, b)
    assert packed.num_real == day_v.num_real
    assert int((packed.person >= 0).sum()) == day_v.num_real

    args_u, sched_u = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 5, 1)
    args_p, sched_p = layout_args(
        packed, packed.extent, p_loc, sus_pp, inf_pp, b, 5, 1
    )
    assert sched_p.num_pairs < sched_u.num_pairs

    acc_d, cnt_d = iref.interactions_dense(*args_u[:7], 5, 1)
    A_oracle = fold_to_people(P, day_v, acc_d)
    for backend in ALL_BACKENDS:
        acc, cnt = iops.interactions_auto(*args_p, block_size=b,
                                          backend=backend)
        A = fold_to_people(P, packed, acc)
        np.testing.assert_allclose(A, A_oracle, rtol=1e-5, atol=1e-6,
                                   err_msg=backend)
        assert int(np.asarray(cnt).sum()) == int(np.asarray(cnt_d).sum())


def test_packed_schedule_covers_all_same_loc_pairs():
    b = 32
    day_v, p_loc, sus_pp, inf_pp = _skewed_case(13, b=b)
    packed = pop_lib.pack_day_occupancy(day_v, b)
    sched = pop_lib.build_block_schedule(packed.loc, packed.extent, b)
    covered = set(zip(sched.row_block[sched.pair_active].tolist(),
                      sched.col_block[sched.pair_active].tolist()))
    real = np.flatnonzero(packed.person >= 0)
    loc = packed.loc
    for i in real:
        for j in real:
            if loc[i] == loc[j]:
                assert (i // b, j // b) in covered


def test_short_circuit_zero_infectious():
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(8, b=b)
    inf_pp[:] = 0.0
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 1, 0)
    for backend in ALL_BACKENDS:
        acc, cnt = iops.interactions_auto(*args, block_size=b, backend=backend)
        assert float(np.abs(np.asarray(acc)).sum()) == 0.0
        assert int(np.asarray(cnt).sum()) == 0


# ---------------------------------------------------------------------------
# In-kernel traversed-edge telemetry: the pallas-compact SMEM accumulator
# must equal the host-side count (sum of per-visit contact counts) exactly.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", [
    "zero_infectious", "all_infectious", "all_padding_block",
    "single_giant_location",
])
@pytest.mark.parametrize("packed", [False, True])
def test_in_kernel_edge_counter_matches_host(kind, packed):
    b = 64
    day_v, p_loc, sus_pp, inf_pp = _extreme_case(kind, b=b)
    if packed:
        layout = pop_lib.pack_day_occupancy(day_v, b)
        extent = layout.extent
    else:
        layout, extent = day_v, day_v.num_real
    args, _ = layout_args(layout, extent, p_loc, sus_pp, inf_pp, b, 21, 4)
    for backend in ALL_BACKENDS:
        acc, cnt, edges = iops.interactions_auto_edges(
            *args, block_size=b, backend=backend
        )
        assert int(np.asarray(edges)) == int(np.asarray(cnt).sum()), backend
    if kind == "all_infectious":
        _, cnt, edges = iops.interactions_auto_edges(
            *args, block_size=b, backend="pallas-compact"
        )
        assert int(np.asarray(edges)) > 0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_in_kernel_edge_counter_random_schedules(seed):
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(seed, b=b)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 123, 5)
    _, cnt_ref, edges_ref = iops.interactions_auto_edges(
        *args, block_size=b, backend="jnp"
    )
    _, cnt, edges = iops.interactions_auto_edges(
        *args, block_size=b, backend="pallas-compact"
    )
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))
    assert int(np.asarray(edges)) == int(np.asarray(edges_ref))
    assert int(np.asarray(edges)) == int(np.asarray(cnt_ref).sum())


# ---------------------------------------------------------------------------
# Second kernel accumulator: per-visit traced-contact counts. Every backend
# must match the dense-numpy tracing oracle bitwise, leave the exposure/
# count/edge outputs bitwise-unchanged relative to the untraced call, and
# vanish exactly when the source channel is identically zero.
# ---------------------------------------------------------------------------


def _with_sources(sus_pp, inf_pp, layout, rs):
    """A per-visit tracing-source vector marking ~half the infectious
    people as today's positives (sources are always infectious)."""
    P = len(sus_pp)
    src_pp = np.where(
        (inf_pp > 0) & (rs.random(P) < 0.5), 1.0, 0.0
    ).astype(np.float32)
    safe = np.maximum(layout.person, 0)
    return src_pp, jnp.asarray(src_pp[safe] * layout.active)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_traced_accumulator_matches_dense_oracle(seed, backend):
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(seed, b=b)
    rs = np.random.default_rng(1000 + seed)
    _, src_v = _with_sources(sus_pp, inf_pp, day_v, rs)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 123, 5)
    acc_d, cnt_d, trc_d = iref.interactions_dense_traced(
        *args[:7], src_v, 123, 5
    )
    acc, cnt, edges, trc = iops.interactions_auto_traced(
        *args, block_size=b, backend=backend, src_val=src_v
    )
    np.testing.assert_array_equal(np.asarray(trc), np.asarray(trc_d))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_d))
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_d), rtol=1e-6)
    assert int(np.asarray(edges)) == int(np.asarray(cnt).sum())
    # tracing condition is a strict subset of the contact condition
    assert (np.asarray(trc) <= np.asarray(cnt)).all()
    assert int(np.asarray(trc).sum()) > 0  # the case actually exercises it


@pytest.mark.parametrize("seed", [0, 1])
def test_traced_call_leaves_exposure_bitwise_unchanged(seed):
    """Adding the second accumulator must not perturb a single bit of the
    exposure/count outputs on any backend (same tiles, same order)."""
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(seed, b=b)
    rs = np.random.default_rng(2000 + seed)
    _, src_v = _with_sources(sus_pp, inf_pp, day_v, rs)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 9, 2)
    for backend in ALL_BACKENDS:
        acc0, cnt0, edges0 = iops.interactions_auto_edges(
            *args, block_size=b, backend=backend
        )
        acc, cnt, edges, _ = iops.interactions_auto_traced(
            *args, block_size=b, backend=backend, src_val=src_v
        )
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc0))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt0))
        assert int(np.asarray(edges)) == int(np.asarray(edges0))


def test_traced_accumulator_zero_sources():
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(5, b=b)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 3, 1)
    src_v = jnp.zeros((args[0].shape[0],), jnp.float32)
    for backend in ALL_BACKENDS:
        _, _, _, trc = iops.interactions_auto_traced(
            *args, block_size=b, backend=backend, src_val=src_v
        )
        assert int(np.abs(np.asarray(trc)).sum()) == 0, backend


@pytest.mark.parametrize("kind", [
    "zero_infectious", "all_infectious", "all_padding_block",
    "single_giant_location",
])
def test_traced_accumulator_extremes_bitwise_across_backends(kind):
    """Epidemic extremes: the tracing accumulator is bitwise identical
    across all five backends on the short-circuit edge cases (dead tiles,
    all-live tiles, padding blocks, one giant location)."""
    b = 64
    day_v, p_loc, sus_pp, inf_pp = _extreme_case(kind, b=b)
    rs = np.random.default_rng(_EXTREME_SEEDS[kind])
    _, src_v = _with_sources(sus_pp, inf_pp, day_v, rs)
    args, _ = layout_args(
        day_v, day_v.num_real, p_loc, sus_pp, inf_pp, b, 21, 4
    )
    ref_out = None
    for backend in ALL_BACKENDS:
        out = iops.interactions_auto_traced(
            *args, block_size=b, backend=backend, src_val=src_v
        )
        if ref_out is None:
            ref_out = out
        else:
            for a, r in zip(out, ref_out):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
