"""The central correctness test: all interaction backends vs the dense
oracle vs the literal serial event-queue DES (Algorithm 1)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import contact as contact_lib
from repro.core import population as pop_lib
from repro.kernels.interactions import ops as iops
from repro.kernels.interactions import ref as iref

from des_oracle import serial_des_day


def make_case(seed, Vn=220, L=30, P=90, b=64):
    rs = np.random.default_rng(seed)
    person = rs.integers(0, P, Vn)
    loc = rs.integers(0, L, Vn)
    start = rs.uniform(0, 80000, Vn).astype(np.float32)
    end = (start + rs.uniform(600, 20000, Vn)).astype(np.float32)
    day_v = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    occ = contact_lib.max_occupancy_fast(L, loc, start, end)
    p_loc = np.asarray(contact_lib.MinMaxAlpha().probability(occ), np.float32)
    sus_pp = rs.uniform(0.0, 1.0, P).astype(np.float32)
    sus_pp[rs.random(P) < 0.3] = 0.0
    inf_pp = np.zeros(P, np.float32)
    inf_pp[rs.choice(P, 14, replace=False)] = rs.uniform(0.5, 1.0, 14)
    return day_v, p_loc, sus_pp, inf_pp, (person, loc, start, end)


def backend_args(day_v, p_loc, sus_pp, inf_pp, b, seed, day):
    L = len(p_loc)
    sched = pop_lib.build_block_schedule(day_v.loc, day_v.num_real, b)
    safe = np.maximum(day_v.person, 0)
    args = (
        jnp.asarray(day_v.person), jnp.asarray(day_v.loc),
        jnp.asarray(day_v.start), jnp.asarray(day_v.end),
        jnp.asarray(p_loc[np.minimum(day_v.loc, L - 1)]),
        jnp.asarray(sus_pp[safe] * day_v.active),
        jnp.asarray(inf_pp[safe] * day_v.active),
        jnp.asarray(sched.row_block), jnp.asarray(sched.col_block),
        jnp.asarray(sched.row_start.astype(np.int32)),
        jnp.asarray(sched.pair_active.astype(np.int32)),
        iops.col_has_infectious(
            jnp.asarray(inf_pp[safe] * day_v.active),
            jnp.asarray(day_v.person), sched.num_blocks, b,
        ),
        jnp.asarray([seed, day], jnp.uint32),
    )
    return args, sched


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("backend", ["jnp", "scan", "pallas"])
def test_backends_match_dense(seed, backend):
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(seed, b=b)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 123, 5)
    acc_d, cnt_d = iref.interactions_dense(*args[:7], 123, 5)
    acc, cnt = iops.interactions_auto(*args, block_size=b, backend=backend)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_d), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_d))


@pytest.mark.parametrize("seed", [3, 4])
def test_matches_serial_event_queue_des(seed):
    """Tensorized pairwise-overlap == literal Algorithm 1, bitwise on the
    contact set and propensities (f32 sum tolerance)."""
    b = 64
    day_v, p_loc, sus_pp, inf_pp, raw = make_case(seed, b=b)
    person, loc, start, end = raw
    P = len(sus_pp)
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 9, 2)
    acc, cnt = iops.interactions_auto(*args, block_size=b, backend="jnp")
    # fold per-visit accumulations to people
    safe = np.maximum(day_v.person, 0)
    A_fast = np.zeros(P)
    np.add.at(A_fast, safe, np.asarray(acc) * day_v.active)
    A_serial, contacts_serial = serial_des_day(
        person, loc, start, end, p_loc, sus_pp, inf_pp, 9, 2
    )
    np.testing.assert_allclose(A_fast, A_serial, rtol=2e-4, atol=1e-4)
    assert int(np.asarray(cnt).sum()) == contacts_serial


def test_block_schedule_covers_all_same_loc_pairs():
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(7, b=32)
    sched = pop_lib.build_block_schedule(day_v.loc, day_v.num_real, 32)
    covered = set(zip(sched.row_block[sched.pair_active].tolist(),
                      sched.col_block[sched.pair_active].tolist()))
    n = day_v.num_real
    for i in range(n):
        for j in range(n):
            if day_v.loc[i] == day_v.loc[j]:
                assert (i // 32, j // 32) in covered


def test_short_circuit_zero_infectious():
    b = 64
    day_v, p_loc, sus_pp, inf_pp, _ = make_case(8, b=b)
    inf_pp[:] = 0.0
    args, _ = backend_args(day_v, p_loc, sus_pp, inf_pp, b, 1, 0)
    for backend in ("jnp", "scan", "pallas"):
        acc, cnt = iops.interactions_auto(*args, block_size=b, backend=backend)
        assert float(np.abs(np.asarray(acc)).sum()) == 0.0
        assert int(np.asarray(cnt).sum()) == 0
