"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; cached decode == teacher-forced forward."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import layers as L
from repro.models import model as M
from repro.models import transformer as T


def small(cfg_name):
    return dataclasses.replace(
        reduced_config(ARCHS[cfg_name]), compute_dtype="float32"
    )


def make_batch(r, B=2, S=32, key=0):
    toks = jax.random.randint(jax.random.key(key), (B, S), 0, r.vocab_size)
    if r.family == "audio":
        return {
            "frames": jax.random.normal(
                jax.random.key(key + 1), (B, r.enc_frames, r.d_model)
            ) * 0.1,
            "tokens": toks,
        }
    if r.family == "vlm":
        return {
            "patch_embeds": jax.random.normal(
                jax.random.key(key + 1), (B, r.num_patches, r.d_model)
            ) * 0.1,
            "tokens": toks[:, : S - r.num_patches],
        }
    return {"tokens": toks}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    r = small(name)
    params = M.init_params(r, jax.random.key(0), max_target_positions=64)
    batch = make_batch(r)
    loss, metrics = jax.jit(lambda p, b: M.forward_train(r, p, None, b))(
        params, batch
    )
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0
    # gradients flow
    g = jax.grad(lambda p: M.forward_train(r, p, None, batch)[0])(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_shapes_and_finite(name):
    r = small(name)
    params = M.init_params(r, jax.random.key(0), max_target_positions=64)
    B = 2
    cache = M.init_cache(r, B, 48)
    logits, cache2 = M.decode_step(
        r, params, None, cache, jnp.ones((B, 1), jnp.int32),
        jnp.asarray(0, jnp.int32),
    )
    assert logits.shape == (B, 1, r.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize(
    "name", ["smollm-360m", "qwen3-14b", "qwen2-1.5b", "mixtral-8x7b",
             "recurrentgemma-9b", "mamba2-130m"]
)
def test_decode_matches_forward(name):
    r = small(name)
    params = M.init_params(r, jax.random.key(1), max_target_positions=64)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, r.vocab_size)
    x = params["embed"][toks]
    h, _, _ = T.stack_forward(r, params, None, x)
    h = L.rms_norm(h, params["final_norm"], r.norm_eps)
    table = params["embed"] if r.tie_embeddings else params["unembed"]
    full = jnp.einsum("bsd,vd->bsv", h, table)
    cache = M.init_cache(r, B, 64)
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(
            r, params, None, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32)
        )
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    tol = 0.1 if r.family == "moe" else 1e-2  # moe: capacity differs by T
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=tol)


def test_swa_ring_cache_matches_full_window():
    """Rolling cache decode == full forward for a sliding-window arch once
    the window has wrapped."""
    r = dataclasses.replace(small("mixtral-8x7b"), attn_window=16)
    params = M.init_params(r, jax.random.key(1))
    B, S = 1, 40
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, r.vocab_size)
    x = params["embed"][toks]
    h, _, _ = T.stack_forward(r, params, None, x)
    h = L.rms_norm(h, params["final_norm"], r.norm_eps)
    table = params["embed"] if r.tie_embeddings else params["unembed"]
    full = jnp.einsum("bsd,vd->bsv", h, table)
    cache = M.init_cache(r, B, r.attn_window)  # ring buffer of window size
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(
            r, params, None, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32)
        )
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=0.1)


def test_param_counts_full_configs():
    """Full configs hit their nominal sizes (sanity on the zoo wiring)."""
    expected = {
        "smollm-360m": (0.30e9, 0.45e9),
        "granite-3-2b": (2.0e9, 2.9e9),
        "qwen3-14b": (13e9, 16e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "mixtral-8x7b": (44e9, 50e9),
        # the assignment's literal dims (48L x 64e x d_ff 1408) give 28B;
        # the "16B" marketing count corresponds to the source model's
        # different layer count — we implement the assigned dims exactly
        "moonshot-v1-16b-a3b": (26e9, 30e9),
        "recurrentgemma-9b": (8e9, 11e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "mamba2-130m": (0.1e9, 0.17e9),
        "whisper-base": (0.05e9, 0.11e9),
    }
    for name, (lo, hi) in expected.items():
        n = M.param_count(ARCHS[name])
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_less_than_total():
    cfg = ARCHS["mixtral-8x7b"]
    assert M.param_count(cfg, active_only=True) < M.param_count(cfg) / 2
