"""Literal serial implementation of the paper's Algorithm 1 (event-queue
DES) — the ground-truth oracle that the tensorized interaction pass must
match exactly (same contact pairs, same propensities, same draws)."""

from __future__ import annotations

import numpy as np

from repro.core import rng


def serial_des_day(
    person, loc, start, end,  # 1-D numpy arrays (real visits only)
    contact_prob,  # (L,)
    sus_val, inf_val,  # (P,) per-person values
    seed, day,
):
    """Returns (A (P,) accumulated propensity before tau, contacts int).

    Implements: per location, order arrival/departure events by time
    (departures first at ties — a visit ending as another starts does not
    overlap); on departure of visit i, pair it with every visit j still in
    the visitor list; contact with prob p_loc (symmetric hash draw);
    propensity T * sus_i * inf_j accumulates to person_i (and the mirrored
    term to person_j).
    """
    P = len(sus_val)
    A = np.zeros((P,), np.float64)
    contacts = 0
    for l in np.unique(loc):
        vis = np.flatnonzero(loc == l)
        events = []  # (time, is_arrival, visit_index)
        for v in vis:
            events.append((start[v], 1, v))
            events.append((end[v], 0, v))
        # departures before arrivals at equal times
        events.sort(key=lambda e: (e[0], e[1]))
        present: list[int] = []
        for t, is_arrival, v in events:
            if is_arrival:
                present.append(v)
                continue
            present.remove(v)
            for w in present:
                pi, pj = person[v], person[w]
                if pi == pj:
                    continue
                T = min(end[v], end[w]) - max(start[v], start[w])
                if T <= 0:
                    continue
                u = rng.np_uniform(
                    seed, int(rng.CONTACT), day,
                    min(pi, pj), max(pi, pj), l,
                )
                if u >= contact_prob[l]:
                    continue
                # directed contributions (i susceptible side, j infectious)
                A[pi] += T * sus_val[pi] * inf_val[pj]
                A[pj] += T * sus_val[pj] * inf_val[pi]
                if sus_val[pi] > 0 and inf_val[pj] > 0:
                    contacts += 1
                if sus_val[pj] > 0 and inf_val[pi] > 0:
                    contacts += 1
    return A, contacts
