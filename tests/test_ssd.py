"""Mamba2 SSD: chunked scan vs naive sequential recurrence, decode
consistency, chunk-size invariance."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ssd


def naive_recurrence(x, dt, A, B, C):
    """Direct h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t."""
    b, S, H, P = x.shape
    G, N = B.shape[-2], B.shape[-1]
    rep = H // G
    Br = np.repeat(np.asarray(B), rep, axis=2)
    Cr = np.repeat(np.asarray(C), rep, axis=2)
    h = np.zeros((b, H, P, N))
    ys = []
    xn, dtn, An = map(np.asarray, (x, dt, A))
    for t in range(S):
        da = np.exp(dtn[:, t] * An)  # (b, H)
        upd = np.einsum("bh,bhp,bhn->bhpn", dtn[:, t], xn[:, t], Br[:, t])
        h = h * da[..., None, None] + upd
        ys.append(np.einsum("bhpn,bhn->bhp", h, Cr[:, t]))
    return np.stack(ys, 1), h


def rand_case(key, b=2, S=64, H=4, P=8, G=2, N=16):
    ks = jax.random.split(jax.random.key(key), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, G, N)) * 0.3
    C = jax.random.normal(ks[4], (b, S, G, N)) * 0.3
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_scan_matches_naive(chunk):
    x, dt, A, B, C = rand_case(0)
    y, h = ssd.ssd_scan_ref(x, dt, A, B, C, chunk)
    y_ref, h_ref = naive_recurrence(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_chunk_invariance():
    x, dt, A, B, C = rand_case(1)
    y1, h1 = ssd.ssd_scan_ref(x, dt, A, B, C, 8)
    y2, h2 = ssd.ssd_scan_ref(x, dt, A, B, C, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


def test_non_divisible_padding():
    x, dt, A, B, C = rand_case(2, S=50)
    y, h = ssd.ssd_scan_ref(x, dt, A, B, C, 16)
    y_ref, h_ref = naive_recurrence(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_decode_step_matches_scan():
    x, dt, A, B, C = rand_case(3, S=12)
    y_scan, h_final = ssd.ssd_scan_ref(x, dt, A, B, C, 4)
    state = jnp.zeros((2, 4, 8, 16), jnp.float32)
    ys = []
    for t in range(12):
        y, state = ssd.ssd_decode_step(
            x[:, t], dt[:, t], A, B[:, t], C[:, t], state
        )
        ys.append(y)
    y_dec = jnp.stack(ys, 1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_scan), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(state), np.asarray(h_final), rtol=1e-4, atol=1e-4
    )


def test_initial_state_carried():
    x, dt, A, B, C = rand_case(4, S=32)
    y_full, h_full = ssd.ssd_scan_ref(x, dt, A, B, C, 8)
    y_a, h_a = ssd.ssd_scan_ref(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], 8)
    y_b, h_b = ssd.ssd_scan_ref(
        x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:], 8, initial_state=h_a
    )
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_full),
        rtol=1e-4, atol=1e-4,
    )


def test_causal_conv_decode_matches_full():
    key = jax.random.key(5)
    x = jax.random.normal(key, (2, 10, 6))
    w = jax.random.normal(jax.random.key(6), (4, 6))
    b = jax.random.normal(jax.random.key(7), (6,))
    full = ssd.causal_conv1d(x, w, b)
    state = jnp.zeros((2, 3, 6), jnp.float32)
    outs = []
    for t in range(10):
        y, state = ssd.conv_decode_step(x[:, t], state, w, b)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), rtol=1e-5, atol=1e-5
    )
