import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import attention as A


def cfg_with(M_, G, Dh):
    return dataclasses.replace(
        reduced_config(ARCHS["granite-3-2b"]), compute_dtype="float32",
        num_heads=M_ * G, num_kv_heads=M_, head_dim=Dh,
    )


@pytest.mark.parametrize("window", [None, 24, 64])
@pytest.mark.parametrize("chunk", [16, 64])
def test_chunked_matches_naive(window, chunk):
    cfg = cfg_with(2, 2, 16)
    B, S, M_, G, Dh = 2, 128, 2, 2, 16
    key = jax.random.key(0)
    q = jax.random.normal(key, (B, S, M_, G, Dh))
    k = jax.random.normal(jax.random.key(1), (B, S, M_, Dh))
    v = jax.random.normal(jax.random.key(2), (B, S, M_, Dh))
    mask = A.causal_window_mask(S, 0, S, window)[None, None, None]
    ref = A.attend(q, k, v, mask, cfg)
    out = A.attend_chunked(q, k, v, cfg, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_ring_cache_slot_validity():
    """decode_attention with a ring cache smaller than the history must
    attend to exactly the last T positions."""
    T = 8
    for pos in (3, 7, 8, 20):
        i = np.arange(T)
        slot_pos = pos - ((pos - i) % T)
        valid = np.asarray(slot_pos >= 0)
        # number of valid slots = min(pos+1, T)
        assert valid.sum() == min(pos + 1, T)
        # each valid slot holds a distinct position in (pos-T, pos]
        sp = np.asarray(slot_pos)[valid]
        assert len(np.unique(sp)) == valid.sum()
        assert (sp <= pos).all() and (sp > pos - T).all()


def test_gqa_grouping_consistent_with_repeat():
    """Grouped attention == attention with explicitly repeated KV heads."""
    cfg = cfg_with(2, 3, 16)
    B, S = 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, 2, 3, 16))
    k = jax.random.normal(jax.random.key(1), (B, S, 2, 16))
    v = jax.random.normal(jax.random.key(2), (B, S, 2, 16))
    mask = A.causal_window_mask(S, 0, S, None)[None, None, None]
    out = A.attend(q, k, v, mask, cfg)
    # repeated formulation
    kr = jnp.repeat(k, 3, axis=2)
    vr = jnp.repeat(v, 3, axis=2)
    cfg_r = dataclasses.replace(cfg, num_heads=6, num_kv_heads=6)
    qr = q.reshape(B, S, 6, 1, 16)
    out_r = A.attend(qr, kr, vr, mask, cfg_r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=1e-5, atol=1e-6)


def test_rope_relative_property():
    """RoPE'd dot products depend only on relative positions."""
    from repro.models.layers import rope

    Dh = 32
    q = jax.random.normal(jax.random.key(0), (1, 1, Dh))
    k = jax.random.normal(jax.random.key(1), (1, 1, Dh))
    def dot_at(pq, pk):
        qr = rope(q, jnp.array([[pq]]))
        kr = rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qr * kr))
    a = dot_at(5, 3)
    b = dot_at(105, 103)
    assert abs(a - b) < 1e-3
