"""Resilient chunked runs: checkpoint integrity, invariant guards, the
deterministic chaos harness, and elastic degradation.

The end-to-end matrix is the PR's acceptance bar: under every chaos
schedule a resilient run must complete **bitwise-equal** to the same run
without faults, with the recovery actions recorded in
``RunResult.provenance["resilience"]``.
"""

import dataclasses
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import api
from repro.api.spec import ResilienceSpec
from repro.checkpoint import (
    CheckpointCorruptionError,
    CheckpointManager,
    leaf_digest,
)
from repro.core import disease, transmission
from repro.data import digital_twin_population
from repro.engine.core import EngineCore
from repro.runtime import (
    ChaosError,
    ChaosEvent,
    ChaosSchedule,
    GuardContext,
    InvariantViolation,
)
from repro.runtime.elastic import plan_elastic_rescale, repartition_person_array
from repro.runtime.guards import check_state


# ---------------------------------------------------------------------------
# checkpoint integrity (satellite: digests, validation, async errors)
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.int32),
            "b": jnp.linspace(0.0, 1.0, 400,
                              dtype=jnp.float32).reshape(20, 20)}


def _leaf_path(mgr, step, key):
    return os.path.join(mgr.directory, f"step-{step:010d}",
                        key.replace("/", "__") + ".npy")


def test_manifest_carries_leaf_digests(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(), blocking=True)
    leaves = mgr.manifest(3)["leaves"]
    assert set(leaves) == {"a", "b"}
    assert leaves["a"]["shape"] == [12] and leaves["a"]["dtype"] == "int32"
    assert leaves["b"]["sha256"] == leaf_digest(np.load(_leaf_path(mgr, 3, "b")))


def test_corrupt_leaf_detected_and_named(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    path = _leaf_path(mgr, 1, "b")
    with open(path, "r+b") as f:  # flip trailing payload bytes
        f.seek(os.path.getsize(path) - 8)
        chunk = f.read(4)
        f.seek(os.path.getsize(path) - 8)
        f.write(bytes(b ^ 0xFF for b in chunk))
    assert any("'b'" in p for p in mgr.verify(1))
    with pytest.raises(CheckpointCorruptionError, match="'b'"):
        mgr.restore_flat(1)


def test_truncated_leaf_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    path = _leaf_path(mgr, 1, "b")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(CheckpointCorruptionError, match="'b'"):
        mgr.restore_flat(1)


def test_missing_leaf_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    os.remove(_leaf_path(mgr, 1, "a"))
    with pytest.raises(CheckpointCorruptionError, match="'a' is missing"):
        mgr.restore_flat(1)


def test_shape_dtype_validated_against_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    np.save(_leaf_path(mgr, 1, "a"), np.zeros((3, 3), np.int32))
    with pytest.raises(CheckpointCorruptionError, match="'a' has shape"):
        mgr.restore_flat(1)
    np.save(_leaf_path(mgr, 1, "a"), np.zeros(12, np.float64))
    with pytest.raises(CheckpointCorruptionError, match="'a' has dtype"):
        mgr.restore_flat(1)


def test_restore_template_leaf_not_in_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.arange(4, dtype=jnp.int32)}, blocking=True)
    like = {"a": jax.ShapeDtypeStruct((4,), jnp.int32),
            "ghost": jax.ShapeDtypeStruct((2,), jnp.float32)}
    with pytest.raises(CheckpointCorruptionError, match="'ghost'"):
        mgr.restore(like, 1)


def test_latest_valid_step_quarantines_and_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    mgr.save(2, _tree(), blocking=True)
    with open(_leaf_path(mgr, 2, "b"), "r+b") as f:
        f.truncate(10)
    assert mgr.latest_valid_step() == 1
    assert mgr.quarantined_steps == [2]
    assert mgr.all_steps() == [1]  # the corrupt snapshot was moved aside
    assert os.path.isdir(os.path.join(str(tmp_path), "quarantine",
                                      f"step-{2:010d}"))


def test_legacy_manifest_without_digests_restores(tmp_path):
    import json
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    mpath = os.path.join(mgr.directory, f"step-{1:010d}", "manifest.json")
    with open(mpath) as f:
        meta = json.load(f)
    for entry in meta["leaves"].values():  # pre-integrity checkpoint format
        del entry["sha256"]
    with open(mpath, "w") as f:
        json.dump(meta, f)
    out = mgr.restore_flat(1)
    np.testing.assert_array_equal(out["a"], np.arange(12))


def test_async_writer_exception_surfaces(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    os.rmdir(mgr.directory)
    with open(mgr.directory, "w") as f:  # writer's makedirs will fail
        f.write("not a directory")
    mgr.save(1, {"x": jnp.zeros(3, jnp.float32)})  # non-blocking: the
    # error lands in the writer thread
    with pytest.raises(RuntimeError, match="background checkpoint write"):
        mgr.wait()
    mgr.wait()  # surfaced once, then cleared


def test_readers_join_inflight_writer(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree())  # async
    assert mgr.latest_step() == 5  # wait()s internally, never races
    assert mgr.latest_valid_step() == 5


# ---------------------------------------------------------------------------
# invariant guards
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_core():
    pop = digital_twin_population(300, seed=7, name="grd")
    return EngineCore.single(
        pop, disease.covid_model(),
        transmission.TransmissionModel(tau=2e-5), seed=3)


def test_guards_pass_on_healthy_state(small_core):
    st = small_core.init_state1()
    n = int(small_core.params.sus_table.shape[-1])
    assert check_state(st, num_states=n) == []


def test_guards_catch_bad_health_and_nan(small_core):
    st = small_core.init_state1()
    n = int(small_core.params.sus_table.shape[-1])
    bad = dataclasses.replace(st, health=st.health.at[0].set(n + 3))
    assert any("health" in v for v in check_state(bad, num_states=n))
    nanned = dataclasses.replace(st, dwell=st.dwell.at[1].set(jnp.nan))
    assert any("dwell" in v and "non-finite" in v
               for v in check_state(nanned, num_states=n))


def test_guard_context_monotonicity(small_core):
    st = small_core.init_state1()
    n = int(small_core.params.sus_table.shape[-1])
    g = GuardContext(num_states=n)
    g.check(st)  # establishes the baseline
    shrunk = dataclasses.replace(
        st, isolated_until=st.isolated_until - 5)
    with pytest.raises(InvariantViolation, match="isolated_until"):
        g.check(shrunk)
    g.reset(st)  # rebase (restore semantics): same state is fine again
    g.check(st)


# ---------------------------------------------------------------------------
# elastic rescaling (satellite: runtime/elastic.py coverage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("old_w,new_w", [(3, 4), (4, 3), (5, 1), (1, 5)])
def test_plan_elastic_rescale_uneven(old_w, new_w):
    P = 10
    old, new, plan = plan_elastic_rescale(P, old_w, new_w)
    assert old == {"workers": old_w, "per_worker": -(-P // old_w)}
    assert new == {"workers": new_w, "per_worker": -(-P // new_w)}
    assert new["workers"] * new["per_worker"] >= P
    assert plan == [(slice(0, P), slice(0, P))]


@pytest.mark.parametrize("new_w", [1, 2, 3, 7])
def test_repartition_preserves_people_and_fills_pads(new_w):
    P = 11
    arr = np.arange(12).reshape(2, 6)  # 2 workers, 1 pad slot
    out = repartition_person_array(arr, P, new_w, fill=-1)
    pw = -(-P // new_w)
    assert out.shape == (new_w, pw)
    np.testing.assert_array_equal(out.reshape(-1)[:P], np.arange(P))
    assert np.all(out.reshape(-1)[P:] == -1)


def test_repartition_roundtrip_bitwise():
    P = 23
    orig = np.random.default_rng(0).integers(0, 100, size=(1, P))
    shrunk = repartition_person_array(orig, P, 5)
    regrown = repartition_person_array(shrunk, P, 1)
    np.testing.assert_array_equal(regrown.reshape(-1)[:P],
                                  orig.reshape(-1)[:P])


# ---------------------------------------------------------------------------
# chaos harness determinism
# ---------------------------------------------------------------------------

def test_chaos_schedule_random_deterministic():
    a = ChaosSchedule.random(seed=42, days=60, every=10)
    b = ChaosSchedule.random(seed=42, days=60, every=10)
    assert a.events == b.events
    assert all(ev.day % 10 == 0 and 0 < ev.day < 60 for ev in a.events)


def test_chaos_events_fire_once():
    sched = ChaosSchedule((ChaosEvent("raise", day=5),))
    with pytest.raises(ChaosError):
        sched.before_chunk(5)
    sched.before_chunk(5)  # one-shot: the replayed boundary is quiet
    assert sched.log == [("raise", 5)]


def test_chaos_event_validates_kind():
    with pytest.raises(ValueError, match="chaos kind"):
        ChaosEvent("meteor", day=1)


# ---------------------------------------------------------------------------
# end-to-end: the recovery matrix (acceptance bar)
# ---------------------------------------------------------------------------

DAYS, EVERY = 12, 3
OBSERVABLES = ("daily_new_infections", "attack_rate", "peak_day")


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(400, seed=11, name="res")


def _spec(**kw):
    base = dict(dataset="twin-2k", days=DAYS, tau=2e-5,
                interventions=("none",), replicates=2,
                observables=OBSERVABLES)
    base.update(kw)
    return api.ExperimentSpec(**base)


@pytest.fixture(scope="module")
def reference(pop):
    """The fault-free run every recovered run must match bitwise."""
    return api.run(_spec(), population=pop)


def _assert_bitwise(ref, res):
    assert set(ref.history) == set(res.history)
    for k in ref.history:
        np.testing.assert_array_equal(ref.history[k], res.history[k],
                                      err_msg=f"history[{k}] diverged")
    for k in ref.observables:
        r, s = ref.observables[k], res.observables[k]
        if isinstance(r, dict):
            for kk in r:
                np.testing.assert_array_equal(r[kk], s[kk])
        else:
            np.testing.assert_array_equal(r, s)


@pytest.mark.parametrize("kind", ["raise", "nan", "corrupt", "truncate"])
def test_chaos_recovery_bitwise(pop, reference, tmp_path, kind):
    spec = _spec().with_overrides(ckpt_dir=str(tmp_path), ckpt_every=EVERY,
                                  resilient=True)
    chaos = ChaosSchedule((ChaosEvent(kind, day=6),))
    res = api.run(spec, population=pop, chaos=chaos)
    _assert_bitwise(reference, res)

    rep = res.provenance["resilience"]
    assert rep["restarts"] == 1
    assert rep["faults"], "recovery actions must be recorded"
    if kind in ("corrupt", "truncate"):
        assert rep["snapshots_quarantined"] >= 1
        assert os.path.isdir(os.path.join(str(tmp_path), "quarantine"))
        assert res.provenance["resumed_from_day"] == 3  # fell back past day 6
    if kind == "nan":
        assert any("non-finite" in v for v in rep["guard_violations"])
        # the poisoned state must never have reached disk
        mgr = CheckpointManager(str(tmp_path))
        for step in mgr.all_steps():
            flat = mgr.restore_flat(step)
            for k, v in flat.items():
                if np.issubdtype(v.dtype, np.floating):
                    assert np.all(np.isfinite(v)), f"step {step} leaf {k}"


def test_chaos_recovery_all_engines(pop, reference, tmp_path):
    """The recovery loop is layout-independent: a pinned single/dist
    (sequential, observables replayed) engine recovers bitwise too."""
    spec = _spec(engine="single").with_overrides(
        ckpt_dir=str(tmp_path), ckpt_every=EVERY, resilient=True)
    res = api.run(spec, population=pop,
                  chaos=ChaosSchedule((ChaosEvent("raise", day=6),)))
    _assert_bitwise(reference, res)
    assert res.provenance["resilience"]["restarts"] == 1


def test_straggler_detection_and_repartition(pop, reference, tmp_path):
    spec = _spec().with_overrides(ckpt_dir=str(tmp_path), ckpt_every=2)
    spec = dataclasses.replace(spec, resilience=ResilienceSpec(
        enabled=True, repartition_on_straggler=True, straggler_factor=3.0))
    calls = []
    res = api.run(spec, population=pop,
                  chaos=ChaosSchedule((ChaosEvent("slow", day=8, sleep_s=0.6),)),
                  on_straggler=lambda day, dt, med: calls.append((day, dt, med)))
    _assert_bitwise(reference, res)
    rep = res.provenance["resilience"]
    assert rep["straggler_events"] and calls
    assert rep["straggler_events"][0]["day"] == 10  # the slowed chunk's end
    assert rep["repartitions"] == 1  # rebuilt once, then the window resets
    assert rep["restarts"] == 0  # a repartition is not a failure


def test_restart_cap_exhausted(pop, tmp_path):
    spec = _spec().with_overrides(ckpt_dir=str(tmp_path), ckpt_every=EVERY,
                                  resilient=True, max_restarts=0)
    with pytest.raises(ChaosError):
        api.run(spec, population=pop,
                chaos=ChaosSchedule((ChaosEvent("raise", day=6),)))


def test_resilient_requires_checkpoint_dir(pop):
    with pytest.raises(ValueError, match="checkpoint"):
        _spec(resilience=ResilienceSpec(enabled=True)).validate()
    with pytest.raises(ValueError, match="resilient"):
        api.run(_spec(), population=pop,
                chaos=ChaosSchedule((ChaosEvent("raise", day=6),)))


def test_resume_falls_back_past_corrupt_newest(pop, reference, tmp_path):
    """Offline corruption of the newest snapshot: a plain (non-resilient)
    resume quarantines it and restarts from the next-older valid step."""
    spec6 = _spec(days=6).with_overrides(ckpt_dir=str(tmp_path),
                                         ckpt_every=EVERY)
    api.run(spec6, population=pop)  # leaves steps 3 and 6 on disk
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.all_steps() == [3, 6]
    # damage the biggest leaf of step 6
    d = os.path.join(str(tmp_path), f"step-{6:010d}")
    names = [f for f in os.listdir(d) if f.endswith(".npy")]
    path = os.path.join(d, max(names, key=lambda f: os.path.getsize(
        os.path.join(d, f))))
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)

    res = api.run(_spec().with_overrides(ckpt_dir=str(tmp_path),
                                         ckpt_every=EVERY), population=pop)
    assert res.provenance["resumed_from_day"] == 3
    assert os.path.isdir(os.path.join(str(tmp_path), "quarantine",
                                      f"step-{6:010d}"))
    _assert_bitwise(reference, res)


# ---------------------------------------------------------------------------
# elastic degradation (device loss) — needs >= 2 devices; the CI
# chaos-matrix job runs this file with 4 emulated host devices.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
@pytest.mark.parametrize("engine,replicates", [("dist", 1), ("hybrid", 2)])
def test_device_loss_elastic_shrink(pop, tmp_path, engine, replicates):
    spec = _spec(engine=engine, replicates=replicates,
                 mesh=api.MeshSpec(workers=2))
    ref = api.run(spec, population=pop)
    res = api.run(
        spec.with_overrides(ckpt_dir=str(tmp_path), ckpt_every=EVERY,
                            resilient=True),
        population=pop,
        chaos=ChaosSchedule((ChaosEvent("device_loss", day=6,
                                        workers_lost=1),)))
    _assert_bitwise(ref, res)
    rep = res.provenance["resilience"]
    assert rep["device_losses"] == [{"workers_before": 2, "workers_after": 1}]
    assert rep["final_workers"] == 1
    assert rep["final_layout"] == ("workers" if engine == "dist" else "hybrid")


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_engine_adopt_state_repads_person_axis(pop):
    """EngineCore.adopt_state re-partitions person-axis leaves from a
    2-worker padded layout onto 1 worker, preserving every real person."""
    spec2 = _spec(engine="dist", replicates=1, mesh=api.MeshSpec(workers=2))
    from repro.api.runner import _make_core
    core2 = _make_core("dist", spec2.validate(), pop, spec2.build_batch())
    st2 = core2.init_state()
    spec1 = dataclasses.replace(spec2, mesh=api.MeshSpec(workers=1))
    core1 = _make_core("dist", spec1.validate(), pop, spec1.build_batch())
    adopted = core1.adopt_state(st2)
    tmpl = core1.init_state()
    assert adopted.health.shape == tmpl.health.shape
    P = pop.num_people
    np.testing.assert_array_equal(
        np.asarray(adopted.health).reshape(-1)[:P],
        np.asarray(st2.health).reshape(-1)[:P])


# ---------------------------------------------------------------------------
# spec / CLI plumbing
# ---------------------------------------------------------------------------

def test_resilience_spec_roundtrip_and_cli_flags(tmp_path):
    spec = _spec().with_overrides(ckpt_dir=str(tmp_path), resilient=True,
                                  max_restarts=7)
    assert spec.resilience.enabled and spec.resilience.max_restarts == 7
    back = api.ExperimentSpec.from_dict(spec.to_dict())
    assert back.resilience == spec.resilience

    import argparse
    from repro.launch import cli
    ap = cli.add_common_args(argparse.ArgumentParser())
    args = ap.parse_args(["--resilient", "--max-restarts", "2",
                          "--ckpt-dir", str(tmp_path)])
    built = cli.build_spec(args, dict(dataset="twin-2k", days=5))
    assert built.resilience.enabled and built.resilience.max_restarts == 2
    args2 = ap.parse_args(["--no-resilient"])
    built2 = cli.build_spec(args2, dict(dataset="twin-2k", days=5))
    assert built2.resilience.enabled is False
