import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import compat
from repro.core import exchange as ex


def build_small_plan(W=1, P=12, V=20, seed=0):
    rs = np.random.default_rng(seed)
    visit_person = rs.integers(0, P, (W, V)).astype(np.int32)
    visit_person[:, -3:] = -1  # padding
    owner = (np.arange(P) * W // P).astype(np.int32)
    local = np.zeros(P, np.int32)
    for w in range(W):
        idx = np.flatnonzero(owner == w)
        local[idx] = np.arange(len(idx))
    return ex.build_exchange_plan(visit_person, owner, local), visit_person, owner, local


def test_plan_routes_every_visit():
    plan, vp, owner, local = build_small_plan()
    routed = (plan.send_idx >= 0).sum()
    assert routed == (vp >= 0).sum()
    assert (plan.recv_slot >= 0).sum() == (vp >= 0).sum()


def test_dispatch_combine_single_worker_roundtrip():
    plan, vp, owner, local = build_small_plan()
    P, V = 12, 20
    mesh = Mesh(np.array(jax.devices()[:1]), ("workers",))
    vals = jnp.asarray(np.random.default_rng(1).normal(size=(P, 2)).astype(np.float32))

    def f(send, recv, vals):
        vv = ex.dispatch(send, recv, vals, V, "workers")
        back = ex.combine(send, recv, vv, P, "workers")
        return vv, back

    send = jnp.asarray(plan.send_idx[0])
    recv = jnp.asarray(plan.recv_slot[0])
    vv, back = jax.jit(
        compat.shard_map(
            f, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec(),
                      jax.sharding.PartitionSpec()),
            out_specs=jax.sharding.PartitionSpec(),
        )
    )(send, recv, vals)
    # dispatch: each visit slot got its person's values
    vv = np.asarray(vv)
    for v in range(V):
        pid = vp[0, v]
        if pid >= 0:
            np.testing.assert_allclose(vv[v], np.asarray(vals)[pid], rtol=1e-6)
        else:
            np.testing.assert_allclose(vv[v], 0.0)
    # combine is the adjoint: back[p] = sum over p's visits of visit values
    back = np.asarray(back)
    expect = np.zeros_like(back)
    for v in range(V):
        pid = vp[0, v]
        if pid >= 0:
            expect[pid] += vv[v]
    np.testing.assert_allclose(back, expect, rtol=1e-6)
