"""Hypothesis property tests on system invariants.

Skipped cleanly when hypothesis isn't installed (it is an optional dev
dependency — CI installs it via ``pip install -e .[dev]``)."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import contact as contact_lib
from repro.core import population as pop_lib
from repro.core import rng
from repro.kernels.interactions import ops as iops
from repro.kernels.interactions import ref as iref


@given(
    seed=st.integers(0, 2**31 - 1),
    day=st.integers(0, 10000),
    n=st.integers(1, 300),
)
@settings(max_examples=30, deadline=None)
def test_uniform_in_open_unit_interval(seed, day, n):
    u = np.asarray(rng.uniform(seed, rng.CONTACT, day, jnp.arange(n, dtype=jnp.uint32)))
    assert (u > 0).all() and (u < 1).all()


@given(occ=st.lists(st.integers(1, 10**6), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_contact_probability_valid(occ):
    p = np.asarray(contact_lib.MinMaxAlpha().probability(np.asarray(occ)))
    assert (p > 0).all() and (p <= 1).all()


@given(
    seed=st.integers(0, 100),
    vn=st.integers(10, 150),
    nloc=st.integers(2, 25),
    npeople=st.integers(5, 60),
)
@settings(max_examples=15, deadline=None)
def test_interaction_pass_invariants(seed, vn, nloc, npeople):
    """For random visit configurations: (a) propensities non-negative;
    (b) people with zero susceptibility accumulate nothing; (c) result is
    invariant to visit-order permutation (partition invariance at the
    math level); (d) dense oracle == blocked backend."""
    rs = np.random.default_rng(seed)
    b = 32
    person = rs.integers(0, npeople, vn)
    loc = rs.integers(0, nloc, vn)
    start = rs.uniform(0, 5000, vn).astype(np.float32)
    end = (start + rs.uniform(1, 4000, vn)).astype(np.float32)
    sus = rs.uniform(0, 1, npeople).astype(np.float32)
    sus[rs.random(npeople) < 0.4] = 0.0
    inf = np.where(rs.random(npeople) < 0.3, rs.uniform(0.1, 1, npeople), 0.0).astype(np.float32)
    p_loc = rs.uniform(0.05, 1.0, nloc).astype(np.float32)

    def run(perm):
        dv = pop_lib.pack_day(person[perm], loc[perm], start[perm], end[perm],
                              pad_multiple=b)
        sched = pop_lib.build_block_schedule(dv.loc, dv.num_real, b)
        safe = np.maximum(dv.person, 0)
        args = (
            jnp.asarray(dv.person), jnp.asarray(dv.loc),
            jnp.asarray(dv.start), jnp.asarray(dv.end),
            jnp.asarray(p_loc[np.minimum(dv.loc, nloc - 1)]),
            jnp.asarray(sus[safe] * dv.active),
            jnp.asarray(inf[safe] * dv.active),
            jnp.asarray(sched.row_block), jnp.asarray(sched.col_block),
            jnp.asarray(sched.row_start.astype(np.int32)),
            jnp.asarray(sched.pair_active.astype(np.int32)),
            iops.col_has_infectious(
                jnp.asarray(inf[safe] * dv.active), jnp.asarray(dv.person),
                sched.num_blocks, b),
            iops.row_has_susceptible(
                jnp.asarray(sus[safe] * dv.active), jnp.asarray(dv.person),
                sched.num_blocks, b),
            jnp.asarray([7, 3], jnp.uint32),
        )
        acc, cnt = iops.interactions_auto(*args, block_size=b, backend="jnp")
        A = np.zeros(npeople)
        np.add.at(A, safe, np.asarray(acc) * dv.active)
        acc_d, _ = iref.interactions_dense(*args[:7], 7, 3)
        A_d = np.zeros(npeople)
        np.add.at(A_d, safe, np.asarray(acc_d) * dv.active)
        return A, A_d, int(np.asarray(cnt).sum())

    A1, A1d, c1 = run(np.arange(vn))
    A2, _, c2 = run(rs.permutation(vn))
    assert (A1 >= 0).all()
    assert (A1[sus == 0] == 0).all()
    np.testing.assert_allclose(A1, A1d, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(A1, A2, rtol=1e-4, atol=1e-5)
    assert c1 == c2


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_block_schedule_complete_and_minimal(data):
    """Every same-location index pair is covered by exactly one active
    block pair; blocks without same-location pairs are absent."""
    n = data.draw(st.integers(1, 120))
    b = 16
    loc = np.sort(data.draw(st.lists(st.integers(0, 8), min_size=n, max_size=n)))
    loc = np.asarray(loc, np.int32)
    V = int(np.ceil(n / b) * b)
    padded = np.concatenate([loc, np.full(V - n, loc[-1] if n else 0, np.int32)])
    sched = pop_lib.build_block_schedule(padded, n, b)
    active = set(zip(sched.row_block[sched.pair_active].tolist(),
                     sched.col_block[sched.pair_active].tolist()))
    need = set()
    for i in range(n):
        for j in range(n):
            if loc[i] == loc[j]:
                need.add((i // b, j // b))
    assert need <= active
    # no duplicate pairs among active ones
    assert len(active) == int(sched.pair_active.sum())


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_max_occupancy_fast_matches_event_loop_oracle(data):
    """``max_occupancy_fast`` (the production O(E log E) sweep) must match
    the O(E) event-loop oracle ``max_occupancy_from_visits`` on schedules
    dense with *tied* start/end times — the tie-breaking rule (departures
    before arrivals at equal times) is where the two could diverge."""
    n = data.draw(st.integers(0, 60))
    L = data.draw(st.integers(1, 6))
    # Integer time grid forces heavy start/end ties, including end == start
    # of another visit (touching visits must not count as overlap) and
    # zero-length visits.
    loc = np.asarray(data.draw(
        st.lists(st.integers(0, L - 1), min_size=n, max_size=n)), np.int64)
    start = np.asarray(data.draw(
        st.lists(st.integers(0, 8), min_size=n, max_size=n)), np.float32)
    dur = np.asarray(data.draw(
        st.lists(st.integers(0, 6), min_size=n, max_size=n)), np.float32)
    end = start + dur
    slow = contact_lib.max_occupancy_from_visits(L, loc, start, end)
    fast = contact_lib.max_occupancy_fast(L, loc, start, end)
    np.testing.assert_array_equal(slow, fast)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_occupancy_packing_preserves_visits_and_shrinks_schedule(data):
    """Packing is a permutation of the real visits (no loss, no dupes),
    keeps each location's run contiguous, and never grows the block-pair
    schedule."""
    n = data.draw(st.integers(1, 200))
    b = 16
    loc = np.sort(np.asarray(data.draw(
        st.lists(st.integers(0, 9), min_size=n, max_size=n)), np.int64))
    rs = np.random.default_rng(0)
    person = rs.integers(0, 50, n)
    start = rs.uniform(0, 100, n).astype(np.float32)
    end = (start + 1.0).astype(np.float32)
    day = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    packed = pop_lib.pack_day_occupancy(day, b)
    real = packed.person >= 0
    assert int(real.sum()) == n
    # permutation: multiset of (person, loc, start) identical
    a = sorted(zip(day.person[: n].tolist(), day.loc[: n].tolist(),
                   day.start[: n].tolist()))
    c = sorted(zip(packed.person[real].tolist(), packed.loc[real].tolist(),
                   packed.start[real].tolist()))
    assert a == c
    before = pop_lib.build_block_schedule(day.loc, day.num_real, b).num_pairs
    after = pop_lib.build_block_schedule(packed.loc, packed.extent, b).num_pairs
    assert after <= before


@given(
    mean=st.floats(0.5, 20.0),
    seed=st.integers(0, 1000),
)
@settings(max_examples=20, deadline=None)
def test_exponential_positive_prop(mean, seed):
    e = np.asarray(rng.exponential(mean, seed, rng.DWELL, 0,
                                   jnp.arange(100, dtype=jnp.uint32)))
    assert (e > 0).all()


# ---------------------------------------------------------------------------
# Per-agent interventions (PR 7): the capacity-limited test budget and the
# isolation-window state machine.
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 2**31 - 1),
    day=st.integers(0, 1000),
    npeople=st.integers(1, 400),
    budget=st.integers(0, 500),
    p_sym=st.floats(0.0, 1.0),
    p_elig=st.floats(0.0, 1.0),
)
@settings(max_examples=40, deadline=None)
def test_budget_take_is_exact(seed, day, npeople, budget, p_sym, p_elig):
    """The lexicographic (score, gpid) threshold selection used by
    engine/day.py takes exactly min(budget, #eligible) people, never more
    (ties cannot over-select: gpid is unique), takes only eligible people,
    and fills symptomatic demand before traced-only demand."""
    from repro.engine.topology import LocalTopology

    rs = np.random.default_rng(seed % 2**32)
    elig = rs.random(npeople) < p_elig
    sym = rs.random(npeople) < p_sym
    gpid = jnp.arange(npeople, dtype=jnp.uint32)
    u = rng.uniform(np.uint32(seed), rng.TEST, day, 0, gpid)
    score = jnp.where(
        jnp.asarray(elig) & jnp.asarray(sym), u,
        jnp.where(jnp.asarray(elig), u + 2.0, 4.0),
    )
    T, G = LocalTopology().rank_threshold(
        score, gpid, jnp.asarray(budget, jnp.int32), npeople, topk=1
    )
    take = np.asarray(
        jnp.asarray(elig) & (budget > 0)
        & ((score < T) | ((score == T) & (gpid <= G)))
    )
    assert take.sum() == min(budget, int(elig.sum()))
    assert not take[~elig].any()
    # symptomatic priority: a traced-only person is taken only if every
    # eligible symptomatic person is
    if take[elig & ~sym].any():
        assert take[elig & sym].sum() == (elig & sym).sum()


@given(
    seed=st.integers(0, 10**6),
    days=st.integers(1, 60),
    n_events=st.integers(0, 80),
)
@settings(max_examples=40, deadline=None)
def test_isolation_window_monotone_until_expiry(seed, days, n_events):
    """The isolated_until update rule — iso = max(iso, day + 1 + dur) on
    positive/traced events, untouched otherwise — yields per-person
    windows that are monotone non-decreasing, always start the day after
    the triggering event, and expire exactly (in_iso == day < iso)."""
    rs = np.random.default_rng(seed)
    P = 12
    MAX_DUR = 20
    iso = np.zeros(P, np.int64)
    ev_day = np.sort(rs.integers(0, days, n_events))
    ev_pid = rs.integers(0, P, n_events)
    ev_dur = rs.integers(0, MAX_DUR + 1, n_events)
    prev = iso.copy()
    k = 0
    for day in range(days):
        while k < len(ev_day) and ev_day[k] == day:
            p, d = ev_pid[k], ev_dur[k]
            iso[p] = max(iso[p], day + 1 + d)
            k += 1
        assert (iso >= prev).all()  # monotone non-decreasing
        assert (iso <= day + 1 + MAX_DUR).all()  # bounded by max window
        # result latency: an event today starts isolation tomorrow, so an
        # extended window always reaches at least day + 1
        newly = iso > prev
        assert (iso[newly] >= day + 1).all()
        prev = iso.copy()
    # expiry is exact: at day == iso the window is over (in_iso == day < iso)
    horizon = int(iso.max())
    assert not (horizon < iso).any()


@given(seed=st.integers(0, 3))
@settings(max_examples=4, deadline=None)
def test_engine_budget_and_isolation_invariants(seed):
    """Engine-level: a real TTI run never exceeds the daily budget, keeps
    per-person isolated_until monotone across days, and never un-tests a
    person."""
    from repro.core import disease as disease_lib
    from repro.core import interventions as iv_lib
    from repro.data import digital_twin_population
    from repro.engine.core import EngineCore

    budget = 12
    pop = digital_twin_population(400, seed=1, name=f"prop{seed}")
    core = EngineCore.single(
        pop, disease_lib.covid_model(),
        interventions=[iv_lib.TestTraceIsolate(
            "tti", tests_per_day=budget, isolation_days=5,
            trace_isolation_days=7,
        )],
        seed=seed, seed_per_day=4,
    )
    state = core.init_state()
    prev_iso = np.asarray(state.isolated_until[0])
    prev_tested = np.asarray(state.tested[0])
    for _ in range(20):
        state, _, hist, _ = core.run_days(1, state=state)
        assert hist["tests_used"].max() <= budget
        iso = np.asarray(state.isolated_until[0])
        tested = np.asarray(state.tested[0])
        assert (iso >= prev_iso).all()
        assert (tested >= prev_tested).all()
        prev_iso, prev_tested = iso, tested
