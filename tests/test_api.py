"""The unified front door (repro.api): spec round-trips, engine-dispatch
parity (one ExperimentSpec -> bitwise-equal trajectories through every
engine), on-device observables vs a host-side numpy reference, and
chunk-boundary checkpoint/resume bitwise equality."""

import dataclasses
import os

import numpy as np
import pytest

from repro import api
from repro.analysis.report import summarize_result
from repro.api import observables as obs_lib
from repro.configs import get_epidemic
from repro.core import simulator

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")


@pytest.fixture(scope="module")
def pop():
    return get_epidemic("twin-2k").build()


def _spec(**kw):
    base = dict(dataset="twin-2k", days=8, tau=2e-5,
                interventions=("none", "school-closure"), replicates=1)
    base.update(kw)
    return api.ExperimentSpec(**base).validate()


# ---------------------------------------------------------------------------
# spec serialization round-trips
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip():
    spec = _spec(
        tau_scales=(1.0, 0.8), replicates=2, backend="compact",
        mesh=api.MeshSpec(workers=2, scenarios=2),
        checkpoint=api.CheckpointSpec(directory="/tmp/x", every=25),
        observables=("attack_rate",),
    )
    again = api.ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert api.ExperimentSpec.from_dict(spec.to_dict()) == spec
    # nested dataclasses survive the dict form
    assert again.mesh.workers == 2
    assert again.checkpoint.every == 25
    assert again.num_scenarios == 2 * 2 * 2


def test_spec_rejects_unknowns():
    with pytest.raises(ValueError, match="unknown ExperimentSpec field"):
        api.ExperimentSpec.from_dict({"dataset": "twin-2k", "dayz": 3})
    with pytest.raises(ValueError, match="intervention preset"):
        _spec(interventions=("no-such-preset",))
    with pytest.raises(ValueError, match="dataset"):
        _spec(dataset="no-such-dataset")
    with pytest.raises(ValueError, match="observable"):
        _spec(observables=("no-such-observable",))
    with pytest.raises(ValueError, match="engine"):
        _spec(engine="no-such-engine")


def test_spec_toml_golden():
    """The checked-in examples/experiment.toml is the TOML golden file."""
    spec = api.ExperimentSpec.from_file(
        os.path.join(EXAMPLES, "experiment.toml"))
    assert spec.dataset == "twin-2k"
    assert spec.interventions == ("none", "school-closure", "tti")
    assert spec.tau_scales == (1.0, 0.8)
    assert spec.replicates == 2
    assert spec.num_scenarios == 12
    assert spec.mesh == api.MeshSpec(workers=1, scenarios=1)
    assert spec.checkpoint.every == 10
    # TOML -> spec -> JSON -> spec is exact
    assert api.ExperimentSpec.from_json(spec.to_json()) == spec


def test_spec_cli_overrides():
    spec = _spec()
    over = spec.with_overrides(days=None, workers=2, ckpt_dir="/tmp/y",
                               backend="compact")
    assert over.days == spec.days  # None = flag not given
    assert over.mesh.workers == 2 and over.mesh.scenarios == 1
    assert over.checkpoint.directory == "/tmp/y"
    assert over.backend == "compact"


# ---------------------------------------------------------------------------
# engine-dispatch parity: one spec, every engine, bitwise-equal trajectories
# ---------------------------------------------------------------------------


def test_engine_dispatch_parity(pop):
    """The acceptance bar: the same ExperimentSpec dispatched through all
    engines yields bitwise-equal per-scenario trajectories and observables
    (1-device worker/scenario meshes, so it runs everywhere)."""
    spec = _spec()
    ref = api.run(spec, population=pop)
    assert ref.provenance["engine"] == "ensemble"  # B=2, 1x1 mesh
    assert ref.history["cumulative"].shape == (spec.days, 2)

    for engine in ("single", "dist", "sharded", "hybrid"):
        r = api.run(spec.with_overrides(engine=engine), population=pop)
        assert r.provenance["engine"] == engine
        for k in simulator.STAT_KEYS:
            np.testing.assert_array_equal(
                ref.history[k], r.history[k], err_msg=f"{engine}/{k}")
        # finalized observables agree bitwise too (same pure reductions)
        for name, vals in ref.observables.items():
            for leaf_a, leaf_b in zip(_leaves(vals),
                                      _leaves(r.observables[name])):
                np.testing.assert_array_equal(
                    leaf_a, leaf_b, err_msg=f"{engine}/{name}")
        assert r.scenario_names == ref.scenario_names

    # ...and the facade matches a hand-rolled single-scenario core run.
    from repro.engine.core import EngineCore
    batch = spec.build_batch()
    for i, s in enumerate(batch):
        sim = EngineCore.single(
            pop, s.disease, s.tm, interventions=s.interventions,
            seed=s.seed, iv_enabled=s.iv_enabled,
        )
        _, h = sim.run1(spec.days)
        np.testing.assert_array_equal(h["cumulative"],
                                      ref.history["cumulative"][:, i])


def _leaves(tree):
    import jax

    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def test_auto_engine_single_for_one_scenario(pop):
    r = api.run(_spec(interventions=("none",), days=4), population=pop)
    assert r.provenance["engine"] == "single"
    assert r.history["cumulative"].shape == (4, 1)  # B axis kept at B=1


# ---------------------------------------------------------------------------
# observables: on-device (in-scan and post-scan) vs host-side numpy
# ---------------------------------------------------------------------------


def test_observables_match_numpy_reference(pop):
    spec = _spec(replicates=3, interventions=("none",))  # B=3 MC band
    r = api.run(spec, population=pop)
    assert r.provenance["observables_in_scan"] is True
    hist = r.history
    B = r.num_scenarios

    # attack rate & cumulative
    np.testing.assert_array_equal(
        r.observables["attack_rate"]["cumulative"], hist["cumulative"][-1])
    np.testing.assert_allclose(
        r.observables["attack_rate"]["attack_rate"],
        hist["cumulative"][-1].astype(np.float32) / pop.num_people,
        rtol=1e-6)

    # peak-day argmax (first-peak semantics == np.argmax)
    np.testing.assert_array_equal(
        r.observables["peak_day"]["peak_day"],
        np.argmax(hist["infectious"], axis=0))
    np.testing.assert_array_equal(
        r.observables["peak_day"]["peak_infectious"],
        hist["infectious"].max(axis=0))

    # daily incidence series is the history column
    np.testing.assert_array_equal(
        r.observables["daily_new_infections"]["daily"],
        hist["new_infections"])

    # cross-scenario mean/CI band vs numpy
    x = hist["new_infections"].astype(np.float32)
    m = x.mean(axis=1)
    sem = x.std(axis=1, ddof=1) / np.sqrt(B)
    band = r.observables["ensemble_mean_ci"]["new_infections"]
    np.testing.assert_allclose(band["mean"], m, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(band["lo"], m - 1.96 * sem, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(band["hi"], m + 1.96 * sem, rtol=1e-5, atol=1e-4)

    # the post-scan on-device driver is bitwise-identical to in-scan
    obs = obs_lib.make_observables(spec.observables)
    ctx = obs_lib.ObsContext(num_people=pop.num_people, num_scenarios=B)
    replay = obs_lib.observables_to_numpy(
        obs_lib.observe_history(obs, hist, ctx))
    for name in r.observables:
        for a, b in zip(_leaves(r.observables[name]), _leaves(replay[name])):
            np.testing.assert_array_equal(a, b, err_msg=name)


def test_sobol_first_order_matches_numpy(pop):
    """The streaming Sobol observable vs a host-side numpy reference on a
    2x2x2 factorial sweep, on an in-scan and a post-scan engine."""
    spec = _spec(
        interventions=("none", "school-closure"), tau_scales=(1.0, 0.7),
        replicates=2, days=10,
        observables=("attack_rate", "sobol_first_order"),
    )
    r = api.run(spec, population=pop)
    assert r.provenance["observables_in_scan"] is True
    y = r.history["cumulative"][-1].astype(np.float32)
    B = y.shape[0]
    assert B == 8
    mu, var = y.mean(), y.var()

    # factorial order: interventions x tau x replicates, replicates inner
    idx = np.arange(B)
    levels = {
        "interventions": idx // 4,
        "tau_scales": (idx // 2) % 2,
        "replicates": idx % 2,
    }
    got = r.observables["sobol_first_order"]
    np.testing.assert_allclose(got["variance"], var, rtol=1e-5)
    for axis, g in levels.items():
        gmeans = np.array([y[g == l].mean() for l in range(2)])
        cnts = np.array([(g == l).sum() for l in range(2)], np.float32)
        s1_ref = float((cnts * (gmeans - mu) ** 2).sum() / B / var)
        np.testing.assert_allclose(got["S1"][axis], s1_ref, rtol=1e-4,
                                   err_msg=axis)
    # sensible magnitudes: tau and intervention axes explain more variance
    # than Monte Carlo replicates on this config
    assert 0.0 <= got["S1"]["replicates"] <= 1.0 + 1e-6

    # a post-scan engine (pinned single, B>1) reproduces the same indices
    r2 = api.run(spec.with_overrides(engine="single"), population=pop)
    for axis in levels:
        np.testing.assert_array_equal(got["S1"][axis],
                                      r2.observables["sobol_first_order"]["S1"][axis])


# ---------------------------------------------------------------------------
# chunk-boundary checkpoint/resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine",
                         ["single", "ensemble", "dist", "sharded", "hybrid"])
def test_checkpoint_resume_bitwise(pop, tmp_path, engine):
    """A run interrupted at a chunk boundary and resumed is bitwise-equal
    to the uninterrupted run — state, history, and observable reductions —
    on every layout (1-device worker/scenario meshes, so it runs
    everywhere; the chunk loop lives in the engine core now)."""
    days = 12
    spec = _spec(days=days, engine=engine)
    ref = api.run(spec, population=pop)

    ck = spec.with_overrides(ckpt_dir=str(tmp_path / engine), ckpt_every=5)
    # "interrupt" after 5 days: a prefix run that checkpoints day 5
    api.run(dataclasses.replace(ck, days=5).validate(), population=pop)
    resumed = api.run(ck, population=pop)

    assert resumed.provenance["resumed_from_day"] == 5
    for k in simulator.STAT_KEYS:
        np.testing.assert_array_equal(ref.history[k], resumed.history[k],
                                      err_msg=k)
    for name in ref.observables:
        for a, b in zip(_leaves(ref.observables[name]),
                        _leaves(resumed.observables[name])):
            np.testing.assert_array_equal(a, b, err_msg=name)
    # a second resume from the final checkpoint is a no-op run
    again = api.run(ck, population=pop)
    assert again.provenance["resumed_from_day"] == days
    np.testing.assert_array_equal(ref.history["cumulative"],
                                  again.history["cumulative"])


def test_resume_rejects_prerefactor_checkpoint(pop, tmp_path):
    """A checkpoint written by the pre-refactor per-engine loops (whose
    resume keys carry no engine-core generation marker) must be refused by
    the resume-key guard, not spliced into a unified-core trajectory."""
    import json

    ck = _spec(days=6).with_overrides(ckpt_dir=str(tmp_path / "old"),
                                      ckpt_every=3)
    api.run(ck, population=pop)
    # Rewrite the manifest to the pre-refactor key format (no "core").
    step_dir = sorted((tmp_path / "old").glob("step-*"))[-1]
    manifest = json.loads((step_dir / "manifest.json").read_text())
    assert manifest["extra"]["resume_key"].pop("core") is not None
    (step_dir / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="incompatible spec or engine"):
        api.run(dataclasses.replace(ck, days=9).validate(), population=pop)


def test_resume_rejects_incompatible_spec(pop, tmp_path):
    """A checkpoint written under one parameterization must not be spliced
    into a run with another (same shapes, different science)."""
    ck = _spec(days=6).with_overrides(ckpt_dir=str(tmp_path / "ck"),
                                      ckpt_every=3)
    api.run(ck, population=pop)
    with pytest.raises(ValueError, match="incompatible spec"):
        api.run(dataclasses.replace(ck, tau=1e-5).validate(), population=pop)
    # ...but extending days (the resume use case) is allowed
    longer = dataclasses.replace(ck, days=9).validate()
    r = api.run(longer, population=pop)
    assert r.provenance["resumed_from_day"] == 6


def test_run_file_with_overrides(tmp_path):
    """The golden TOML runs end-to-end through run_file, flags-style
    overrides applying on top (the --spec CLI path in library form)."""
    r = api.run_file(os.path.join(EXAMPLES, "experiment.toml"),
                     days=3, replicates=1, tau_scales=(1.0,))
    assert r.spec.days == 3
    assert r.num_scenarios == 3  # replicates/tau_scales overridden
    assert r.history["cumulative"].shape == (3, 3)


# ---------------------------------------------------------------------------
# RunResult round-trip + report consumption
# ---------------------------------------------------------------------------


def test_run_result_json_roundtrip(pop, tmp_path):
    r = api.run(_spec(days=5), population=pop)
    path = str(tmp_path / "result.json")
    r.save(path)
    back = api.RunResult.load(path)
    assert back.spec == r.spec
    assert back.scenario_names == r.scenario_names
    np.testing.assert_array_equal(back.history["cumulative"],
                                  r.history["cumulative"])
    # report rows from observables == rows computed from history
    assert summarize_result(back) == r.summaries
    # legacy fallback path: strip the observables, rows still come out
    back.observables = {}
    assert summarize_result(back) == r.summaries
