import numpy as np

from repro.core import contact


def test_eq1_limits():
    cm = contact.MinMaxAlpha(5, 40, 1000)
    p = np.asarray(cm.probability(np.array([1, 2, 3, 6, 100, 1000, 100000])))
    assert (p <= 1.0).all() and (p > 0).all()
    assert p[0] == 1.0 and p[1] == 1.0  # N <= 2: everyone meets
    # At peak occupancy N, expected contacts = p*(N-1) in [A, B]
    for N in (50, 500, 5000, 100000):
        pN = float(cm.probability(np.array([N]))[0])
        exp_contacts = pN * (N - 1)
        assert 4.9 <= exp_contacts <= 40.1, (N, exp_contacts)


def test_eq1_monotone_contacts():
    cm = contact.MinMaxAlpha()
    Ns = np.array([10, 100, 1000, 10000])
    expected = np.asarray(cm.probability(Ns)) * (Ns - 1)
    assert (np.diff(expected) > 0).all()  # contacts grow with size, A->B


def test_max_occupancy_sweep_vs_fast():
    rs = np.random.default_rng(0)
    for trial in range(5):
        L, V = 20, 300
        loc = rs.integers(0, L, V)
        start = rs.uniform(0, 1000, V).astype(np.float32)
        end = (start + rs.uniform(1, 500, V)).astype(np.float32)
        slow = contact.max_occupancy_from_visits(L, loc, start, end)
        fast = contact.max_occupancy_fast(L, loc, start, end)
        np.testing.assert_array_equal(slow, fast)


def test_touching_visits_do_not_overlap():
    # visit ends exactly when another starts: occupancy stays 1
    loc = np.array([0, 0])
    start = np.array([0.0, 10.0], np.float32)
    end = np.array([10.0, 20.0], np.float32)
    occ = contact.max_occupancy_fast(1, loc, start, end)
    assert occ[0] == 1


def test_fixed_probability():
    fp = contact.FixedProbability(0.3)
    p = np.asarray(fp.probability(np.array([1, 10, 100])))
    np.testing.assert_allclose(p, 0.3)
