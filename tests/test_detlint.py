"""detlint suite: golden-bad corpus, pragmas, baselines, JSON/CLI
contract, the Level-2 jaxpr helpers, the repo-wide clean gate, and the
x64 day-step guard (zero f64 ops on every interaction backend).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import (
    assert_no_f64,
    collective_count,
    find_f64,
    recompile_sentinel,
)
from repro.analysis.lint import (
    LintConfig,
    apply_baseline,
    load_baseline,
    render_json,
    rule_catalog,
    run_lint,
    write_baseline,
)

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
CORPUS = os.path.join(HERE, "lint_corpus")
RULES = tuple(sorted(rule_catalog()))

#: det002's bad snippet cross-checks against a declared registry.
TEST_STREAMS = {"CONTACT": 0x01, "DWELL": 0x04}


def lint_paths(paths, **kw):
    kw.setdefault("excludes", ("__pycache__",))  # un-exclude lint_corpus
    findings, errors = run_lint(paths, LintConfig(**kw))
    assert not errors, errors
    return findings


def lint_corpus(name, **kw):
    return lint_paths([os.path.join(CORPUS, name)], **kw)


# ---------------------------------------------------------------------------
# golden-bad corpus: each bad snippet trips exactly its own rule
# ---------------------------------------------------------------------------


def test_rule_catalog_is_the_det_family():
    assert RULES == ("DET001", "DET002", "DET003", "DET004", "DET005",
                     "DET006")


@pytest.mark.parametrize("rule", RULES)
def test_bad_snippet_triggers_exactly_its_rule(rule):
    findings = lint_corpus(f"{rule.lower()}_bad.py", streams=TEST_STREAMS)
    assert findings, f"{rule} bad snippet produced no findings"
    assert {f.rule for f in findings} == {rule}, findings


@pytest.mark.parametrize("rule", RULES)
def test_good_snippet_is_clean(rule):
    findings = lint_corpus(f"{rule.lower()}_good.py", streams=TEST_STREAMS)
    assert findings == [], findings


def test_det002_registry_modes():
    # Without a registry the literal/missing-arg findings still fire, but
    # the undeclared-constant check (needs the declared set) stays quiet.
    bare = lint_corpus("det002_bad.py")
    assert len(bare) == 2
    # With the registry, rng.UNREGISTERED is flagged too.
    full = lint_corpus("det002_bad.py", streams=TEST_STREAMS)
    assert len(full) == 3
    assert any("UNREGISTERED" in f.message for f in full)


def test_det002_flags_duplicate_ids_in_registry(tmp_path):
    d = tmp_path / "core"
    d.mkdir()
    (d / "rng.py").write_text(textwrap.dedent("""\
        import numpy as np
        CONTACT = np.uint32(1)
        INFECT = np.uint32(1)
        _PRIVATE = np.uint32(1)
    """))
    findings = lint_paths([str(tmp_path)])
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "DET002"
    assert "CONTACT" in f.message and "INFECT" in f.message
    assert "_PRIVATE" not in f.message  # underscore names are not streams


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------


def _lint_source(tmp_path, source, name="snippet.py", **kw):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint_paths([str(p)], **kw)


def test_pragma_same_line(tmp_path):
    assert _lint_source(tmp_path, """\
        import random  # detlint: ignore[DET001] — test fixture
    """) == []


def test_pragma_comment_line_above(tmp_path):
    assert _lint_source(tmp_path, """\
        # detlint: ignore[DET001] — host-side helper
        import random
    """) == []


def test_pragma_multi_comment_justification(tmp_path):
    # The pragma may be followed by more comment lines before the code.
    assert _lint_source(tmp_path, """\
        # detlint: ignore[DET001] — host-side builder: deterministic
        # via the explicit seed; draws no simulation randomness.
        import random
    """) == []


def test_pragma_wildcard_and_wrong_rule(tmp_path):
    assert _lint_source(tmp_path, """\
        import random  # detlint: ignore[*]
    """) == []
    findings = _lint_source(tmp_path, """\
        import random  # detlint: ignore[DET003]
    """)
    assert [f.rule for f in findings] == ["DET001"]


def test_pragma_skip_file(tmp_path):
    assert _lint_source(tmp_path, """\
        # detlint: skip-file — generated fixture
        import random
        import jax.numpy as jnp
        x = jnp.zeros(4)
    """) == []


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def test_baseline_round_trip(tmp_path):
    findings = lint_corpus("det001_bad.py")
    bl_path = tmp_path / "baseline.json"
    write_baseline(str(bl_path), findings)
    baseline = load_baseline(str(bl_path))
    new, suppressed = apply_baseline(findings, baseline)
    assert new == [] and len(suppressed) == len(findings)


def test_baseline_keys_are_line_number_free(tmp_path):
    findings = lint_corpus("det001_bad.py")
    bl_path = tmp_path / "baseline.json"
    write_baseline(str(bl_path), findings)
    data = json.loads(bl_path.read_text())
    assert data["version"] == 1
    for key in data["suppress"]:
        rule, path, _ = key.split("::", 2)
        assert rule in RULES and path.endswith("det001_bad.py")


def test_baseline_catches_new_findings(tmp_path):
    f1 = lint_corpus("det001_bad.py")
    bl_path = tmp_path / "baseline.json"
    write_baseline(str(bl_path), f1[:1])  # baseline only the first finding
    new, suppressed = apply_baseline(f1, load_baseline(str(bl_path)))
    assert len(suppressed) == 1 and len(new) == len(f1) - 1


def test_baseline_missing_file_is_empty():
    assert load_baseline(None) == {}
    assert load_baseline("/nonexistent/baseline.json") == {}


def test_baseline_rejects_foreign_json(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"not": "a baseline"}')
    with pytest.raises(ValueError):
        load_baseline(str(p))


def test_committed_baseline_is_empty():
    baseline = load_baseline(os.path.join(REPO, ".detlint-baseline.json"))
    assert sum(baseline.values()) == 0


# ---------------------------------------------------------------------------
# JSON report + CLI contract
# ---------------------------------------------------------------------------


def test_json_report_schema():
    findings = lint_corpus("det003_bad.py")
    report = render_json(findings, [], [])
    assert set(report) == {"version", "tool", "findings", "suppressed",
                           "errors", "counts", "exit_code"}
    assert report["tool"] == "detlint" and report["version"] == 1
    assert report["exit_code"] == 1
    assert report["counts"] == {"DET003": len(findings)}
    for f in report["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message"}
    assert render_json([], [], [])["exit_code"] == 0


def _run_cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, cwd=cwd, env=env,
    )


def test_cli_exit_codes(tmp_path):
    bad = os.path.join(CORPUS, "det006_bad.py")
    good = os.path.join(CORPUS, "det006_good.py")
    assert _run_cli(bad).returncode == 1
    assert _run_cli(good).returncode == 0
    assert _run_cli().returncode == 2  # no paths
    assert _run_cli("--rules", "DET999", good).returncode == 2


def test_cli_list_rules():
    res = _run_cli("--list-rules")
    assert res.returncode == 0
    for rule in RULES:
        assert rule in res.stdout


def test_cli_json_and_baseline_workflow(tmp_path):
    bad = os.path.join(CORPUS, "det004_bad.py")
    report_path = tmp_path / "report.json"
    res = _run_cli(bad, "--json", str(report_path))
    assert res.returncode == 1
    report = json.loads(report_path.read_text())
    assert report["counts"] == {"DET004": 2}

    bl = tmp_path / "baseline.json"
    assert _run_cli(bad, "--write-baseline", str(bl)).returncode == 0
    assert _run_cli(bad, "--baseline", str(bl)).returncode == 0


# ---------------------------------------------------------------------------
# the repo itself is clean (satellite: empty committed baseline)
# ---------------------------------------------------------------------------


def test_repo_src_is_detlint_clean():
    findings, errors = run_lint([os.path.join(REPO, "src")], LintConfig())
    assert not errors, errors
    assert findings == [], "\n".join(
        f"{f.path}:{f.line}: {f.rule} {f.message}" for f in findings)


# ---------------------------------------------------------------------------
# per-directory relax profiles (the tests/ posture)
# ---------------------------------------------------------------------------

#: The committed posture for tests/: DET001 off (tests draw raw numpy
#: randomness to build fixtures — that is host-side setup, not simulation
#: state), every other rule at full strength. CI passes exactly this via
#: ``--relax tests/:DET001``.
TESTS_RELAX = (("tests/", ("DET001",)),)


def test_relax_drops_rule_under_prefix_only(tmp_path):
    for sub in ("tests", "src"):
        d = tmp_path / sub
        d.mkdir()
        (d / "mod.py").write_text("import random\n")  # DET001 bait
    relax = ((f"{tmp_path}/tests/", ("DET001",)),)
    findings = lint_paths([str(tmp_path)], relax=relax)
    assert [f.rule for f in findings] == ["DET001"]
    assert findings[0].path.endswith("src/mod.py")


def test_relax_is_per_rule_not_blanket(tmp_path):
    d = tmp_path / "tests"
    d.mkdir()
    (d / "mod.py").write_text(
        "import random\n"
        "import jax.numpy as jnp\n"
        "x = jnp.zeros(4)\n"  # DET003 must survive the DET001 relax
    )
    findings = lint_paths([str(tmp_path)],
                          relax=((f"{tmp_path}/tests/", ("DET001",)),))
    assert [f.rule for f in findings] == ["DET003"]
    # a wildcard relax silences the whole prefix
    assert lint_paths([str(tmp_path)],
                      relax=((f"{tmp_path}/tests/", ("*",)),)) == []


def test_repo_tests_are_detlint_clean_under_relax():
    """tests/ holds the same determinism bar as src/ apart from the
    declared DET001 carve-out — the posture CI enforces."""
    prefix, codes = TESTS_RELAX[0]
    findings, errors = run_lint(
        [os.path.join(REPO, "tests")],
        LintConfig(relax=((os.path.join(REPO, prefix), codes),),
                   excludes=("__pycache__", "lint_corpus")))
    assert not errors, errors
    assert findings == [], "\n".join(
        f"{f.path}:{f.line}: {f.rule} {f.message}" for f in findings)


def test_cli_relax_flag(tmp_path):
    d = tmp_path / "tests"
    d.mkdir()
    (d / "mod.py").write_text("import random\n")
    target = str(tmp_path)
    assert _run_cli(target).returncode == 1
    assert _run_cli("--relax", f"{target}/:DET001", target).returncode == 0
    # usage errors: malformed spec, unknown rule
    assert _run_cli("--relax", "no-colon", target).returncode == 2
    assert _run_cli("--relax", "tests/:DET999", target).returncode == 2


# ---------------------------------------------------------------------------
# Level 2: jaxpr helpers
# ---------------------------------------------------------------------------


def test_find_f64_clean_on_pinned_fn():
    def f(x):
        def body(c, _):
            return c * jnp.float32(1.5), c.sum()

        return jax.lax.scan(body, x, None, length=3)

    assert find_f64(f, jnp.ones((4,), jnp.float32)) == []
    assert_no_f64(f, jnp.ones((4,), jnp.float32))


def test_find_f64_catches_promotion_leak():
    was = jax.config.read("jax_enable_x64")
    try:
        jax.config.update("jax_enable_x64", True)

        def leaky(x):
            return x * 1.0 + jnp.float64(2.0)

        leaks = find_f64(leaky, jnp.ones((4,), jnp.float32))
        assert leaks and all(d == "float64" for _, _, d in leaks)
        with pytest.raises(AssertionError, match="f64 leak"):
            assert_no_f64(leaky, jnp.ones((4,), jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", was)


def test_find_f64_descends_into_scan_bodies():
    was = jax.config.read("jax_enable_x64")
    try:
        jax.config.update("jax_enable_x64", True)

        def f(x):
            def body(c, _):
                return c + 1.0e-3, None  # f64 literal only inside the body

            return jax.lax.scan(body, x.astype(jnp.float64), None, length=2)

        assert find_f64(f, jnp.ones((4,), jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", was)


def test_collective_count():
    def f(x):
        return jax.lax.psum(x, "i"), jax.lax.pmax(x, "i")

    pm = jax.pmap(f, axis_name="i")
    counts = collective_count(lambda x: pm(x), jnp.ones((1, 4), jnp.float32))
    assert counts.get("psum", 0) >= 1 and counts.get("pmax", 0) >= 1

    def g(x):
        return x * 2

    assert collective_count(g, jnp.ones((4,), jnp.float32)) == {}


def test_recompile_sentinel():
    step = jax.jit(lambda x: x + 1)
    step(jnp.ones(3, jnp.float32))
    with recompile_sentinel(step):
        step(jnp.ones(3, jnp.float32))
        step(jnp.ones(3, jnp.float32))
    with pytest.raises(AssertionError, match="recompile sentinel"):
        with recompile_sentinel(step):
            step(jnp.ones(5, jnp.float32))  # new shape -> recompile
    with recompile_sentinel(step, allow=1):
        step(jnp.ones(7, jnp.float32))


# ---------------------------------------------------------------------------
# x64 guard: the traced day step has zero f64 ops on every backend
# (trivially true when x64 is off; the dedicated JAX_ENABLE_X64=1 CI
# pass is where this bites — the PR 5/6 promotion bug class).
# ---------------------------------------------------------------------------

DAY_STEP_BACKENDS = ("jnp", "scan", "compact", "pallas", "pallas-compact")


@pytest.fixture(scope="module")
def tiny_core_inputs():
    from repro.configs import ScenarioBatch
    from repro.data import digital_twin_population

    pop = digital_twin_population(300, seed=7, name="detlint-x64")
    batch = ScenarioBatch.from_product(tau=2e-5, seeds=[3])
    return pop, batch


@pytest.mark.parametrize("backend", DAY_STEP_BACKENDS)
def test_day_step_has_no_f64_ops(tiny_core_inputs, backend):
    from repro.engine import EngineCore
    from repro.engine import day as day_lib

    pop, batch = tiny_core_inputs
    core = EngineCore(pop, batch, layout="local", backend=backend)
    params = core.scenario_params(0)
    state = jax.tree.map(lambda a: a[0], core.init_state())

    def step(params, state):
        return day_lib.day_step(core.topo, core.static, core.route,
                                core.week, params, state)

    assert_no_f64(step, params, state)
