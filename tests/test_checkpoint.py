import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import disease, simulator, transmission
from repro.data import digital_twin_population
from repro.engine.core import EngineCore, state_from_flat, state_to_tree
from repro.runtime import FaultConfig, FaultTolerantLoop
from repro.runtime.elastic import repartition_person_array


def _payload(state):
    # The flat "state/<field>" checkpoint layout state_from_flat expects.
    return {f"state/{k}": v for k, v in state_to_tree(state).items()}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(10, dtype=jnp.int32),
            "nested": {"b": jnp.ones((3, 4), jnp.float32) * 2.5}}
    mgr.save(7, tree, extra={"note": "x"}, blocking=True)
    assert mgr.all_steps() == [7]
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out = mgr.restore(like)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))
    np.testing.assert_allclose(np.asarray(out["nested"]["b"]), 2.5)
    assert mgr.manifest()["extra"]["note"] == "x"


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.zeros(3, jnp.float32)}, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.arange(5, dtype=jnp.int32)})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_sim_restart_bitwise(tmp_path):
    pop = digital_twin_population(800, seed=4, name="ck")
    tm = transmission.TransmissionModel(tau=2e-5)
    sim = EngineCore.single(pop, disease.covid_model(), tm, seed=9)
    mgr = CheckpointManager(str(tmp_path))
    st, h1 = sim.run1(12)
    mgr.save(12, _payload(st), blocking=True)
    # restart from disk
    payload = _payload(st)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype), payload)
    restored = state_from_flat(mgr.restore(like))
    _, h_res = sim.run1(8, state=restored)
    _, h_full = sim.run1(20)
    np.testing.assert_array_equal(h_full["cumulative"][12:], h_res["cumulative"])


def test_fault_loop_recovers(tmp_path):
    """Injected failures at steps 5 and 11 -> restore+replay, identical
    final state to an uninterrupted run."""
    pop = digital_twin_population(600, seed=5, name="fl")
    tm = transmission.TransmissionModel(tau=2e-5)
    sim = EngineCore.single(pop, disease.covid_model(), tm, seed=2)
    mgr = CheckpointManager(str(tmp_path))
    static, week, contact_prob, params = simulator.legacy_parts(sim)
    day_step = jax.jit(
        lambda st: simulator.day_step(static, week, contact_prob, params, st)
    )

    state0 = sim.init_state1()
    mgr.save(0, _payload(state0), blocking=True)
    holder = {"state": state0}
    failed = set()

    def step_fn(state):
        new_state, _ = day_step(state)
        return new_state

    def save_fn(step, state):
        mgr.save(step, _payload(state), blocking=True)

    def restore_fn():
        step = mgr.latest_step()
        payload = mgr.manifest(step)
        like = _payload(sim.init_state1())
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype), like
        )
        return step, state_from_flat(mgr.restore(like, step))

    def injector(step):
        if step in (5, 11) and step not in failed:
            failed.add(step)
            raise RuntimeError(f"injected node failure at day {step}")

    loop = FaultTolerantLoop(
        step_fn, save_fn, restore_fn,
        FaultConfig(checkpoint_interval=4, max_restarts=5),
        fault_injector=injector,
    )
    final_step, final_state = loop.run(state0, 0, 16)
    assert final_step == 16
    assert loop.stats.restarts == 2

    # uninterrupted reference
    ref, _ = sim.run1(16)
    np.testing.assert_array_equal(
        np.asarray(final_state.health), np.asarray(ref.health)
    )


def test_straggler_detection():
    import time

    calls = []

    def slow_step(state):
        if state == 15:
            time.sleep(0.05)
        else:
            time.sleep(0.001)
        return state + 1

    loop = FaultTolerantLoop(
        slow_step, lambda s, st: None, lambda: (0, 0),
        FaultConfig(checkpoint_interval=1000, straggler_window=10,
                    straggler_factor=3.0),
        on_straggler=lambda step, dt, med: calls.append(step),
    )
    loop.run(0, 0, 30)
    assert loop.stats.straggler_events >= 1
    assert calls


def test_elastic_repartition():
    arr = np.arange(10).reshape(1, 10)  # 1 worker, 10 people
    out = repartition_person_array(arr, 10, 4)
    assert out.shape == (4, 3)
    np.testing.assert_array_equal(out.reshape(-1)[:10], np.arange(10))
