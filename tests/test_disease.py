import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import disease


@pytest.mark.parametrize("model", [disease.covid_model(), disease.sir_model(), disease.seir_model()])
def test_models_valid(model):
    model.validate()
    assert model.susceptibility[model.initial_state] > 0
    assert model.susceptibility[model.entry_state] == 0


def test_seeding_exact_count():
    m = disease.covid_model()
    state, dwell = disease.initial_health(m, 500)
    state, dwell = disease.seed_infections(m, state, dwell, 10, 1, 0)
    assert int((np.asarray(state) == m.entry_state).sum()) == 10


def test_progression_reaches_recovered():
    m = disease.covid_model()
    P = 200
    state, dwell = disease.initial_health(m, P)
    state, dwell = disease.seed_infections(m, state, dwell, 50, 1, 0)
    for day in range(1, 60):
        none = jnp.zeros((P,), bool)
        state, dwell = disease.update_health(m, state, dwell, none, 1, day)
    final = np.bincount(np.asarray(state), minlength=m.num_states)
    R = m.state_index("R")
    assert final[R] == 50  # everyone seeded eventually recovers
    assert final[m.initial_state] == P - 50  # no spontaneous infections


def test_infection_only_from_susceptible():
    m = disease.sir_model()
    P = 10
    state = jnp.full((P,), m.state_index("R"), jnp.int32)
    dwell = jnp.full((P,), disease.ABSORBING_DWELL, jnp.float32)
    all_inf = jnp.ones((P,), bool)
    s2, _ = disease.update_health(m, state, dwell, all_inf, 0, 0)
    assert (np.asarray(s2) == m.state_index("R")).all()


def test_branching_fractions():
    m = disease.covid_model()
    P = 20000
    ipre = m.state_index("Ipre")
    state = jnp.full((P,), ipre, jnp.int32)
    dwell = jnp.full((P,), 0.5, jnp.float32)  # expire today
    s2, _ = disease.update_health(m, state, dwell, jnp.zeros((P,), bool), 3, 11)
    counts = np.bincount(np.asarray(s2), minlength=m.num_states)
    frac_sym = counts[m.state_index("Isym")] / P
    assert abs(frac_sym - 0.65) < 0.02


def test_dwell_minimum_one_day():
    m = disease.covid_model()
    P = 1000
    state, dwell = disease.initial_health(m, P)
    s2, d2 = disease.update_health(
        m, state, dwell, jnp.ones((P,), bool), 0, 0
    )
    d2 = np.asarray(d2)
    assert (d2[np.asarray(s2) == m.entry_state] >= 1.0).all()
