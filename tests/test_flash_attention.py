"""Flash-attention Pallas kernel vs jnp oracle: shapes/dtypes/masks sweep
(interpret mode on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import flash_attention_ref


CASES = [
    # (BH, Sq, Sk, Dh, causal, window, dtype, blk)
    (4, 128, 128, 64, True, None, jnp.float32, 64),
    (2, 128, 128, 128, True, None, jnp.float32, 64),
    (2, 64, 256, 64, True, None, jnp.float32, 64),  # end-aligned queries
    (2, 128, 128, 64, True, 48, jnp.float32, 64),  # sliding window
    (2, 128, 128, 64, False, None, jnp.float32, 64),  # bidirectional
    (2, 128, 128, 64, True, None, jnp.bfloat16, 64),
    (1, 256, 256, 256, True, None, jnp.float32, 128),
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_ref(case):
    BH, Sq, Sk, Dh, causal, window, dt, blk = case
    q = jax.random.normal(jax.random.key(0), (BH, Sq, Dh), dt)
    k = jax.random.normal(jax.random.key(1), (BH, Sk, Dh), dt)
    v = jax.random.normal(jax.random.key(2), (BH, Sk, Dh), dt)
    out = flash_attention_bhsd(
        q, k, v, causal=causal, window=window, blk_q=blk, blk_k=blk
    )
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_block_shape_invariance():
    q = jax.random.normal(jax.random.key(0), (2, 256, 64))
    k = jax.random.normal(jax.random.key(1), (2, 256, 64))
    v = jax.random.normal(jax.random.key(2), (2, 256, 64))
    outs = [
        np.asarray(flash_attention_bhsd(q, k, v, blk_q=b, blk_k=b))
        for b in (32, 64, 128)
    ]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_gqa_wrapper_layout():
    from repro.kernels.flash_attention.ops import flash_attention

    B, S, M, G, Dh = 2, 128, 2, 2, 64
    q = jax.random.normal(jax.random.key(0), (B, S, M, G, Dh))
    k = jax.random.normal(jax.random.key(1), (B, S, M, Dh))
    v = jax.random.normal(jax.random.key(2), (B, S, M, Dh))
    out = flash_attention(q, k, v, blk_q=64, blk_k=64)
    assert out.shape == (B, S, M * G, Dh)
    # spot-check one (b, m, g) plane against the BHSD kernel
    ref = flash_attention_bhsd(
        q[:, :, 1, 1][:1], k[:, :, 1][:1], v[:, :, 1][:1], blk_q=64, blk_k=64
    )
    np.testing.assert_allclose(
        np.asarray(out[0, :, 3]), np.asarray(ref[0]), atol=1e-5
    )
