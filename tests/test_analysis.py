from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as rf


SYNTH_HLO = """
HloModule m
ENTRY %main {
  %x = f32[128,256]{1,0} parameter(0)
  %ag = f32[1024,256]{1,0} all-gather(%x), replica_groups=[1,8]<=[8], dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[16,256]{1,0} reduce-scatter(%x), replica_groups=[1,8]<=[8], dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
  %a2a = f32[128,256]{1,0} all-to-all(%x), replica_groups=[1,8]<=[8]
  %ars = f32[128,256]{1,0} all-reduce-start(%x), replica_groups=[1,8]<=[8]
  %ard = f32[128,256]{1,0} all-reduce-done(%ars)
}
"""


def test_collective_bytes_parser():
    out = hlo_lib.collective_bytes(SYNTH_HLO)
    base = 128 * 256 * 4
    assert out["bytes"]["all-gather"] == 1024 * 256 * 4 // 8  # operand = result/G
    assert out["bytes"]["all-reduce"] == base * 2  # ar + ar-start
    assert out["bytes"]["reduce-scatter"] == 16 * 256 * 4 * 8  # operand = result*G
    assert out["bytes"]["collective-permute"] == base
    assert out["bytes"]["all-to-all"] == base
    assert out["count"]["all-reduce"] == 2  # -done not double counted


def test_extrapolation_math():
    m1 = {"flops": 100.0, "bytes_accessed": 50.0,
          "collectives": {"total_bytes": 10, "bytes": {"all-reduce": 10}}}
    m2 = {"flops": 160.0, "bytes_accessed": 70.0,
          "collectives": {"total_bytes": 14, "bytes": {"all-reduce": 14}}}
    out = rf.extrapolate_layers(m1, m2, num_layers=10)
    assert out["flops"] == 100 + 9 * 60
    assert out["bytes_accessed"] == 50 + 9 * 20
    assert out["collective_total_bytes"] == 10 + 9 * 4


def test_roofline_terms_and_bottleneck():
    t = rf.RooflineTerms(
        flops=197e12 * 0.5,  # 0.5s compute
        bytes_accessed=819e9 * 0.1,  # 0.1s memory
        collective_bytes=50e9 * 0.2,  # 0.2s collective
        model_flops_global=197e12 * 0.4 * 256,
        chips=256,
    )
    assert t.bottleneck == "compute"
    assert abs(t.t_compute - 0.5) < 1e-9
    assert abs(t.roofline_fraction - 0.8) < 1e-9


def test_model_flops():
    from repro.configs import ARCHS, TRAIN_4K, DECODE_32K

    cfg = ARCHS["smollm-360m"]
    n = 361_821_120
    mf = rf.model_flops(cfg, TRAIN_4K, n, n)
    assert mf == 6.0 * n * 256 * 4096
    mf_d = rf.model_flops(cfg, DECODE_32K, n, n)
    assert mf_d == 2.0 * n * 128
