import numpy as np
import jax
import jax.numpy as jnp

from repro.models import rglru


def make_params(key, D=8, W=8):
    ks = jax.random.split(jax.random.key(key), 8)
    return {
        "w_gelu": jax.random.normal(ks[0], (D, W)) * 0.3,
        "w_lin": jax.random.normal(ks[1], (D, W)) * 0.3,
        "conv_w": jax.random.normal(ks[2], (4, W)) * 0.3,
        "conv_b": jnp.zeros((W,), jnp.float32),
        "w_a": jax.random.normal(ks[3], (W, W)) * 0.3,
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_x": jax.random.normal(ks[4], (W, W)) * 0.3,
        "b_x": jnp.zeros((W,), jnp.float32),
        "lam": jnp.ones((W,), jnp.float32),
        "w_out": jax.random.normal(ks[5], (W, D)) * 0.3,
    }


def test_assoc_scan_matches_sequential():
    p = make_params(0)
    x = jax.random.normal(jax.random.key(1), (2, 24, 8))
    h, final = rglru.rglru_scan(x, p)
    # sequential reference
    log_a, gate_i = rglru._gates(x, p)
    a = np.asarray(jnp.exp(log_a))
    beta = np.asarray(jnp.sqrt(1 - jnp.exp(2 * log_a)))
    gx = beta * np.asarray(gate_i) * np.asarray(x)
    hs = np.zeros((2, 8))
    seq = []
    for t in range(24):
        hs = a[:, t] * hs + gx[:, t]
        seq.append(hs.copy())
    seq = np.stack(seq, 1)
    np.testing.assert_allclose(np.asarray(h), seq, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(final), seq[:, -1], rtol=1e-5, atol=1e-5)


def test_decode_matches_scan():
    p = make_params(2)
    x = jax.random.normal(jax.random.key(3), (2, 16, 8))
    out_full, (conv_tail, lru_final) = rglru.recurrent_block(x, p, None)
    state = (jnp.zeros((2, 3, 8), jnp.float32),
             jnp.zeros((2, 8), jnp.float32))
    outs = []
    for t in range(16):
        o, state = rglru.recurrent_block_decode(x[:, t : t + 1], p, state)
        outs.append(o[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(out_full), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(state[1]), np.asarray(lru_final), rtol=1e-4, atol=1e-4
    )


def test_stability_bounded():
    """|h| stays bounded (a <= 1 guaranteed by the -c*softplus exponent)."""
    p = make_params(4)
    x = jax.random.normal(jax.random.key(5), (1, 512, 8)) * 10
    h, _ = rglru.rglru_scan(x, p)
    assert np.isfinite(np.asarray(h)).all()
