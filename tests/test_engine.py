"""The unified engine core: the acceptance matrix.

Every legacy layout (single / dist / ensemble / sharded / hybrid) now
dispatches through the single topology-parameterized scan
(repro.engine.day.run_days). These tests pin the refactor's contract
against the *pre-refactor* reference semantics — hand-rolled scans over
the legacy pure ``core/simulator.py:day_step`` and
``core/simulator_dist.py:dist_day_step`` (which remain in the tree as the
reference arithmetic) — bitwise, per scenario, for the ``jnp`` and
``compact`` interaction backends, plus the no-op scenario padding and the
in-scan observable path on sharded topologies.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ScenarioBatch
from repro.core import compat, disease
from repro.core import interventions as iv
from repro.core import simulator as sim_lib
from repro.core import simulator_dist as sd
from repro.data import digital_twin_population
from repro.engine import (
    CoreDriver,
    EngineCore,
    LocalTopology,
    MeshTopology,
    ProductTopology,
    ScenarioTopology,
    index_params,
    make_topology,
    no_op_params,
    run_chunked,
)
from repro.launch.mesh import make_worker_mesh

DAYS = 10
BACKENDS = ("jnp", "compact")


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(900, seed=5, name="engine-t")


@pytest.fixture(scope="module")
def batch():
    return ScenarioBatch.from_product(
        interventions={
            "baseline": (),
            "schools": [iv.Intervention(
                "schools", iv.CaseThreshold(on=30), iv.LocTypeIs(2),
                iv.CloseLocations(),
            )],
        },
        tau=2e-5,
        seeds=[11],
    )


def _legacy_single_hist(pop, batch, days, backend):
    """Pre-refactor reference: a jitted lax.scan over the legacy pure
    ``day_step`` (exactly what EpidemicSimulator.run compiled before the
    refactor), one scenario at a time."""
    from repro.core import interactions as inter_lib

    week = inter_lib.build_week_data(pop, 128, pack=True)
    contact_prob = jnp.asarray(pop.contact_prob)
    hists, finals = [], []
    for s in batch:
        iv_slots, _, params = sim_lib.build_params(
            pop, s.disease, s.tm, s.interventions, s.seed,
            seed_per_day=s.seed_per_day, seed_days=s.seed_days,
            static_network=s.static_network, iv_enabled=s.iv_enabled,
        )
        static = sim_lib.SimStatic(
            num_people=pop.num_people, num_locations=pop.num_locations,
            iv_slots=iv_slots, backend=backend,
        )
        state = sim_lib.init_state(s.disease, pop.num_people, len(iv_slots))
        final, hist = jax.jit(
            lambda st, p: sim_lib.run_scan(
                static, week, contact_prob, p, st, DAYS
            )
        )(state, params)
        hists.append(jax.device_get(hist))
        finals.append(final)
    return finals, hists


def _legacy_dist_hist(pop, batch, days, backend, workers=1):
    """Pre-refactor reference: shard_map(lax.scan over the legacy pure
    ``dist_day_step``) — the program DistSimulator.run compiled before."""
    mesh = make_worker_mesh(workers)
    plan = sd.build_dist_plan(pop, workers, 128, True, pack=True)
    week, route = sd.week_device_arrays(plan)
    hists, finals = [], []
    for s in batch:
        iv_slots, _, params = sim_lib.build_params(
            pop, s.disease, s.tm, s.interventions, s.seed,
            seed_per_day=s.seed_per_day, seed_days=s.seed_days,
            static_network=s.static_network, iv_enabled=s.iv_enabled,
        )
        params = sd.pad_params(params, plan)
        static = sd.make_dist_static(
            plan, pop.num_locations, iv_slots, backend=backend,
            max_seed_per_day=s.seed_per_day,
        )

        def worker(state, wk, rt, p):
            wk = jax.tree.map(lambda a: a.squeeze(1), wk)
            rt = jax.tree.map(lambda a: a.squeeze(1), rt)
            return sd.dist_run_scan(static, rt, wk, p, state, days)

        wspec = jax.tree.map(lambda _: P(None, sd.AXIS), week)
        rspec = jax.tree.map(lambda _: P(None, sd.AXIS), route)
        fn = jax.jit(compat.shard_map(
            worker, mesh=mesh,
            in_specs=(sd.dist_state_specs(), wspec, rspec,
                      sd.dist_param_specs()),
            out_specs=(sd.dist_state_specs(),
                       {k: P() for k in sd.STAT_KEYS}),
        ))
        state = sd.dist_init_state(s.disease, plan, len(iv_slots))
        final, hist = fn(state, week, route, params)
        hists.append(jax.device_get(hist))
        finals.append(final)
    return finals, hists


# ---------------------------------------------------------------------------
# the acceptance matrix: 5 layouts × {jnp, compact}, bitwise vs pre-refactor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_layout_matrix_bitwise_vs_prerefactor(pop, batch, backend):
    finals_ref, hists_ref = _legacy_single_hist(pop, batch, DAYS, backend)

    # single: local core, one scenario per B=1 run
    core1 = EngineCore(pop, batch, layout="local", backend=backend)
    for i in range(len(batch)):
        sl = lambda t: jax.tree.map(lambda x: x[i: i + 1], t)
        f, _, h, _ = core1.run_days(
            DAYS, params=sl(core1.params), state=sl(core1.init_state())
        )
        for k in sim_lib.STAT_KEYS:
            np.testing.assert_array_equal(
                hists_ref[i][k], h[k][:, 0], err_msg=f"single/{backend}/{k}")
        np.testing.assert_array_equal(
            np.asarray(finals_ref[i].health), np.asarray(f.health)[0])

    # ensemble: the same local core, whole batch in one scan
    _, _, hist_ens, _ = core1.run_days(DAYS)
    for i in range(len(batch)):
        for k in sim_lib.STAT_KEYS:
            np.testing.assert_array_equal(
                hists_ref[i][k], hist_ens[k][:, i],
                err_msg=f"ensemble/{backend}/{k}")

    # dist: workers topology, bitwise vs the legacy shard_map scan
    finals_d, hists_d = _legacy_dist_hist(pop, batch, DAYS, backend)
    corew = EngineCore(pop, batch, layout="workers", workers=1,
                       backend=backend)
    for i in range(len(batch)):
        sl = lambda t: jax.tree.map(lambda x: x[i: i + 1], t)
        f, _, h, _ = corew.run_days(
            DAYS, params=sl(corew.params), state=sl(corew.init_state())
        )
        for k in sim_lib.STAT_KEYS:
            np.testing.assert_array_equal(
                hists_d[i][k], h[k][:, 0], err_msg=f"dist/{backend}/{k}")
            np.testing.assert_array_equal(
                hists_ref[i][k], h[k][:, 0],
                err_msg=f"dist-vs-single/{backend}/{k}")
        np.testing.assert_array_equal(
            np.asarray(finals_d[i].health), np.asarray(f.health)[0])

    # sharded + hybrid: scenario-sharded placements of the same scan
    for layout, kw in (("scenarios", dict(scen_shards=1)),
                       ("hybrid", dict(workers=1, scen_shards=1))):
        core = EngineCore(pop, batch, layout=layout, backend=backend, **kw)
        _, _, h, _ = core.run_days(DAYS)
        for i in range(len(batch)):
            for k in sim_lib.STAT_KEYS:
                np.testing.assert_array_equal(
                    hists_ref[i][k], h[k][:, i],
                    err_msg=f"{layout}/{backend}/{k}")

    # the intervention trigger really fired in scenario 1 (non-trivial run)
    assert hist_ens["cumulative"][-1, 0] != hist_ens["cumulative"][-1, 1]


@pytest.mark.parametrize("layout,kw", [
    ("scenarios", dict(scen_shards=4)),
    ("hybrid", dict(workers=2, scen_shards=2)),
    ("workers", dict(workers=4)),
])
def test_layout_matrix_multidevice(pop, batch, layout, kw):
    """The same matrix on real >1-device meshes (CI multidevice job)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    _, hists_ref = _legacy_single_hist(pop, batch, DAYS, "jnp")
    core = EngineCore(pop, batch, layout=layout, backend="jnp", **kw)
    if layout == "workers":
        for i in range(len(batch)):
            sl = lambda t: jax.tree.map(lambda x: x[i: i + 1], t)
            _, _, h, _ = core.run_days(
                DAYS, params=sl(core.params), state=sl(core.init_state()))
            for k in sim_lib.STAT_KEYS:
                np.testing.assert_array_equal(hists_ref[i][k], h[k][:, 0],
                                              err_msg=f"{layout}/{k}")
    else:
        _, _, h, _ = core.run_days(DAYS)
        for i in range(len(batch)):
            for k in sim_lib.STAT_KEYS:
                np.testing.assert_array_equal(hists_ref[i][k], h[k][:, i],
                                              err_msg=f"{layout}/{k}")


# ---------------------------------------------------------------------------
# topology protocol
# ---------------------------------------------------------------------------


def test_topology_composition():
    assert isinstance(make_topology(None, None), LocalTopology)
    assert isinstance(make_topology("workers", None), MeshTopology)
    assert isinstance(make_topology(None, "scenarios"), ScenarioTopology)
    prod = make_topology("workers", "scenarios")
    assert isinstance(prod, ProductTopology)
    # operator composition mirrors the factory
    assert MeshTopology() * ScenarioTopology() == prod
    assert prod.axis_names == ("workers", "scenarios")
    # identity placement composes away (reflected via __rmul__)
    assert LocalTopology() * ScenarioTopology() == ScenarioTopology()
    with pytest.raises(TypeError):
        _ = ScenarioTopology() * MeshTopology()


def test_local_topology_identity_collectives():
    topo = LocalTopology()
    x = jnp.arange(5.0, dtype=jnp.float32)
    np.testing.assert_array_equal(topo.psum(x), x)
    np.testing.assert_array_equal(topo.pmax(x), x)
    assert int(topo.worker_index()) == 0
    np.testing.assert_array_equal(topo.scen_gather(x, 3), x[:3])
    # dispatch == masked gather; combine == segment_sum
    pid = jnp.asarray([0, 2, -1, 1], jnp.int32)
    chans = jnp.arange(3.0, dtype=jnp.float32)[:, None]
    out = topo.dispatch(None, pid, chans)
    np.testing.assert_array_equal(out[:, 0], [0.0, 2.0, 0.0, 1.0])
    acc = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    active = pid >= 0
    back = topo.combine(None, pid, active, acc, 3)
    np.testing.assert_array_equal(back, [1.0, 4.0, 2.0])


def test_local_seed_threshold_matches_sort():
    topo = LocalTopology()
    u = jnp.asarray([0.9, 0.1, 0.5, 0.3], jnp.float32)
    t = topo.seed_threshold(u, jnp.asarray(2, jnp.int32), 4, 2)
    assert float(t) == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# no-op padding (the padded-slot satellite)
# ---------------------------------------------------------------------------


def test_no_op_params_are_inert(pop):
    b = ScenarioBatch.from_product(disease=disease.covid_model(),
                                   tau=2e-5, seeds=[1])
    core = EngineCore(pop, b, layout="local")
    inert = no_op_params(index_params(core.params, 0))
    state, _, hist, _ = core.run_days(
        8, params=jax.tree.map(lambda x: x[None], inert))
    assert int(np.asarray(state.cumulative)[0]) == 0
    assert hist["new_infections"].sum() == 0
    assert hist["infectious"].max() == 0


def test_scenario_padding_never_in_results(pop):
    """A 3-real batch on a 4-shard scenario axis: the pad slot is inert
    and sliced off every returned history."""
    from repro.engine.core import pad_batch

    b = ScenarioBatch.from_product(disease=disease.covid_model(),
                                   tau=2e-5, seeds=[1, 2, 3])
    padded = pad_batch(b, 4)
    assert len(padded) == 4
    assert padded[3].name.startswith("__pad")

    if len(jax.devices()) >= 4:
        core4 = EngineCore(pop, b, layout="scenarios", scen_shards=4)
        assert len(core4.padded) == 4
        final, _, hist, _ = core4.run_days(DAYS)
        assert all(v.shape[1] == 3 for v in hist.values())
        # the pad column did no epidemiology at all
        assert int(np.asarray(final.cumulative)[3]) == 0
        ref = EngineCore(pop, b, layout="local").run_days(DAYS)[2]
        for k in sim_lib.STAT_KEYS:
            np.testing.assert_array_equal(ref[k], hist[k], err_msg=k)


# ---------------------------------------------------------------------------
# chunked checkpoint/resume at the engine level
# ---------------------------------------------------------------------------


def test_run_chunked_without_manager_single_chunk(pop, batch):
    from repro.api import observables as obs_lib

    obs = obs_lib.make_observables(("attack_rate",))
    ctx = obs_lib.ObsContext(num_people=pop.num_people,
                             num_scenarios=len(batch))
    core = EngineCore(pop, batch, layout="local")
    driver = CoreDriver(core, obs)
    state, hist, carries, dailies, resumed, chunks = run_chunked(
        driver, DAYS, obs, ctx)
    assert resumed is None and chunks == 1
    _, _, ref, _ = core.run_days(DAYS)
    for k in sim_lib.STAT_KEYS:
        np.testing.assert_array_equal(ref[k], hist[k], err_msg=k)
    final = obs_lib.observables_to_numpy(
        obs_lib.finalize_all(obs, carries, dailies, ctx))
    np.testing.assert_array_equal(final["attack_rate"]["cumulative"],
                                  hist["cumulative"][-1])


def test_engine_core_rejects_unknown_layout(pop, batch):
    with pytest.raises(ValueError, match="layout"):
        EngineCore(pop, batch, layout="banana")


def test_engine_core_rejects_mismatched_mesh(pop, batch):
    with pytest.raises(ValueError, match="mesh axes"):
        EngineCore(pop, batch, layout="scenarios",
                   mesh=make_worker_mesh(1))


def test_slot_structure_validation(pop):
    """Mixed intervention structures are rejected at batch-params build."""
    s0 = ScenarioBatch.from_product(disease=disease.covid_model(),
                                    tau=2e-5, seeds=[1])[0]
    s1 = dataclasses.replace(
        s0, name="other",
        interventions=(iv.Intervention(
            "schools", iv.CaseThreshold(on=30), iv.LocTypeIs(2),
            iv.CloseLocations()),),
        iv_enabled=(True,),
    )
    with pytest.raises(ValueError, match="intervention structure"):
        EngineCore(pop, ScenarioBatch(scenarios=(s0, s1)), layout="local")


# ---------------------------------------------------------------------------
# per-agent interventions (PR 7): the tracing accumulator and TTI state
# must be bitwise identical across every backend and every layout.
# ---------------------------------------------------------------------------

TTI_DAYS = 25


@pytest.fixture(scope="module")
def tti_kw():
    return dict(
        interventions=[iv.TestTraceIsolate(
            "tti", tests_per_day=15, start_day=3, isolation_days=6,
            trace_isolation_days=9,
        )],
        iv_enabled=[True], seed=7, seed_per_day=4,
    )


def _tti_hist(pop, tti_kw, **core_kw):
    core = EngineCore.single(pop, disease.covid_model(), **tti_kw, **core_kw)
    return core.run1(TTI_DAYS)[1]


def test_tti_bitwise_across_backends(pop, tti_kw):
    ref = _tti_hist(pop, tti_kw, backend="jnp")
    # the run exercises every new pathway
    assert ref["tests_used"].sum() > 0
    assert ref["traced"].sum() > 0
    assert ref["isolated"].sum() > 0
    for backend in ("scan", "compact", "pallas", "pallas-compact"):
        h = _tti_hist(pop, tti_kw, backend=backend)
        for k in sim_lib.STAT_KEYS:
            np.testing.assert_array_equal(
                ref[k], h[k], err_msg=f"{backend}/{k}")


def test_tti_bitwise_across_layouts(pop, tti_kw):
    ref = _tti_hist(pop, tti_kw)
    for layout, kw in (("workers", dict(workers=1)),
                       ("scenarios", dict(scen_shards=1)),
                       ("hybrid", dict(workers=1, scen_shards=1))):
        h = _tti_hist(pop, tti_kw, layout=layout, **kw)
        for k in sim_lib.STAT_KEYS:
            np.testing.assert_array_equal(
                ref[k], h[k], err_msg=f"{layout}/{k}")


@pytest.mark.parametrize("layout,kw", [
    ("scenarios", dict(scen_shards=4)),
    ("hybrid", dict(workers=2, scen_shards=2)),
    ("workers", dict(workers=4)),
])
def test_tti_multidevice(pop, tti_kw, layout, kw):
    """Tracing + test budget on real >1-device meshes: the traced-contact
    halo rides the exposure exchange and the budget's order statistic
    gathers per-worker candidates — both must stay bitwise."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    ref = _tti_hist(pop, tti_kw)
    h = _tti_hist(pop, tti_kw, layout=layout, **kw)
    for k in sim_lib.STAT_KEYS:
        np.testing.assert_array_equal(ref[k], h[k], err_msg=f"{layout}/{k}")


def test_mixed_family_slot_structure_validated(pop):
    """A batch mixing TTI-present and TTI-absent scenarios has divergent
    per-agent slot structure and must be rejected like classic slots."""
    from repro.configs.sweep import Scenario
    from repro.core import transmission as tx

    mk = lambda name, ivs: Scenario(
        name=name, disease=disease.covid_model(), tm=tx.TransmissionModel(),
        interventions=tuple(ivs), iv_enabled=(), seed=0,
    )
    bad = [
        mk("a", [iv.Intervention("x", iv.DayRange(0), iv.Everyone(),
                                 iv.ScaleInfectivity(0.5))]),
        mk("b", [iv.TestTraceIsolate("x", tests_per_day=5)]),
    ]
    with pytest.raises(ValueError, match="intervention structure"):
        EngineCore(pop, bad)


# ---------------------------------------------------------------------------
# the collective schedule is part of the determinism contract: a fixed
# topology must emit a FIXED set of collectives (data-dependent counts
# would vary the reduction order, forking float summation run to run).
# Pinned per layout, next to the mesh shape they were derived on; the
# counts are per-shard jaxpr facts, so they hold for any mesh size.
# ---------------------------------------------------------------------------

# (workers axis) exposure all_to_all out+back, halo gather, psum reductions
WORKERS_COLLECTIVES = {"all_to_all": 2, "all_gather": 1, "psum": 5}
# (scenarios axis) replicated-stat gathers only — no cross-scenario math
SCENARIOS_COLLECTIVES = {"all_gather": 10}
# (workers x scenarios) exactly the sum of the two axes' schedules, plus
# one extra all_gather where the scenario axis collects the worker-reduced
# stats
HYBRID_COLLECTIVES = {"all_to_all": 2, "all_gather": 11, "psum": 5}


@pytest.mark.parametrize("layout,kw,expected", [
    ("local", {}, {}),
    ("workers", dict(workers=1), WORKERS_COLLECTIVES),
    ("scenarios", dict(scen_shards=1), SCENARIOS_COLLECTIVES),
    ("hybrid", dict(workers=1, scen_shards=1), HYBRID_COLLECTIVES),
])
def test_collective_schedule_pinned_per_topology(pop, batch, layout, kw,
                                                 expected):
    from repro.analysis import hlo

    core = EngineCore(pop, batch, layout=layout, **kw)
    args = lambda days: (core.runner_fn(days, ()), core.params,
                         core.init_state(), (), core.week, core.route)
    counts = hlo.collective_count(*args(3))
    assert counts == expected, f"{layout} collective schedule changed"
    # ...and it must not scale with the day count: the collectives live in
    # the scan body, so a longer run replays the same schedule.
    assert hlo.collective_count(*args(6)) == expected


# ---------------------------------------------------------------------------
# bounded runner cache (the serve tier's executable-budget seam)
# ---------------------------------------------------------------------------


def test_runner_cache_bounded_lru(pop, batch):
    core = EngineCore(pop, batch, layout="local", max_runners=2)
    r3 = core.runner_fn(3, ())
    core.runner_fn(4, ())
    assert core.runner_cached(3, ()) and core.runner_cached(4, ())
    # a recency-bumping hit keeps (3,) alive through the next eviction
    assert core.runner_fn(3, ()) is r3
    core.runner_fn(5, ())  # evicts (4,), the least recently used
    assert core.runner_cached(3, ()) and core.runner_cached(5, ())
    assert not core.runner_cached(4, ())
    stats = core.runner_cache_stats()
    assert stats["size"] == 2 and stats["max_entries"] == 2
    assert stats["evictions"] == 1 and stats["hits"] == 1
    # re-building the evicted runner is correct, just a fresh trace
    assert core.runner_fn(4, ()) is not None
    assert core.runner_cache_stats()["evictions"] == 2


def test_local_rank_threshold_budget_semantics():
    topo = LocalTopology()
    score = jnp.asarray([0.5, 4.0, 0.1, 2.2, 4.0], jnp.float32)
    gpid = jnp.arange(5, dtype=jnp.uint32)
    T, G = topo.rank_threshold(score, gpid, jnp.asarray(2, jnp.int32), 5, 1)
    take = (score < T) | ((score == T) & (gpid <= G))
    np.testing.assert_array_equal(
        np.asarray(take), [True, False, True, False, False])
    # budget larger than the eligible pool: threshold lands on the 4.0 tier
    T, G = topo.rank_threshold(score, gpid, jnp.asarray(4, jnp.int32), 5, 1)
    assert float(T) == 4.0
