"""Golden-bad: DET006 — host nondeterminism inside a traced step.

Expected findings: the wall-clock read (baked in at trace time), the
set iteration (PYTHONHASHSEED-dependent order), and the attribute
mutation (state behind jit's back).
"""

import time


def day_step(state, tracker):
    t = time.time()
    for item in {1, 2, 3}:
        state = state + item
    tracker.last = state
    return state, t
