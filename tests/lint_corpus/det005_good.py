"""Golden-good: DET005 — the two sanctioned write shapes: an
unconditional final write, and the row_start zeroing idiom for a
guarded accumulator."""

import jax.numpy as jnp
from jax.experimental import pallas as pl


def good_kernel(x_ref, o_ref, acc_ref):
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    o_ref[...] = x_ref[...] + acc_ref[...]
