"""Golden-bad: DET003 — dtype-unpinned constructors and default-dtype
scalar math.

Expected findings: ``zeros`` / ``arange`` without dtype, the bare-float
``jnp.log`` (computes in f64 under x64), and the unpinned literal
``jnp.array``.
"""

import jax.numpy as jnp


def build(n):
    z = jnp.zeros(n)
    r = jnp.arange(n)
    s = jnp.log(10000.0)
    a = jnp.array(0.5)
    return z, r, s, a
