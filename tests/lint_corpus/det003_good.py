"""Golden-good: DET003 — every constructor pins its dtype, scalar math
wraps an operand in a concrete dtype."""

import jax.numpy as jnp


def build(n):
    z = jnp.zeros(n, jnp.float32)
    r = jnp.arange(n, dtype=jnp.int32)
    s = jnp.log(jnp.float32(10000.0))
    a = jnp.array(0.5, jnp.float32)
    m = jnp.ones(n, bool)
    return z, r, s, a, m
