"""Golden-bad: DET001 — raw RNG outside core/rng.py.

Expected findings: the stdlib ``random`` import, the ``random.random()``
call, and the ``np.random`` draw. No other rule applies.
"""

import random

import numpy as np


def pick_host_seed():
    return random.random()


def jitter(n):
    return np.random.rand(n)
