"""Golden-bad: DET004 — integer sums crossing psum without widening.

Expected findings: the sum pinned to int32 before the collective, and
the raw unwidened sum. Both are the PR-2 contacts-overflow shape.
"""

import jax
import jax.numpy as jnp


def day_counts(contacts):
    pinned = jax.lax.psum(contacts.sum().astype(jnp.int32), "workers")
    raw = jax.lax.psum(contacts.sum(), "workers")
    return pinned, raw
