"""Golden-good: DET004 — bool-mask sums (bounded by shard width) stay
int32; unbounded sums widen through a named dtype seam or int64."""

import jax
import jax.numpy as jnp


def day_counts(contacts, infected, cdtype):
    mask = infected > 0
    bounded = jax.lax.psum(mask.sum().astype(jnp.int32), "workers")
    widened = jax.lax.psum(contacts.sum().astype(cdtype), "workers")
    wide64 = jax.lax.psum(contacts.sum().astype(jnp.int64), "workers")
    return bounded, widened, wide64
