"""Golden-bad: DET002 — undeclared / literal RNG stream ids.

Expected findings: the literal ``0x99`` stream, the missing stream
argument on ``exponential``, and (when the test supplies the declared
registry) the undeclared ``rng.UNREGISTERED`` constant.
"""

from repro.core import rng


def draw(seed, day, pid):
    u = rng.uniform(seed, 0x99, day, pid)
    v = rng.exponential(3.0, seed)
    w = rng.hash_u32(seed, rng.UNREGISTERED, day, pid)
    return u, v, w
