"""Golden-good: DET001 — draws routed through the counter-RNG streams."""

from repro.core import rng


def pick(seed, day, pid):
    return rng.uniform(seed, rng.CONTACT, day, pid)
