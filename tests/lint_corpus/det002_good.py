"""Golden-good: DET002 — every draw passes a declared stream constant
(including the ``int(rng.X)`` numpy-mirror idiom)."""

from repro.core import rng


def draw(seed, day, pid):
    u = rng.uniform(seed, rng.CONTACT, day, pid)
    v = rng.exponential(3.0, seed, int(rng.DWELL), day)
    return u, v
