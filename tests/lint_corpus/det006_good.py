"""Golden-good: DET006 — the traced step is pure in (params, state):
sorted iteration, no clock, no attribute writes."""


def day_step(state, items):
    for item in sorted(items):
        state = state + item
    return state
