"""Golden-bad: DET005 — output ref written only under pl.when.

Expected finding: ``o_ref`` has no unconditional write and no zeroing
branch, so grid steps where ``ki != 0`` flush undefined VMEM.
"""

from jax.experimental import pallas as pl


def bad_kernel(x_ref, o_ref):
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _write():
        o_ref[...] = x_ref[...] * 2.0
