import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced_config
from repro.models import moe


def small_moe(E=4, K=2, cf=8.0):
    return dataclasses.replace(
        reduced_config(ARCHS["mixtral-8x7b"]), compute_dtype="float32",
        num_experts=E, experts_per_token=K, capacity_factor=cf,
    )


def make_params(cfg, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": jax.random.normal(ks[0], (D, E)) * 0.02,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D),
        "w_down": jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F),
    }


def dense_reference(x, p, cfg):
    """Compute every expert densely and combine with the same gates."""
    xt = x.reshape(-1, x.shape[-1])
    logits = xt @ p["router"]
    gate_v, gate_i = jax.lax.top_k(logits, cfg.experts_per_token)
    gates = jax.nn.softmax(gate_v.astype(jnp.float32), -1)
    outs = []
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, 1)  # (T, E, D)
    sel = jnp.take_along_axis(outs, gate_i[..., None], axis=1)  # (T, K, D)
    return (sel * gates[..., None]).sum(1).reshape(x.shape)


def test_matches_dense_when_capacity_ample():
    cfg = small_moe(cf=8.0)
    p = make_params(cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    out, aux = moe.moe_ffn(x, p, cfg)
    ref = dense_reference(x, p, cfg)
    assert float(aux["dropped_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_capacity_drops_tokens():
    cfg = small_moe(cf=0.25)
    p = make_params(cfg)
    x = jax.random.normal(jax.random.key(2), (4, 32, cfg.d_model))
    out, aux = moe.moe_ffn(x, p, cfg)
    assert float(aux["dropped_fraction"]) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_load_balance_loss_range():
    cfg = small_moe()
    p = make_params(cfg)
    x = jax.random.normal(jax.random.key(3), (2, 64, cfg.d_model))
    _, aux = moe.moe_ffn(x, p, cfg)
    # >= 1 by Cauchy-Schwarz at uniform; near-uniform router at init
    assert 0.9 < float(aux["load_balance"]) < 4.0


def test_dropping_is_deterministic():
    cfg = small_moe(cf=0.5)
    p = make_params(cfg)
    x = jax.random.normal(jax.random.key(4), (2, 32, cfg.d_model))
    a, _ = moe.moe_ffn(x, p, cfg)
    b, _ = moe.moe_ffn(x, p, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
