"""Distributed engine layouts: partition invariance (bitwise) across worker
counts and partitioning schemes, intervention semantics (Vaccinate +
trigger activation), outbreak-seeding edge cases, and the hybrid
(workers x scenarios) ensemble. Multi-device runs happen in a subprocess
because the host device count is locked at first jax init; in-process
twins of the same checks run directly when the session already has >= 4
devices (the CI multi-device job)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

# Interventions exercising every action kind, with both trigger families
# (DayRange and hysteresis/latching CaseThreshold) activating mid-run.
IVS_SRC = r"""
ivs = [
    iv.Intervention('vax', iv.DayRange(3), iv.RandomFraction(0.3, salt=9),
                    iv.Vaccinate(0.8)),
    iv.Intervention('schools', iv.CaseThreshold(on=30, off=10),
                    iv.LocTypeIs(2), iv.CloseLocations()),
    iv.Intervention('masks', iv.CaseThreshold(on=60), iv.Everyone(),
                    iv.ScaleInfectivity(0.5)),
    iv.Intervention('iso', iv.DayRange(5, 9), iv.RandomFraction(0.2, salt=4),
                    iv.Isolate()),
]
"""

SCRIPT = r"""
import numpy as np, jax, json
from jax.sharding import Mesh
from repro.data import digital_twin_population
from repro.configs import ScenarioBatch
from repro.core import disease, interventions as iv, transmission
from repro.engine.core import EngineCore
from repro.launch.mesh import make_hybrid_mesh

pop = digital_twin_population(1200, seed=1, name='t')
P = pop.num_people
tm = transmission.TransmissionModel(tau=2e-5)
out = {}

# --- partition invariance, no interventions -------------------------------
sim = EngineCore.single(pop, disease.covid_model(), tm, seed=3)
f1, h1 = sim.run1(15)
out['single'] = h1['cumulative'].tolist()
for W in (2, 8):
    mesh = Mesh(np.array(jax.devices()[:W]), ('workers',))
    # W=2 runs the active-set 'compact' backend: its runtime tile
    # compaction must stay bitwise-parity with the jnp single-device run.
    d = EngineCore.single(pop, disease.covid_model(), tm, seed=3,
                          layout='workers', mesh=mesh,
                          backend='compact' if W == 2 else 'jnp')
    fd, hd = d.run1(15)
    out[f'dist{W}'] = hd['cumulative'].tolist()
    out[f'dist{W}_state_equal'] = bool(
        (np.asarray(fd.health)[:P] == np.asarray(f1.health)).all()
        and (np.asarray(fd.dwell)[:P] == np.asarray(f1.dwell)).all())
    out[f'dist{W}_single_program'] = len(d._runners) == 1
mesh = Mesh(np.array(jax.devices()[:8]), ('workers',))
d = EngineCore.single(pop, disease.covid_model(), tm, seed=3,
                      layout='workers', mesh=mesh, balanced=False)
out['dist8_naive'] = d.run1(15)[1]['cumulative'].tolist()

# --- Vaccinate + trigger activation parity --------------------------------
IVS
sim = EngineCore.single(pop, disease.covid_model(), tm,
                        interventions=ivs, seed=3)
fs, hs = sim.run1(15)
mesh2 = Mesh(np.array(jax.devices()[:2]), ('workers',))
d = EngineCore.single(pop, disease.covid_model(), tm, interventions=ivs,
                      seed=3, layout='workers', mesh=mesh2)
fd, hd = d.run1(15)
out['iv_single'] = hs['cumulative'].tolist()
out['iv_dist'] = hd['cumulative'].tolist()
out['iv_state_equal'] = bool(
    (np.asarray(fd.health)[:P] == np.asarray(fs.health)).all()
    and (np.asarray(fd.vaccinated)[:P] == np.asarray(fs.vaccinated)).all())
out['iv_vax_count'] = int(np.asarray(fs.vaccinated).sum())

# --- seeding edge cases: seed_per_day = 0 and > people-per-worker ---------
mesh8 = Mesh(np.array(jax.devices()[:8]), ('workers',))
for spd in (0, 500):  # Pw = 150 at W=8, so 500 exceeds every local shard
    s = EngineCore.single(pop, disease.covid_model(), tm, seed=5,
                          seed_per_day=spd)
    dd = EngineCore.single(pop, disease.covid_model(), tm, seed=5,
                           seed_per_day=spd, layout='workers', mesh=mesh8)
    out[f'seed{spd}_single'] = s.run1(8)[1]['cumulative'].tolist()
    out[f'seed{spd}_dist'] = dd.run1(8)[1]['cumulative'].tolist()

# --- hybrid (W=2, S=2) vs sequential dist vs single-device ensemble ------
batch = ScenarioBatch.from_product(
    interventions={'baseline': (), 'schools': [iv.Intervention(
        'schools', iv.CaseThreshold(on=30), iv.LocTypeIs(2),
        iv.CloseLocations())]},
    tau=2e-5, seeds=[3])
hyb = EngineCore(pop, batch, layout='hybrid', mesh=make_hybrid_mesh(2, 2))
fh, hh = hyb.run(15)
ens = EngineCore(pop, batch)
fe, he = ens.run(15)
out['hybrid'] = np.asarray(hh['cumulative']).T.tolist()
out['ens'] = np.asarray(he['cumulative']).T.tolist()
seq = []
state_eq = True
for i, sc in enumerate(batch):
    d = EngineCore.single(
        pop, sc.disease, sc.tm, interventions=sc.interventions,
        seed=sc.seed, iv_enabled=sc.iv_enabled, layout='workers', mesh=mesh2)
    fd, hd = d.run1(15)
    seq.append(hd['cumulative'].tolist())
    state_eq = state_eq and bool(
        (np.asarray(fd.health) == np.asarray(fh.health)[i]).all())
out['seq_dist'] = seq
out['hybrid_state_equal'] = state_eq and bool(
    (np.asarray(fh.health)[:, :P] == np.asarray(fe.health)).all())
print("RESULT " + json.dumps(out))
""".replace("IVS", IVS_SRC)


@pytest.mark.slow
def test_partition_invariance_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])

    # Partition invariance across worker counts + partitioning schemes.
    assert out["single"] == out["dist2"] == out["dist8"] == out["dist8_naive"]
    assert out["single"][-1] > 70  # an actual outbreak was simulated
    assert out["dist2_state_equal"] and out["dist8_state_equal"]
    # The whole run compiled as ONE jitted scan (no per-day dispatch).
    assert out["dist2_single_program"] and out["dist8_single_program"]

    # Vaccinate + trigger activation: bitwise parity, and the interventions
    # actually fired (trajectory diverges from the baseline run).
    assert out["iv_single"] == out["iv_dist"]
    assert out["iv_state_equal"]
    assert out["iv_vax_count"] > 0
    assert out["iv_single"] != out["single"]

    # Seeding edge cases: seed_per_day=0 seeds nobody on either path;
    # seed_per_day > people-per-worker stays aligned with the single path.
    assert out["seed0_single"] == out["seed0_dist"] == [0] * 8
    assert out["seed500_single"] == out["seed500_dist"]
    assert out["seed500_single"][-1] > 0

    # Hybrid three-way equality: per-scenario trajectories match sequential
    # worker-sharded runs AND the single-device ensemble, bitwise.
    assert out["hybrid"] == out["seq_dist"] == out["ens"]
    assert out["hybrid_state_equal"]
    assert out["hybrid"][0] != out["hybrid"][1]  # school closure bites


# ---------------------------------------------------------------------------
# In-process twins for multi-device sessions (the CI multi-device job runs
# pytest under XLA_FLAGS=--xla_force_host_platform_device_count=4, so these
# execute the shard_map paths directly on every PR).
# ---------------------------------------------------------------------------


def _need_devices(n):
    import jax

    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.mark.parametrize("backend", ["jnp", "compact"])
def test_dist_run_single_scan_matches_single_device(backend):
    _need_devices(2)
    import jax
    from jax.sharding import Mesh
    from repro.core import disease, transmission
    from repro.engine.core import EngineCore
    from repro.data import digital_twin_population

    pop = digital_twin_population(800, seed=2, name="dist-inproc")
    tm = transmission.TransmissionModel(tau=2e-5)
    sim = EngineCore.single(pop, disease.covid_model(), tm, seed=4)
    f1, h1 = sim.run1(10)
    mesh = Mesh(np.array(jax.devices()[:2]), ("workers",))
    d = EngineCore.single(pop, disease.covid_model(), tm, seed=4,
                          layout="workers", mesh=mesh, backend=backend)
    fd, hd = d.run1(10)
    for key in ("cumulative", "new_infections", "infectious", "susceptible",
                "contacts"):
        np.testing.assert_array_equal(h1[key], hd[key])
    np.testing.assert_array_equal(
        np.asarray(f1.health), np.asarray(fd.health)[: pop.num_people]
    )
    # One cached runner for the whole run — a single jitted scan program.
    assert list(d._runners) == [(10, ())]
