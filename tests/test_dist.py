"""Distributed simulator: partition invariance (bitwise) across worker
counts and partitioning schemes. Multi-device runs happen in a subprocess
because the host device count is locked at first jax init."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import numpy as np, jax, json
from jax.sharding import Mesh
from repro.data import digital_twin_population
from repro.core import disease, simulator, simulator_dist, transmission

pop = digital_twin_population(1200, seed=1, name='t')
tm = transmission.TransmissionModel(tau=2e-5)
out = {}
sim = simulator.EpidemicSimulator(pop, disease.covid_model(), tm, seed=3)
out['single'] = sim.run(15)[1]['cumulative'].tolist()
for W in (2, 8):
    mesh = Mesh(np.array(jax.devices()[:W]), ('workers',))
    d = simulator_dist.DistSimulator(pop, disease.covid_model(), mesh, tm, seed=3)
    out[f'dist{W}'] = d.run(15)[1]['cumulative'].tolist()
mesh = Mesh(np.array(jax.devices()[:8]), ('workers',))
d = simulator_dist.DistSimulator(pop, disease.covid_model(), mesh, tm, seed=3,
                                 balanced=False)
out['dist8_naive'] = d.run(15)[1]['cumulative'].tolist()
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_partition_invariance_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["single"] == out["dist2"] == out["dist8"] == out["dist8_naive"]
    assert out["single"][-1] > 70  # an actual outbreak was simulated
